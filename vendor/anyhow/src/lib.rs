//! Offline stand-in for the `anyhow` crate — the subset this workspace
//! actually uses, so the default build needs no registry access (the same
//! policy as `config::json` standing in for serde_json).
//!
//! Provided: [`Error`] (a boxed-free context chain), [`Result`], the
//! [`anyhow!`]/[`bail!`] macros, and the [`Context`] extension trait for
//! `Result` and `Option`. `{e}` prints the outermost message, `{e:#}`
//! prints the full `a: b: c` chain (matching real anyhow), and `{e:?}`
//! prints the chain as a "Caused by" list.

// Vendored stand-in: it tracks real anyhow's API shape, not the house
// style, so it is held to build + test but not to the clippy gate the
// first-party crates answer to (CI runs `clippy --workspace -D warnings`).
#![allow(clippy::all)]

use std::fmt;

/// An error: a root cause plus the context frames wrapped around it.
/// `chain[0]` is the outermost (most recent) context, the last element is
/// the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-separated (anyhow's format)
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what keeps the blanket conversion below coherent (same trick as real
// anyhow, minus the specialisation).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context frames.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` (or to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = anyhow!("root {}", 42);
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("missing thing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing thing");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("want {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "want 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("root"));
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }
}
