#!/usr/bin/env python3
"""Validate (and summarise) BENCH_micro.json, the machine-readable bench
snapshot `cargo bench --bench micro` writes and perf PRs commit.

Schema: a JSON array of records, each
    {"op": <non-empty str>, "size": <number > 0>, "ns_per_iter": <finite number > 0>}

Op names are additionally matched against the known op families below
(e.g. `stats_pass_w{W}`, `hot_swap`, `free_stats`, `serve_predict_w{W}`,
`serve_stream_w{W}`, `cycle_eval_{sync|pipelined}_w{W}_v{V}`,
`frontend_load_c{C}_{p50|p99|row}`); each family carries its trend
direction (which way a good PR moves it), echoed in the summary. An op
outside every family is
a **warning**, not an error — the gate stays non-blocking for new bench
keys — unless `--strict-ops` is passed.

Exit codes:
    0  file valid (or absent without --require); op-family warnings only
    1  file absent with --require
    2  malformed JSON, records violating the schema, or unknown op
       families under --strict-ops

Usage:
    python3 scripts/bench_trend.py [--require] [--strict-ops] [path ...]

Defaults to ./BENCH_micro.json. Run from CI as a non-blocking step after
the bench so a bad emitter is caught the moment it lands, and locally to
eyeball the per-op trend (min/max ns across sizes).
"""

import json
import math
import re
import sys

# The bench emitter's op vocabulary: one (regex, trend) pair per family.
# `trend` is the direction a *good* PR moves the metric — "lower" means a
# shrinking ns_per_iter is an improvement. It is per-family (not global)
# so latency-style keys and any future ratio-style keys can disagree;
# the summary prints it next to each op so a perf diff reads without
# cross-referencing the emitter. Keep in sync with rust/benches/micro.rs
# (each `rec.push` site).
KNOWN_OP_FAMILIES = [
    (r"stats_fwd_(rust_cpu|xla)", "lower"),
    (r"stats_vjp_(rust_cpu|xla)", "lower"),
    (r"engine_eval_by_chunk", "lower"),
    (r"engine_eval_sparse", "lower"),
    (r"dense_gp_eval", "lower"),
    (r"matmul_(naive|blocked|t)", "lower"),
    (r"syrk", "lower"),
    (r"cycle_eval_(sync|pipelined)_w\d+_v\d+", "lower"),
    (r"serve_predict_w\d+", "lower"),
    # streamed serving: same batches through predict_stream (batch k+1
    # issued before batch k's gather) — compare against serve_predict_w{W}
    (r"serve_stream_w\d+", "lower"),
    # the stats-only pass (distributed posterior rebuild) per worker
    # count, and the end-to-end refit-and-swap round
    (r"stats_pass_w\d+", "lower"),
    (r"hot_swap", "lower"),
    # posterior rebuild from the captured final-eval statistics (zero
    # collective rounds; only the leader's M×M factorisations remain)
    (r"free_stats", "lower"),
    # SIMD dispatch tiers: the rewired microkernels at the scalar escape
    # hatch ("off") vs the chunked-scalar / AVX2+FMA tiers
    (r"simd_(matmul|syrk|psi1|psi2)_(off|scalar|native)", "lower"),
    # concurrent-client serving front-end: sequential single-row baseline
    # (ns per request), then per-client-count request-latency quantiles
    # and inverse throughput (ns per served row) under closed-loop load
    (r"frontend_seq_1row", "lower"),
    (r"frontend_load_c\d+_(p50|p99|row)", "lower"),
    # point-to-point round trip through Comm over InMemoryTransport —
    # the dynamic dispatch + Result plumbing of the Transport trait
    (r"comm_transport_overhead", "lower"),
    # out-of-core chunk store: one full sequential read pass over the
    # store (same bytes, same grid — the file row is the disk cost) and
    # the streamed SGPR evaluation cycle at W ranks, each rank holding
    # only its double-buffered O(chunk) window
    (r"chunked_read_(resident|file)", "lower"),
    (r"cycle_eval_chunked_w\d+", "lower"),
]
_KNOWN_OPS = re.compile(
    "^(?:" + "|".join(rx for rx, _ in KNOWN_OP_FAMILIES) + ")$")


def trend_for(op):
    """The op's family trend direction, or '?' for unknown families."""
    for rx, trend in KNOWN_OP_FAMILIES:
        if re.fullmatch(rx, op):
            return trend
    return "?"


def validate(path, require, strict_ops=False):
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        if require:
            print(f"{path}: missing (run `cargo bench --bench micro` first)")
            return 1
        print(f"{path}: not present, skipping (pass --require to enforce)")
        return 0
    except json.JSONDecodeError as e:
        print(f"{path}: malformed JSON: {e}")
        return 2

    if not isinstance(data, list):
        print(f"{path}: top level must be an array, got {type(data).__name__}")
        return 2

    errors = []
    by_op = {}
    for i, rec in enumerate(data):
        where = f"{path}[{i}]"
        if not isinstance(rec, dict):
            errors.append(f"{where}: not an object")
            continue
        extra = set(rec) - {"op", "size", "ns_per_iter"}
        if extra:
            errors.append(f"{where}: unknown keys {sorted(extra)}")
        op = rec.get("op")
        if not isinstance(op, str) or not op:
            errors.append(f"{where}: 'op' must be a non-empty string, got {op!r}")
            continue
        size = rec.get("size")
        if not isinstance(size, (int, float)) or isinstance(size, bool) or size <= 0:
            errors.append(f"{where} ({op}): 'size' must be a positive number, got {size!r}")
        ns = rec.get("ns_per_iter")
        if (not isinstance(ns, (int, float)) or isinstance(ns, bool)
                or not math.isfinite(ns) or ns <= 0):
            errors.append(f"{where} ({op}): 'ns_per_iter' must be a finite positive "
                          f"number, got {ns!r}")
            continue
        by_op.setdefault(op, []).append((size, ns))

    if errors:
        for e in errors:
            print(e)
        print(f"{path}: {len(errors)} malformed entr{'y' if len(errors) == 1 else 'ies'} "
              f"out of {len(data)}")
        return 2

    unknown = sorted(op for op in by_op if not _KNOWN_OPS.match(op))
    if unknown:
        for op in unknown:
            print(f"{path}: warning: op {op!r} matches no known op family "
                  f"(new bench key? teach scripts/bench_trend.py)")
        if strict_ops:
            return 2

    print(f"{path}: {len(data)} records across {len(by_op)} ops")
    for op in sorted(by_op):
        points = sorted(by_op[op])
        lo, hi = min(ns for _, ns in points), max(ns for _, ns in points)
        sizes = "..".join(str(int(s)) for s in (points[0][0], points[-1][0]))
        print(f"  {op:<34} [{trend_for(op):<5}] sizes {sizes:<14} "
              f"ns/iter {lo:>14.1f} .. {hi:>14.1f}")
    return 0


def main(argv):
    require = "--require" in argv
    strict_ops = "--strict-ops" in argv
    paths = [a for a in argv if not a.startswith("--")] or ["BENCH_micro.json"]
    return max(validate(p, require, strict_ops) for p in paths)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
