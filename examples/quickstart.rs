//! Quickstart: sparse GP regression on 1-D synthetic data.
//!
//!   cargo run --release --example quickstart [-- --backend xla]
//!
//! Fits a sparse GP (M = 16 inducing points) to N = 1000 noisy samples of
//! a GP draw, prints the learned hyperparameters and train/test RMSE, and
//! sketches the posterior fit as ASCII art.

use anyhow::Result;
use gpparallel::cli::Args;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::{EngineConfig, OptChoice};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::linalg::Mat;
use gpparallel::models::SparseGpRegression;
use gpparallel::optim::Lbfgs;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let backend = BackendKind::parse(args.get("backend").unwrap_or("cpu"))
        .expect("--backend cpu|xla");

    // 1. data: y = f(x) + ε with f ~ GP(0, RBF), observed inputs
    let spec = SyntheticSpec { n: 1000, q: 1, d: 1, noise: 0.01, ..Default::default() };
    let ds = generate_supervised(&spec, 42);
    let x = ds.x().unwrap();
    let n_train = 900;
    let train = ds.take(n_train);
    let x_test = Mat::from_vec(100, 1, x.as_slice()[n_train..].to_vec());
    let y_test = Mat::from_vec(100, 1, ds.y().as_slice()[n_train..].to_vec());

    // 2. fit: 2 workers, chunked, L-BFGS on the variational bound
    let cfg = EngineConfig {
        workers: 2,
        chunk: 256,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 80, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let model = SparseGpRegression::fit(&train.x().unwrap(), &train.y(), 16,
                                        "quickstart", cfg, 42)?;

    // 3. report
    let r = &model.result;
    let kern = &r.fitted.kerns[0];
    println!("== quickstart: sparse GP regression (N={n_train}, M=16, backend={}) ==",
             backend.name());
    println!("final bound        : {:.2}", r.f);
    println!("iterations / evals : {} / {}", r.iterations, r.evaluations);
    println!("learned variance   : {:.3}   (generator: 1.0)", kern.variance);
    println!("learned lengthscale: {:.3}   (generator: 1.0)", kern.lengthscales[0]);
    println!("learned noise sd   : {:.4}  (generator: 0.1)",
             (1.0 / r.fitted.betas[0]).sqrt());
    println!("train RMSE         : {:.4}", model.rmse(&train.x().unwrap(), &train.y()));
    println!("test  RMSE         : {:.4}", model.rmse(&x_test, &y_test));
    println!("phase breakdown    : {}", r.timing.summary());

    // 4. ASCII posterior sketch over x ∈ [-2, 2]
    let grid = Mat::from_fn(61, 1, |i, _| -2.0 + 4.0 * i as f64 / 60.0);
    let (mean, _) = model.predict(&grid);
    let (lo, hi) = mean.as_slice().iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &v| (l.min(v), h.max(v)));
    println!("\nposterior mean over [-2, 2]:");
    for row in (0..12).rev() {
        let level = lo + (hi - lo) * (row as f64 + 0.5) / 12.0;
        let band = (hi - lo) / 12.0;
        let line: String = (0..61)
            .map(|i| if (mean[(i, 0)] - level).abs() < band * 0.5 { '*' } else { ' ' })
            .collect();
        println!("  {level:+6.2} |{line}");
    }
    Ok(())
}
