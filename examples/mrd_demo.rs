//! MRD demo: two observation views sharing a latent space.
//!
//!   cargo run --release --example mrd_demo
//!
//! Builds two 4-D views driven by one shared 1-D signal plus one private
//! signal each, fits MRD with a Q=3 shared latent space, and prints the
//! per-view ARD relevance profile — the MRD signature is that one latent
//! dimension is relevant to both views (the shared signal) while others
//! specialise.

use anyhow::Result;
use gpparallel::cli::Args;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::{EngineConfig, OptChoice};
use gpparallel::data::rng::Rng64;
use gpparallel::linalg::Mat;
use gpparallel::models::Mrd;
use gpparallel::optim::Lbfgs;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let backend = BackendKind::parse(args.get("backend").unwrap_or("cpu"))
        .expect("--backend cpu|xla");
    let iters: usize = args.get_parse("iters", 120)?;
    let n: usize = args.get_parse("n", 256)?;

    // ground truth: shared signal t, private signals p1, p2
    let mut rng = Rng64::new(7);
    let t: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let p1: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let p2: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    let view = |sig: &[f64], priv_sig: &[f64], rng: &mut Rng64| {
        Mat::from_fn(n, 4, |i, j| {
            let wsh = [1.0, 0.6, -0.8, 0.3][j];
            let wpr = [0.4, -0.7, 0.5, 0.9][j];
            (wsh * sig[i]).sin() + wpr * priv_sig[i] * 0.7 + 0.05 * rng.normal()
        })
    };
    let y1 = view(&t, &p1, &mut rng);
    let y2 = view(&t, &p2, &mut rng);

    println!("== MRD: two 4-D views, shared 1-D + private 1-D signals, Q=3 ==");
    let cfg = EngineConfig {
        workers: 2,
        chunk: 256,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: iters, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let model = Mrd::fit(&[y1, y2], 3, 20, &["mrd", "mrd"], cfg, 7)?;
    let r = &model.result;

    println!("final bound     : {:.2}", r.f);
    println!("bound improved  : {:+.2}",
             r.trace.last().unwrap() - r.trace.first().unwrap());
    println!("iterations      : {}", r.iterations);
    println!("timing          : {}", r.timing.summary());

    println!("\nARD relevance (1/lengthscale², normalised per view):");
    println!("{:>8} {:>10} {:>10} {:>10}", "view", "dim 1", "dim 2", "dim 3");
    for (v, rel) in model.relevance().iter().enumerate() {
        println!("{:>8} {:>10.3} {:>10.3} {:>10.3}", v, rel[0], rel[1], rel[2]);
    }
    println!("\n(a dimension relevant in BOTH rows encodes the shared signal;");
    println!(" view-specific dimensions encode the private signals)");
    Ok(())
}
