//! End-to-end driver — the paper's §4 experiment, run for real.
//!
//!   cargo run --release --example bgplvm_synthetic -- \
//!       [--n 2000] [--iters 300] [--workers 2] [--backend cpu|xla]
//!
//! Generates the paper's synthetic dataset (1-D latents mapped into 3-D
//! by sampling an RBF-kernel GP), fits a Bayesian GP-LVM with M = 100
//! inducing points through the full distributed stack, logs the bound
//! curve to results/bgplvm_curve.csv, and reports the latent-recovery
//! quality plus the phase/communication accounting. The run is recorded
//! in EXPERIMENTS.md §E2E.

use anyhow::Result;
use gpparallel::cli::Args;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::{EngineConfig, OptChoice};
use gpparallel::data::csv::write_matrix;
use gpparallel::data::synthetic::{generate, SyntheticSpec};
use gpparallel::linalg::Mat;
use gpparallel::models::BayesianGplvm;
use gpparallel::optim::Lbfgs;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let n: usize = args.get_parse("n", 2000)?;
    let iters: usize = args.get_parse("iters", 300)?;
    let workers: usize = args.get_parse("workers", 2)?;
    let seed: u64 = args.get_parse("seed", 0)?;
    let backend = BackendKind::parse(args.get("backend").unwrap_or("cpu"))
        .expect("--backend cpu|xla");

    // The paper's dataset: 1-D latent, 3-D observations via an RBF GP.
    let spec = SyntheticSpec { n, q: 1, d: 3, noise: 1e-2, ..Default::default() };
    let ds = generate(&spec, seed);
    println!("== Bayesian GP-LVM on the paper's synthetic task ==");
    println!("N={n}  D=3  Q=1  M=100  backend={}  workers={workers}", backend.name());

    let cfg = EngineConfig {
        workers,
        chunk: 1024,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: iters, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let t0 = std::time::Instant::now();
    let model = BayesianGplvm::fit(&ds.y(), 1, 100, "paper", cfg, seed)?;
    let wall = t0.elapsed().as_secs_f64();
    let r = &model.result;

    // loss curve -> CSV
    std::fs::create_dir_all("results")?;
    let curve = Mat::from_fn(r.trace.len(), 2, |i, j| {
        if j == 0 { i as f64 } else { r.trace[i] }
    });
    write_matrix(Path::new("results/bgplvm_curve.csv"), &curve,
                 Some(&["iteration", "bound"]))?;

    println!("\nfinal bound          : {:.2}", r.f);
    println!("bound improvement    : {:+.2}",
             r.trace.last().unwrap() - r.trace.first().unwrap());
    println!("iterations / evals   : {} / {}", r.iterations, r.evaluations);
    println!("wall time            : {wall:.1}s  ({:.3}s per eval)", r.sec_per_eval);
    println!("projected (1 core/rank): {:.3}s per eval", r.projected_sec_per_eval());
    println!("indistributable time : {:.2}%",
             r.timing.indistributable_fraction() * 100.0);
    println!("communication        : {} messages, {:.2} MiB",
             r.messages_sent, r.bytes_sent as f64 / (1024.0 * 1024.0));
    let align = model.latent_alignment(ds.latent_truth().unwrap());
    println!("latent alignment     : |corr(mu, truth)| = {align:.4}");
    println!("\nloss curve written to results/bgplvm_curve.csv");

    // sample of the curve for the log
    println!("\nbound curve (sampled):");
    let k = r.trace.len();
    for i in [0, k / 8, k / 4, k / 2, 3 * k / 4, k - 1] {
        println!("  iter {:4}: {:.2}", i, r.trace[i]);
    }
    Ok(())
}
