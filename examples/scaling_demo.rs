//! Scaling demo: a small interactive slice of Fig 1a.
//!
//!   cargo run --release --example scaling_demo [-- --backend xla]
//!
//! Times one full optimisation iteration (stats fwd + reduce + M×M core
//! + vjp + gradient collection) of the Bayesian GP-LVM for a few dataset
//! sizes and worker counts, and prints the paper-style table. The full
//! sweep lives in `cargo bench --bench fig1a_scaling`.

use anyhow::Result;
use gpparallel::cli::Args;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::synthetic::{generate, SyntheticSpec};
use gpparallel::models::BayesianGplvm;
use gpparallel::optim::Lbfgs;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let backend = BackendKind::parse(args.get("backend").unwrap_or("cpu"))
        .expect("--backend cpu|xla");
    let evals: usize = args.get_parse("evals", 2)?;

    println!("== scaling demo (backend={}, M=100, Q=1, D=3) ==", backend.name());
    println!("{:>6} {:>8} {:>14} {:>16} {:>9}",
             "N", "workers", "wall s/iter", "projected s/iter", "indist %");

    for &n in &[1024usize, 2048, 4096] {
        let spec = SyntheticSpec { n, q: 1, d: 3, ..Default::default() };
        let ds = generate(&spec, 0);
        for &workers in &[1usize, 2, 4] {
            let problem = BayesianGplvm::problem(&ds.y, 1, 100, "paper", 0);
            let cfg = EngineConfig {
                workers,
                chunk: 1024,
                backend,
                artifacts_dir: "artifacts".into(),
                opt: OptChoice::Lbfgs(Lbfgs::default()),
                pipeline: true,
                verbose: false,
            };
            let engine = Engine::new(problem, cfg)?;
            let r = engine.time_iterations(evals)?;
            println!("{:>6} {:>8} {:>14.4} {:>16.4} {:>9.2}",
                     n, workers, r.sec_per_eval, r.projected_sec_per_eval(),
                     r.timing.indistributable_fraction() * 100.0);
        }
    }
    println!("\n(single-core host: wall-clock is flat in workers; the projected");
    println!(" column divides the distributable work across ranks — see DESIGN.md)");
    Ok(())
}
