//! Scaling demo: a small interactive slice of Fig 1a, plus the serving
//! fan-out.
//!
//!   cargo run --release --example scaling_demo [-- --backend parallel]
//!
//! Part 1 times one full optimisation iteration (stats fwd + reduce +
//! M×M core + vjp + gradient collection) of the Bayesian GP-LVM for a
//! few dataset sizes and worker counts and prints the paper-style table
//! (the full sweep lives in `cargo bench --bench fig1a_scaling`).
//!
//! Part 2 fits a sparse GP regressor once, then serves the same
//! posterior through the sharded serving subsystem at several cluster
//! sizes — the posterior is broadcast once, each prediction batch is
//! partitioned over the ranks, and the assembled result is checked
//! bit-identical against the single-node posterior.
//!
//! Part 3 re-serves the same batches as a **batch stream**
//! (`predict_stream`): batch k+1's announcement and shard sends overlap
//! batch k's gather, so the serving ranks never idle for the leader's
//! round-trip — the streamed outputs are checked bit-identical to the
//! sequential ones (streaming is a protocol reordering, not a different
//! computation).
//!
//! Part 4 hot-swaps the served posterior mid-session: a second core
//! (same fit, different noise precision) is `rebroadcast` without
//! tearing the session down, and the post-swap batch is checked
//! bit-identical against the single-node posterior of the *new* core.
//!
//! Part 5 puts the concurrent-client front-end in front of the same
//! cluster: 1 vs 8 closed-loop clients issuing single-row requests
//! through the micro-batching scheduler, printing throughput and
//! latency quantiles against the sequential one-row-per-round baseline
//! (coalescing amortises the leader's per-round trip across requests).
//!
//! Part 6 is the out-of-core capstone: a synthetic supervised dataset is
//! **generated straight to an on-disk chunk store** (never resident),
//! then SGPR trains from it at several worker counts with every rank
//! streaming its chunks through a two-slot window — the Fig-1a-style
//! table reports wall s/iter, the per-rank streamed working set (O(chunk),
//! independent of N/P) and the process peak RSS. `--part6-n 1000000`
//! runs it at paper scale; the default keeps the demo interactive.

use anyhow::Result;
use gpparallel::cli::Args;
use gpparallel::collectives::Cluster;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use gpparallel::coordinator::{make_backends, Engine, EngineConfig, FrontendConfig,
                              OptChoice, ServingFrontend};
use gpparallel::data::store::{ChunkSource, FileStore};
use gpparallel::data::synthetic::{generate, generate_supervised,
                                  generate_supervised_to_store, SyntheticSpec};
use gpparallel::linalg::Mat;
use gpparallel::math::predict::PosteriorCore;
use gpparallel::math::stats::sgpr_stats_fwd_chunked;
use gpparallel::models::{BayesianGplvm, Posterior, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Process peak resident set (VmHWM) in MB, if the platform exposes it.
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb / 1024.0);
        }
    }
    None
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let backend = BackendKind::parse(args.get("backend").unwrap_or("cpu"))
        .expect("--backend cpu|xla");
    let evals: usize = args.get_parse("evals", 2)?;

    println!("== scaling demo (backend={}, M=100, Q=1, D=3) ==", backend.name());
    println!("{:>6} {:>8} {:>14} {:>16} {:>9}",
             "N", "workers", "wall s/iter", "projected s/iter", "indist %");

    for &n in &[1024usize, 2048, 4096] {
        let spec = SyntheticSpec { n, q: 1, d: 3, ..Default::default() };
        let ds = generate(&spec, 0);
        for &workers in &[1usize, 2, 4] {
            let problem = BayesianGplvm::problem(&ds.y(), 1, 100, "paper", 0);
            let cfg = EngineConfig {
                workers,
                chunk: 1024,
                backend,
                artifacts_dir: "artifacts".into(),
                opt: OptChoice::Lbfgs(Lbfgs::default()),
                pipeline: true,
                verbose: false,
                simd: None,
            };
            let engine = Engine::new(problem, cfg)?;
            let r = engine.time_iterations(evals)?;
            println!("{:>6} {:>8} {:>14.4} {:>16.4} {:>9.2}",
                     n, workers, r.sec_per_eval, r.projected_sec_per_eval(),
                     r.timing.indistributable_fraction() * 100.0);
        }
    }
    println!("\n(single-core host: wall-clock is flat in workers; the projected");
    println!(" column divides the distributable work across ranks — see DESIGN.md)");

    // ---------------------------------------------------------------
    // sharded serving: one posterior, prediction batches fanned out
    // ---------------------------------------------------------------
    let (n, nt, batches, rows_per_chunk) = (2048usize, 2048usize, 4usize, 256usize);
    println!("\n== sharded serving (SGPR, N={n}, Nt={nt}, {batches} batches, \
              chunk={rows_per_chunk}) ==");

    let spec = SyntheticSpec { n, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 1);
    let x = ds.x().unwrap();
    let fit_cfg = EngineConfig {
        workers: 1,
        chunk: 1024,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 15, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let model = SparseGpRegression::fit(&x, &ds.y(), 48, "paper", fit_cfg, 1)?;
    let core = model.posterior().core().clone();
    let xstar = Mat::from_fn(nt, 1, |i, _| -2.5 + 5.0 * i as f64 / (nt - 1) as f64);
    let (single_mean, single_var) = model.predict(&xstar);

    println!("{:>8} {:>14} {:>14} {:>12}",
             "workers", "s/batch", "rows/s", "max |Δ| vs 1-node");
    for workers in [1usize, 2, 4] {
        let (core_ref, xs) = (&core, &xstar);
        let results = Cluster::run(workers, move |mut comm| {
            let (mut backends, _rt) = make_backends(backend, &["paper".to_string()],
                                                    std::path::Path::new("artifacts"))
                .expect("backend construction");
            let be = backends[0].as_mut();
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(),
                                                          rows_per_chunk, &mut comm)
                    .expect("leader");
                let mut mean = Mat::zeros(0, 0);
                let mut var = Vec::new();
                let mut elapsed = Duration::ZERO;
                for _ in 0..batches {
                    let t0 = Instant::now();
                    dp.predict_into(&mut comm, be, xs, &mut mean, &mut var)
                        .expect("sharded predict");
                    elapsed += t0.elapsed();
                }
                dp.finish(&mut comm).expect("finish");
                Some((mean, var, elapsed.as_secs_f64() / batches as f64))
            } else {
                worker_serve(&mut comm, be).expect("serve");
                None
            }
        });
        let (mean, var, sec) = results[0].as_ref().expect("leader result");
        let mut dv = 0.0f64;
        for (a, b) in var.iter().zip(&single_var) {
            dv = dv.max((a - b).abs());
        }
        let max_diff = mean.max_abs_diff(&single_mean).max(dv);
        println!("{:>8} {:>14.5} {:>14.0} {:>12.1e}",
                 workers, sec, nt as f64 / sec, max_diff);
    }
    println!("(serving is bit-identical across cluster sizes: |Δ| must print 0.0e0)");

    // ---------------------------------------------------------------
    // batch streams: the same batches, sequential vs streamed protocol
    // (batch k+1's announcement + shard sends overlap batch k's gather)
    // ---------------------------------------------------------------
    println!("\n== batch streams: {batches} × {nt}-row batches, sequential vs streamed ==");
    let stream_in: Vec<Mat> = (0..batches).map(|_| xstar.clone()).collect();
    println!("{:>8} {:>14} {:>14} {:>8} {:>12}",
             "workers", "seq s/batch", "stream s/batch", "ratio", "max |Δ|");
    for workers in [2usize, 4] {
        let (core_ref, bs) = (&core, &stream_in);
        let results = Cluster::run(workers, move |mut comm| {
            let (mut backends, _rt) = make_backends(backend, &["paper".to_string()],
                                                    std::path::Path::new("artifacts"))
                .expect("backend construction");
            let be = backends[0].as_mut();
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(),
                                                          rows_per_chunk, &mut comm)
                    .expect("leader");
                let mut mean = Mat::zeros(0, 0);
                let mut var = Vec::new();
                // warm the partition + scratch, then time both protocols
                dp.predict_into(&mut comm, be, &bs[0], &mut mean, &mut var)
                    .expect("warmup");
                let t0 = Instant::now();
                for b in bs.iter() {
                    dp.predict_into(&mut comm, be, b, &mut mean, &mut var)
                        .expect("sequential batch");
                }
                let t_seq = t0.elapsed().as_secs_f64() / bs.len() as f64;
                let t0 = Instant::now();
                let outs = dp.predict_stream(&mut comm, be, bs).expect("streamed run");
                let t_stream = t0.elapsed().as_secs_f64() / bs.len() as f64;
                dp.finish(&mut comm).expect("finish");
                Some((outs, t_seq, t_stream, mean, var))
            } else {
                worker_serve(&mut comm, be).expect("serve");
                None
            }
        });
        let (outs, t_seq, t_stream, seq_mean, seq_var) =
            results[0].as_ref().expect("leader result");
        // streamed output must equal the sequential output bit for bit
        let mut dmax = 0.0f64;
        for (m, v) in outs {
            dmax = dmax.max(m.max_abs_diff(seq_mean));
            for (a, b) in v.iter().zip(seq_var) {
                dmax = dmax.max((a - b).abs());
            }
        }
        println!("{:>8} {:>14.5} {:>14.5} {:>8.2} {:>12.1e}",
                 workers, t_seq, t_stream, t_seq / t_stream, dmax);
    }
    println!("(streaming is a protocol reordering: |Δ| must print 0.0e0)");

    // ---------------------------------------------------------------
    // posterior hot-swap: rebroadcast a new core mid-session
    // ---------------------------------------------------------------
    println!("\n== posterior hot-swap (same session, β′ = 2β) ==");
    // a second posterior at the fitted kernel/Z but doubled noise
    // precision, built from the serial chunked statistics (the same
    // summation discipline the engine's distributed STATS pass pins)
    let fitted = &model.result.fitted;
    let w = vec![1.0; x.rows()];
    let st = sgpr_stats_fwd_chunked(&fitted.kerns[0], &x, &w, &ds.y(), &fitted.zs[0], 1024);
    let core_b = PosteriorCore::new(fitted.kerns[0].clone(), fitted.zs[0].clone(),
                                    2.0 * fitted.betas[0], &st)?;
    let (swap_mean, swap_var) = Posterior::from_core(core_b.clone()).predict(&xstar);

    println!("{:>8} {:>16} {:>16}", "workers", "pre-swap |Δ|", "post-swap |Δ|");
    for workers in [2usize, 4] {
        let (ca, cb, xs) = (&core, &core_b, &xstar);
        let results = Cluster::run(workers, move |mut comm| {
            let (mut backends, _rt) = make_backends(backend, &["paper".to_string()],
                                                    std::path::Path::new("artifacts"))
                .expect("backend construction");
            let be = backends[0].as_mut();
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(ca.clone(), rows_per_chunk,
                                                          &mut comm)
                    .expect("leader");
                let before = dp.predict(&mut comm, be, xs).expect("pre-swap batch");
                dp.rebroadcast(cb.clone(), &mut comm).expect("swap");
                let after = dp.predict(&mut comm, be, xs).expect("post-swap batch");
                dp.finish(&mut comm).expect("finish");
                Some((before, after))
            } else {
                worker_serve(&mut comm, be).expect("serve");
                None
            }
        });
        let (before, after) = results[0].as_ref().expect("leader result");
        let mut dv_before = 0.0f64;
        for (a, b) in before.1.iter().zip(&single_var) {
            dv_before = dv_before.max((a - b).abs());
        }
        let d_before = before.0.max_abs_diff(&single_mean).max(dv_before);
        let mut dv_after = 0.0f64;
        for (a, b) in after.1.iter().zip(&swap_var) {
            dv_after = dv_after.max((a - b).abs());
        }
        let d_after = after.0.max_abs_diff(&swap_mean).max(dv_after);
        println!("{:>8} {:>16.1e} {:>16.1e}", workers, d_before, d_after);
    }
    println!("(both columns must print 0.0e0: the swap is exact and atomic)");

    // ---------------------------------------------------------------
    // concurrent-client front-end: micro-batched single-row requests
    // ---------------------------------------------------------------
    let (k_req, fe_workers, fe_rpc) = (64usize, 2usize, 16usize);
    println!("\n== serving front-end ({fe_workers} workers, {k_req} single-row \
              requests per client) ==");

    // sequential baseline: one caller, one cluster round per row
    let (core_ref, xs) = (&core, &xstar);
    let results = Cluster::run(fe_workers, move |mut comm| {
        let (mut backends, _rt) = make_backends(backend, &["paper".to_string()],
                                                std::path::Path::new("artifacts"))
            .expect("backend construction");
        let be = backends[0].as_mut();
        if comm.rank() == 0 {
            let mut dp = DistributedPosterior::leader(core_ref.clone(), fe_rpc,
                                                      &mut comm)
                .expect("leader");
            let mut mean = Mat::zeros(0, 0);
            let mut var = Vec::new();
            let row = Mat::from_fn(1, 1, |_, _| xs[(0, 0)]);
            dp.predict_into(&mut comm, be, &row, &mut mean, &mut var)
                .expect("warmup");
            let t0 = Instant::now();
            for i in 0..k_req {
                let row = Mat::from_fn(1, 1, |_, _| xs[(i % xs.rows(), 0)]);
                dp.predict_into(&mut comm, be, &row, &mut mean, &mut var)
                    .expect("sequential request");
            }
            let t = t0.elapsed().as_secs_f64() / k_req as f64;
            dp.finish(&mut comm).expect("finish");
            Some(t)
        } else {
            worker_serve(&mut comm, be).expect("serve");
            None
        }
    });
    let t_seq = results.into_iter().next().unwrap().expect("leader result");
    println!("sequential baseline: {:>8.0} rows/s ({:.0} µs/request)",
             1.0 / t_seq, t_seq * 1e6);

    println!("{:>8} {:>12} {:>12} {:>12} {:>10}",
             "clients", "rows/s", "p50 µs", "p99 µs", "batch fill");
    let mut rps_8 = 0.0f64;
    for clients in [1usize, 8] {
        let (core_ref, xs) = (&core, &xstar);
        let results = Cluster::run(fe_workers, move |mut comm| {
            let (mut backends, _rt) = make_backends(backend, &["paper".to_string()],
                                                    std::path::Path::new("artifacts"))
                .expect("backend construction");
            let be = backends[0].as_mut();
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), fe_rpc,
                                                          &mut comm)
                    .expect("leader");
                let fe = ServingFrontend::new(
                    FrontendConfig {
                        max_batch_rows: 32,
                        max_wait: Duration::from_micros(50),
                        queue_rows: 1024,
                        dump_every: None,
                    },
                    1, 2);
                let t0 = Instant::now();
                let report = std::thread::scope(|s| {
                    let hands: Vec<_> = (0..clients)
                        .map(|c| {
                            let h = fe.handle();
                            s.spawn(move || {
                                for i in 0..k_req {
                                    let idx = (c * k_req + i) % xs.rows();
                                    let row = Mat::from_fn(1, 1, |_, _| xs[(idx, 0)]);
                                    h.predict(row).expect("front-end request");
                                }
                            })
                        })
                        .collect();
                    let closer = {
                        let h = fe.handle();
                        s.spawn(move || {
                            for jh in hands {
                                jh.join().unwrap();
                            }
                            h.close();
                        })
                    };
                    let report = fe.run(&mut dp, &mut comm, be);
                    closer.join().unwrap();
                    report
                });
                let wall = t0.elapsed().as_secs_f64();
                dp.finish(&mut comm).expect("finish");
                Some((report, wall))
            } else {
                worker_serve(&mut comm, be).expect("serve");
                None
            }
        });
        let (report, wall) = results.into_iter().next().unwrap().expect("leader result");
        let rps = (clients * k_req) as f64 / wall;
        if clients == 8 {
            rps_8 = rps;
        }
        println!("{:>8} {:>12.0} {:>12.1} {:>12.1} {:>10.2}",
                 clients, rps, report.snapshot.latency_p50_us,
                 report.snapshot.latency_p99_us, report.snapshot.batch_fill);
    }
    println!("(8 clients vs sequential: {:.1}x throughput — coalescing amortises",
             rps_8 * t_seq);
    println!(" the leader's per-round trip across concurrent requests)");

    // ---------------------------------------------------------------
    // Part 6: out-of-core — train straight from an on-disk chunk store
    // ---------------------------------------------------------------
    let n6: usize = args.get_parse("part6-n", 65_536)?;
    let chunk6: usize = args.get_parse("part6-chunk", 4096)?;
    let m6 = 64usize;
    println!("\n== out-of-core: streamed SGPR from an on-disk store \
              (N={n6}, chunk_rows={chunk6}, M={m6}) ==");
    println!("(--part6-n 1000000 runs it at paper scale; generation and training");
    println!(" both stream, so the matrices are never resident)");

    let dir = std::env::temp_dir().join(format!("gpparallel_scaling_store_{}",
                                                std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec6 = SyntheticSpec { n: n6, q: 1, d: 3, ..Default::default() };
    let t0 = Instant::now();
    let man6 = generate_supervised_to_store(&spec6, 11, &dir, chunk6)?;
    println!("generated {} chunks ({} rows, {:.1} MB on disk) in {:.2} s",
             man6.num_chunks(), man6.n,
             (man6.n * (man6.q + man6.d) * 8) as f64 / (1024.0 * 1024.0),
             t0.elapsed().as_secs_f64());
    // the streamed working set per rank: a double-buffered window of two
    // chunk slots (x block + y block + row weights), independent of N/P
    let slot_bytes = (chunk6 * (man6.q + man6.d) + chunk6) * 8;
    let store6: Arc<dyn ChunkSource> = Arc::new(FileStore::open(&dir)?);

    println!("{:>8} {:>14} {:>16} {:>14} {:>12}",
             "workers", "wall s/iter", "projected s/iter", "rank set KB", "peak RSS MB");
    for workers in [1usize, 2, 4] {
        let problem = SparseGpRegression::problem_from_store(&store6, m6, "paper", 11)?;
        let cfg = EngineConfig {
            workers,
            chunk: chunk6,
            backend,
            artifacts_dir: "artifacts".into(),
            opt: OptChoice::Lbfgs(Lbfgs::default()),
            pipeline: true,
            verbose: false,
            simd: None,
        };
        let r = Engine::new(problem, cfg)?.time_iterations(1)?;
        let rss = peak_rss_mb().map_or_else(|| "n/a".to_string(), |v| format!("{v:.0}"));
        println!("{:>8} {:>14.4} {:>16.4} {:>14.0} {:>12}",
                 workers, r.sec_per_eval, r.projected_sec_per_eval(),
                 (2 * slot_bytes) as f64 / 1024.0, rss);
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!("(every rank's streamed window is two chunk slots regardless of N/P;");
    println!(" peak RSS is process-wide and includes the leader's M×M core work)");
    Ok(())
}
