//! The five invariant rules and the wire-tag registry model.
//!
//! Every rule is lexical (see [`crate::lexer`]) and every rule has the
//! same escape hatch: a `// lint: allow(<rule>)` comment on the flagged
//! line or anywhere in the contiguous comment/attribute block directly
//! above it. The escape is deliberately noisy in review — the comment
//! must sit at the use site, next to the justification prose.

use crate::lexer::{lex, test_regions, Line};

/// Rule: every `unsafe` token carries a `SAFETY` comment.
pub const RULE_UNSAFE: &str = "unsafe-safety";
/// Rule: wire tags/verbs live in `collectives::protocol`, once, and call
/// sites never pass raw numeric tags.
pub const RULE_WIRE: &str = "wire-registry";
/// Rule: functions annotated `// lint: no-alloc` stay allocation-free.
pub const RULE_ALLOC: &str = "no-alloc-hot-path";
/// Rule: no `.unwrap()` / `.expect(` in the protocol layers.
pub const RULE_UNWRAP: &str = "no-unwrap-protocol";
/// Rule: every `Ordering::Relaxed` states why relaxed suffices.
pub const RULE_RELAXED: &str = "relaxed-ordering-justified";

/// One finding, addressed `path:line` (1-based) for editor jumping.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Repo-relative path of the offending file.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (one of the `RULE_*` constants).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

/// The parsed wire vocabulary of `collectives::protocol`.
#[derive(Debug, Default)]
pub struct Registry {
    /// `TAG_*` message tags (`u64`).
    pub tags: Vec<(String, u64)>,
    /// `CMD_*` / `SRV_*` command verbs (`f64`).
    pub verbs: Vec<(String, f64)>,
}

/// Evaluate a `u64` registry initialiser: a decimal literal (with `_`
/// separators), `u64::MAX`, or `u64::MAX - <k>`.
fn parse_u64_expr(v: &str) -> Option<u64> {
    let v = v.trim();
    if let Some(rest) = v.strip_prefix("u64::MAX") {
        let rest = rest.trim();
        if rest.is_empty() {
            return Some(u64::MAX);
        }
        let k: u64 = rest.strip_prefix('-')?.trim().replace('_', "").parse().ok()?;
        return u64::MAX.checked_sub(k);
    }
    v.replace('_', "").parse().ok()
}

/// Parse the registry file and check its internal invariants: every tag
/// value unique across all tags, every verb value unique within its
/// prefix group (`CMD_*` and `SRV_*` ride different wire contexts, so
/// `CMD_STOP = 0.0` and `SRV_DONE = 0.0` may coexist).
pub fn parse_registry(path: &str, src: &str) -> (Registry, Vec<Diagnostic>) {
    let lines = lex(src);
    let region = test_regions(&lines);
    let mut reg = Registry::default();
    let mut tag_lines: Vec<usize> = Vec::new();
    let mut verb_lines: Vec<usize> = Vec::new();
    let mut diags = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if region[i] {
            continue;
        }
        let t = line.code.trim();
        let Some(rest) = t
            .strip_prefix("pub const ")
            .or_else(|| t.strip_prefix("const "))
        else {
            continue;
        };
        let Some((name, tail)) = rest.split_once(':') else {
            continue;
        };
        let Some((ty, val)) = tail.split_once('=') else {
            continue;
        };
        let (name, ty) = (name.trim(), ty.trim());
        let val = val.trim().trim_end_matches(';').trim();
        match ty {
            "u64" => match parse_u64_expr(val) {
                Some(v) => {
                    reg.tags.push((name.to_string(), v));
                    tag_lines.push(i + 1);
                }
                None => diags.push(Diagnostic {
                    path: path.to_string(),
                    line: i + 1,
                    rule: RULE_WIRE,
                    message: format!("cannot evaluate tag initialiser `{val}` for `{name}`"),
                }),
            },
            "f64" => match val.parse::<f64>() {
                Ok(v) => {
                    reg.verbs.push((name.to_string(), v));
                    verb_lines.push(i + 1);
                }
                Err(_) => diags.push(Diagnostic {
                    path: path.to_string(),
                    line: i + 1,
                    rule: RULE_WIRE,
                    message: format!("cannot evaluate verb initialiser `{val}` for `{name}`"),
                }),
            },
            _ => {}
        }
    }
    for (j, (name, v)) in reg.tags.iter().enumerate() {
        if let Some(k) = reg.tags[..j].iter().position(|(_, w)| w == v) {
            diags.push(Diagnostic {
                path: path.to_string(),
                line: tag_lines[j],
                rule: RULE_WIRE,
                message: format!(
                    "tag `{name}` reuses value {v} already assigned to `{}`",
                    reg.tags[k].0
                ),
            });
        }
    }
    for (j, (name, v)) in reg.verbs.iter().enumerate() {
        let prefix = |n: &str| n.split('_').next().unwrap_or("").to_string();
        let pj = prefix(name);
        if let Some(k) = reg.verbs[..j]
            .iter()
            .position(|(n, w)| w.to_bits() == v.to_bits() && prefix(n) == pj)
        {
            diags.push(Diagnostic {
                path: path.to_string(),
                line: verb_lines[j],
                rule: RULE_WIRE,
                message: format!(
                    "verb `{name}` reuses value {v} already assigned to `{}`",
                    reg.verbs[k].0
                ),
            });
        }
    }
    (reg, diags)
}

/// True if `code` contains `tok` as a standalone token (not a substring
/// of a longer identifier).
fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(p) = code[start..].find(tok) {
        let at = start + p;
        let end = at + tok.len();
        let ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
        let before_ok = at == 0 || !ident(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !ident(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Collect the comment text of the contiguous comment/attribute block
/// directly above line `i`. Attribute-only lines (`#[...]`) are skipped
/// without breaking contiguity — a `// SAFETY preconditions` block above
/// a `#[target_feature(...)]` attribute still governs the `unsafe fn`
/// below it. A blank line or a code line severs the block.
fn preceding_block(lines: &[Line], i: usize) -> String {
    let mut out = String::new();
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        let is_attr = code.starts_with("#[") || code.starts_with("#![");
        if code.is_empty() && l.comment.is_empty() {
            break;
        }
        if code.is_empty() || is_attr {
            out.push_str(&l.comment);
            out.push('\n');
        } else {
            break;
        }
    }
    out
}

/// The escape hatch: `lint: allow(<rule>)` on the flagged line or in the
/// preceding comment/attribute block.
fn allowed(lines: &[Line], i: usize, rule: &str) -> bool {
    let esc = format!("lint: allow({rule})");
    lines[i].comment.contains(&esc) || preceding_block(lines, i).contains(&esc)
}

/// A justification comment for line `i`: same-line comment or preceding
/// block containing `needle` (matched case-insensitively when
/// `ci` is set).
fn justified(lines: &[Line], i: usize, needle: &str, ci: bool) -> bool {
    let hit = |text: &str| {
        if ci {
            text.to_lowercase().contains(&needle.to_lowercase())
        } else {
            text.contains(needle)
        }
    };
    hit(&lines[i].comment) || hit(&preceding_block(lines, i))
}

/// Split the argument list of a call whose `(` sits at byte `open` in
/// `s`. Returns the top-level comma-separated arguments, or `None` if
/// the call never closes (malformed source). Nested `()[]{}` groups are
/// tracked with one depth counter — string contents were elided by the
/// lexer, so stray brackets inside literals cannot occur.
fn call_args(s: &str, open: usize) -> Option<Vec<String>> {
    let mut depth = 1i64;
    let mut args = vec![String::new()];
    for c in s[open + 1..].chars() {
        match c {
            '(' | '[' | '{' => {
                depth += 1;
                args.last_mut().unwrap().push(c);
            }
            ')' | ']' | '}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(args);
                }
                args.last_mut().unwrap().push(c);
            }
            ',' if depth == 1 => args.push(String::new()),
            _ => args.last_mut().unwrap().push(c),
        }
    }
    None
}

/// Tokens denied inside a `// lint: no-alloc` function body.
const ALLOC_TOKENS: &[&str] = &["Vec::new", "vec!", ".to_vec(", ".clone(", "Box::new"];

/// Lint one file. `path` is the repo-relative label — it drives the
/// scoping decisions (`collectives/` + `coordinator/engine/` for the
/// unwrap rule, `tests/`/`benches/` vs `src/` for test-region
/// exemptions, `collectives/protocol.rs` as the one sanctioned home for
/// wire constants).
pub fn lint_file(path: &str, src: &str) -> Vec<Diagnostic> {
    let lines = lex(src);
    let region = test_regions(&lines);
    let is_protocol = path.ends_with("collectives/protocol.rs");
    // In `src/`, unit-test modules may improvise tags and unwrap freely;
    // integration tests and benches put real traffic on the wire, so the
    // wire rule holds there even inside `#[cfg(test)]`.
    let src_unit_tests = !path.contains("tests/") && !path.contains("benches/");
    let in_protocol_scope =
        path.contains("collectives/") || path.contains("coordinator/engine/");
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut push = |line: usize, rule: &'static str, message: String| {
        diags.push(Diagnostic {
            path: path.to_string(),
            line: line + 1,
            rule,
            message,
        });
    };

    for (i, line) in lines.iter().enumerate() {
        let code = &line.code;

        // --- unsafe-safety: applies everywhere, tests included -------
        if has_token(code, "unsafe")
            && !justified(&lines, i, "SAFETY", false)
            && !allowed(&lines, i, RULE_UNSAFE)
        {
            push(
                i,
                RULE_UNSAFE,
                "`unsafe` without a `// SAFETY:` comment stating the upheld invariants".into(),
            );
        }

        // --- wire-registry (declarations outside the registry) -------
        if !is_protocol
            && !(src_unit_tests && region[i])
            && ["const TAG_", "const CMD_", "const SRV_"]
                .iter()
                .any(|p| code.contains(p))
            && !allowed(&lines, i, RULE_WIRE)
        {
            push(
                i,
                RULE_WIRE,
                "wire tag/verb constant declared outside `collectives::protocol` \
                 (the registry is the single point of uniqueness)"
                    .into(),
            );
        }

        // --- no-unwrap-protocol --------------------------------------
        if in_protocol_scope
            && !region[i]
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(&lines, i, RULE_UNWRAP)
        {
            push(
                i,
                RULE_UNWRAP,
                "`.unwrap()`/`.expect(` in the protocol layers; surface the error \
                 (`ok_or_else` + `?`) or take the poison-tolerant lock path"
                    .into(),
            );
        }

        // --- relaxed-ordering-justified ------------------------------
        if !region[i]
            && has_token(code, "Relaxed")
            && !code.trim_start().starts_with("use ")
            && !justified(&lines, i, "relaxed", true)
            && !allowed(&lines, i, RULE_RELAXED)
        {
            push(
                i,
                RULE_RELAXED,
                "`Ordering::Relaxed` without a comment justifying why relaxed \
                 ordering suffices at this site"
                    .into(),
            );
        }
    }

    // --- wire-registry (raw numeric tags at send/recv sites) ---------
    // The call may span lines, so scan the concatenated code halves and
    // map byte offsets back to lines.
    let mut joined = String::new();
    let mut starts = Vec::with_capacity(lines.len());
    for line in &lines {
        starts.push(joined.len());
        joined.push_str(&line.code);
        joined.push('\n');
    }
    let line_of = |pos: usize| starts.partition_point(|&s| s <= pos) - 1;
    for pat in [".send(", ".recv("] {
        let mut from = 0;
        while let Some(p) = joined[from..].find(pat) {
            let at = from + p;
            from = at + 1;
            let i = line_of(at);
            if is_protocol || (src_unit_tests && region[i]) {
                continue;
            }
            let Some(args) = call_args(&joined, at + pat.len() - 1) else {
                continue;
            };
            // Single-argument sends (mpsc channels) carry no tag; the
            // wire tag is always the second argument of a transport or
            // collective send/recv.
            if args.len() < 2 {
                continue;
            }
            let tag = args[1].trim();
            if tag.starts_with(|c: char| c.is_ascii_digit()) && !allowed(&lines, i, RULE_WIRE) {
                push(
                    i,
                    RULE_WIRE,
                    format!(
                        "raw numeric wire tag `{tag}` at a `{pat}..)` call site; \
                         use a named constant from `collectives::protocol`"
                    ),
                );
            }
        }
    }

    // --- no-alloc-hot-path --------------------------------------------
    // A `// lint: no-alloc` comment marks the next function; its body
    // (first `{` after the marker through the matching `}`) must stay
    // free of allocation tokens.
    for (m, lm) in lines.iter().enumerate() {
        if !lm.comment.contains("lint: no-alloc") {
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut end = lines.len() - 1;
        'body: for (k, lk) in lines.iter().enumerate().skip(m) {
            for c in lk.code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' if opened => {
                        depth -= 1;
                        if depth == 0 {
                            end = k;
                            break 'body;
                        }
                    }
                    _ => {}
                }
            }
        }
        for (k, lk) in lines.iter().enumerate().take(end + 1).skip(m) {
            for tok in ALLOC_TOKENS {
                if lk.code.contains(tok) && !allowed(&lines, k, RULE_ALLOC) {
                    push(
                        k,
                        RULE_ALLOC,
                        format!(
                            "`{tok}` inside a `// lint: no-alloc` function; reuse a \
                             scratch buffer or hoist the allocation out of the hot path"
                        ),
                    );
                }
            }
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(path: &str, src: &str, rule: &str) -> Vec<usize> {
        lint_file(path, src)
            .into_iter()
            .filter(|d| d.rule == rule)
            .map(|d| d.line)
            .collect()
    }

    // --- unsafe-safety ------------------------------------------------

    #[test]
    fn unsafe_without_safety_is_flagged() {
        let src = include_str!("../fixtures/unsafe_fail.rs");
        assert_eq!(hits("rust/src/linalg/fx.rs", src, RULE_UNSAFE).len(), 1);
    }

    #[test]
    fn unsafe_with_safety_passes() {
        let src = include_str!("../fixtures/unsafe_pass.rs");
        assert!(hits("rust/src/linalg/fx.rs", src, RULE_UNSAFE).is_empty());
    }

    #[test]
    fn unsafe_allow_escape_is_honoured() {
        let src = include_str!("../fixtures/unsafe_allow.rs");
        assert!(hits("rust/src/linalg/fx.rs", src, RULE_UNSAFE).is_empty());
    }

    #[test]
    fn deleting_a_safety_comment_turns_the_file_red() {
        // The acceptance property stated in the docs: strip the SAFETY
        // comments from a passing file and the linter must object.
        let src = include_str!("../fixtures/unsafe_pass.rs");
        let stripped: String = src
            .lines()
            .filter(|l| !l.contains("SAFETY"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(!hits("rust/src/linalg/fx.rs", &stripped, RULE_UNSAFE).is_empty());
    }

    // --- wire-registry ------------------------------------------------

    #[test]
    fn raw_numeric_tags_and_stray_consts_are_flagged() {
        let src = include_str!("../fixtures/wire_fail.rs");
        // send + recv with literal tags, plus a stray `const TAG_`.
        assert_eq!(hits("rust/src/collectives/fx.rs", src, RULE_WIRE).len(), 3);
    }

    #[test]
    fn named_tags_and_single_arg_channel_sends_pass() {
        let src = include_str!("../fixtures/wire_pass.rs");
        assert!(hits("rust/src/collectives/fx.rs", src, RULE_WIRE).is_empty());
    }

    #[test]
    fn wire_allow_escape_is_honoured() {
        let src = include_str!("../fixtures/wire_allow.rs");
        assert!(hits("rust/src/collectives/fx.rs", src, RULE_WIRE).is_empty());
    }

    #[test]
    fn src_unit_tests_may_use_raw_tags() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(c: &mut C) { c.send(1, 42, &[]); }\n}\n";
        assert!(hits("rust/src/collectives/fx.rs", src, RULE_WIRE).is_empty());
        // ... but integration tests may not.
        assert_eq!(hits("rust/tests/fx.rs", src, RULE_WIRE).len(), 1);
    }

    #[test]
    fn registry_duplicates_are_flagged() {
        let src = include_str!("../fixtures/registry_dup.rs");
        let (_, diags) = parse_registry("rust/src/collectives/protocol.rs", src);
        assert_eq!(diags.len(), 2, "one duplicate tag + one duplicate verb");
    }

    #[test]
    fn registry_unique_values_pass() {
        let src = include_str!("../fixtures/registry_ok.rs");
        let (reg, diags) = parse_registry("rust/src/collectives/protocol.rs", src);
        assert!(diags.is_empty());
        assert_eq!((reg.tags.len(), reg.verbs.len()), (3, 3));
        assert_eq!(reg.tags[1].1, u64::MAX - 1);
    }

    // --- no-alloc-hot-path --------------------------------------------

    #[test]
    fn allocation_in_marked_fn_is_flagged() {
        let src = include_str!("../fixtures/noalloc_fail.rs");
        // Vec::new + .to_vec( inside the marked body; the unmarked
        // function below it allocates freely.
        assert_eq!(hits("rust/src/coordinator/engine/fx.rs", src, RULE_ALLOC).len(), 2);
    }

    #[test]
    fn scratch_reuse_in_marked_fn_passes() {
        let src = include_str!("../fixtures/noalloc_pass.rs");
        assert!(hits("rust/src/coordinator/engine/fx.rs", src, RULE_ALLOC).is_empty());
    }

    #[test]
    fn chunk_read_path_allocation_turns_the_tree_red() {
        // Pins the chunk-store contract: a `Vec::new` creeping into the
        // marked `read_chunk` body is a diagnostic, while the cold
        // open-time allocation below the body stays legal.
        let src = include_str!("../fixtures/noalloc_chunkread_fail.rs");
        let lines = hits("rust/src/data/store.rs", src, RULE_ALLOC);
        assert_eq!(lines.len(), 1, "exactly the hot-path Vec::new");
        assert!(src.lines().nth(lines[0] - 1).unwrap().contains("Vec::new"));
    }

    #[test]
    fn noalloc_allow_escape_is_honoured() {
        let src = include_str!("../fixtures/noalloc_allow.rs");
        assert!(hits("rust/src/coordinator/engine/fx.rs", src, RULE_ALLOC).is_empty());
    }

    // --- no-unwrap-protocol -------------------------------------------

    #[test]
    fn unwrap_in_protocol_layer_is_flagged() {
        let src = include_str!("../fixtures/unwrap_fail.rs");
        assert_eq!(hits("rust/src/collectives/fx.rs", src, RULE_UNWRAP).len(), 2);
        // The same file outside the protocol layers is fine.
        assert!(hits("rust/src/linalg/fx.rs", src, RULE_UNWRAP).is_empty());
    }

    #[test]
    fn fallible_plumbing_and_test_mods_pass() {
        let src = include_str!("../fixtures/unwrap_pass.rs");
        assert!(hits("rust/src/coordinator/engine/fx.rs", src, RULE_UNWRAP).is_empty());
    }

    #[test]
    fn unwrap_allow_escape_is_honoured() {
        let src = include_str!("../fixtures/unwrap_allow.rs");
        assert!(hits("rust/src/collectives/fx.rs", src, RULE_UNWRAP).is_empty());
    }

    // --- relaxed-ordering-justified -----------------------------------

    #[test]
    fn unjustified_relaxed_is_flagged() {
        let src = include_str!("../fixtures/relaxed_fail.rs");
        assert_eq!(hits("rust/src/metrics/fx.rs", src, RULE_RELAXED).len(), 1);
    }

    #[test]
    fn justified_relaxed_and_use_lines_pass() {
        let src = include_str!("../fixtures/relaxed_pass.rs");
        assert!(hits("rust/src/metrics/fx.rs", src, RULE_RELAXED).is_empty());
    }

    #[test]
    fn relaxed_allow_escape_is_honoured() {
        let src = include_str!("../fixtures/relaxed_allow.rs");
        assert!(hits("rust/src/metrics/fx.rs", src, RULE_RELAXED).is_empty());
    }
}
