//! `gpp-lint` — the repo's invariant linter.
//!
//! Walks `rust/src`, `rust/tests` and `rust/benches` and enforces five
//! lexical invariants over the concurrency and unsafe layers (see
//! `docs/TESTING.md` for the rule catalog and the escape policy):
//!
//! * `unsafe-safety` — every `unsafe` carries a `// SAFETY:` comment.
//! * `wire-registry` — wire tags/verbs are declared once, in
//!   `collectives::protocol`, with unique values, and call sites never
//!   pass raw numeric tags.
//! * `no-alloc-hot-path` — `// lint: no-alloc` functions stay
//!   allocation-free.
//! * `no-unwrap-protocol` — no `.unwrap()`/`.expect(` in `collectives/`
//!   or `coordinator/engine/` outside tests.
//! * `relaxed-ordering-justified` — every `Ordering::Relaxed` states why
//!   relaxed suffices.
//!
//! Exit status: 0 clean, 1 diagnostics, 2 usage/IO error. Diagnostics
//! print as `path:line: [rule] message`.
//!
//! Usage: `cargo run -p gpp-lint [-- <repo-root>]`. Without an argument
//! the root is found by walking up from the current directory to the
//! first ancestor containing `rust/src`.

mod lexer;
mod rules;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Directories scanned, relative to the repo root.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches"];

/// The one sanctioned home for wire tags and command verbs.
const REGISTRY: &str = "rust/src/collectives/protocol.rs";

/// Locate the repo root: explicit argument, else the first ancestor of
/// the current directory containing `rust/src`, else the workspace root
/// relative to this crate's manifest (covers `cargo run -p gpp-lint`
/// from anywhere inside the workspace).
fn find_root() -> Option<PathBuf> {
    if let Some(arg) = std::env::args_os().nth(1) {
        return Some(PathBuf::from(arg));
    }
    if let Ok(cwd) = std::env::current_dir() {
        for anc in cwd.ancestors() {
            if anc.join("rust/src").is_dir() {
                return Some(anc.to_path_buf());
            }
        }
    }
    let manifest = std::env::var_os("CARGO_MANIFEST_DIR")?;
    Path::new(&manifest).join("../..").canonicalize().ok()
}

/// Collect `.rs` files under `dir`, depth-first in sorted order so the
/// diagnostic stream is deterministic across filesystems.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    let Some(root) = find_root() else {
        eprintln!("gpp-lint: cannot locate the repo root; pass it as the first argument");
        return ExitCode::from(2);
    };

    let reg_src = match std::fs::read_to_string(root.join(REGISTRY)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("gpp-lint: cannot read {REGISTRY}: {e}");
            return ExitCode::from(2);
        }
    };
    let (registry, mut diags) = rules::parse_registry(REGISTRY, &reg_src);

    let mut files = Vec::new();
    for d in SCAN_DIRS {
        let dir = root.join(d);
        if !dir.is_dir() {
            continue;
        }
        if let Err(e) = collect_rs(&dir, &mut files) {
            eprintln!("gpp-lint: cannot walk {d}: {e}");
            return ExitCode::from(2);
        }
    }

    for f in &files {
        let rel = f
            .strip_prefix(&root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        match std::fs::read_to_string(f) {
            Ok(src) => diags.extend(rules::lint_file(&rel, &src)),
            Err(e) => {
                eprintln!("gpp-lint: cannot read {rel}: {e}");
                return ExitCode::from(2);
            }
        }
    }

    diags.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    for d in &diags {
        println!("{}:{}: [{}] {}", d.path, d.line, d.rule, d.message);
    }
    if diags.is_empty() {
        println!(
            "gpp-lint: {} files clean ({} wire tags, {} verbs registered)",
            files.len(),
            registry.tags.len(),
            registry.verbs.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("gpp-lint: {} diagnostic(s)", diags.len());
        ExitCode::from(1)
    }
}
