//! A lightweight, line-oriented Rust scanner.
//!
//! The linter does not need a parser: every rule it enforces is a
//! *lexical* invariant (a token that must or must not appear, a comment
//! that must accompany it). What it does need is to never be fooled by
//! comments and string literals — `"send(1, 42)"` inside a doc string is
//! not a wire call, and `// unsafe` in prose is not an unsafe block. This
//! module splits a source file into per-line `{code, comment}` halves
//! with string/char contents elided from the code half, and tracks
//! `#[cfg(test)]`-gated regions by brace depth.

/// One physical source line, split into its code and comment halves.
///
/// String and char literal *contents* are stripped from `code` (the
/// delimiting quotes are kept, collapsed to `""`), so substring searches
/// over `code` cannot match inside a literal. All comment text on the
/// line — `//`, `///`, `/* .. */`, including the interior lines of a
/// multi-line block comment — lands in `comment`.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code text with comments removed and literal contents elided.
    pub code: String,
    /// Concatenated comment text found on this line.
    pub comment: String,
}

/// Scanner state that survives a newline (multi-line constructs).
#[derive(Clone, Copy)]
enum State {
    /// Ordinary code.
    Normal,
    /// Inside `//` — terminated by the newline.
    LineComment,
    /// Inside `/* .. */`, with nesting depth (Rust block comments nest).
    Block(usize),
    /// Inside a `"` string (escapes honoured; may span lines).
    Str,
    /// Inside a raw string `r##" .. "##` with the given hash count.
    RawStr(usize),
}

/// True if `code` currently ends in an identifier character — used to
/// tell `r"` / `b"` literal prefixes apart from identifiers that merely
/// end in `r` or `b` (e.g. `var"` cannot occur, but `ptr` followed by a
/// separate token can).
fn prev_is_ident(code: &str) -> bool {
    code.chars()
        .next_back()
        .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// If `chars[i..]` starts a raw-string opener (`r"`, `r#"`, ...; `i`
/// points at the `r`), return the hash count.
fn raw_opener(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i + 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

/// Split `src` into per-line code/comment halves.
pub fn lex(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = State::Normal;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, State::LineComment) {
                st = State::Normal;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            State::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = State::LineComment;
                    cur.code.push(' ');
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = State::Block(1);
                    cur.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Str;
                    i += 1;
                } else if c == 'r' && !prev_is_ident(&cur.code) && raw_opener(&chars, i).is_some() {
                    let h = raw_opener(&chars, i).unwrap();
                    cur.code.push('"');
                    st = State::RawStr(h);
                    i += 1 + h + 1;
                } else if c == 'b' && !prev_is_ident(&cur.code) {
                    // Byte-literal prefixes: b"..", br".." / br#"..", b'x'.
                    if chars.get(i + 1) == Some(&'"') {
                        cur.code.push('"');
                        st = State::Str;
                        i += 2;
                    } else if chars.get(i + 1) == Some(&'r') && raw_opener(&chars, i + 1).is_some()
                    {
                        let h = raw_opener(&chars, i + 1).unwrap();
                        cur.code.push('"');
                        st = State::RawStr(h);
                        i += 2 + h + 1;
                    } else if chars.get(i + 1) == Some(&'\'') {
                        i = skip_char_literal(&chars, i + 1);
                    } else {
                        cur.code.push('b');
                        i += 1;
                    }
                } else if c == '\'' {
                    // Lifetime (`'a`, `'static`) vs char literal (`'x'`,
                    // `'\n'`): after the quote, an identifier-start char
                    // NOT followed by a closing quote is a lifetime.
                    let c1 = chars.get(i + 1).copied();
                    let c2 = chars.get(i + 2).copied();
                    let is_lifetime = c1
                        .is_some_and(|x| x.is_alphabetic() || x == '_')
                        && c2 != Some('\'');
                    if is_lifetime {
                        cur.code.push('\'');
                        i += 1;
                    } else {
                        i = skip_char_literal(&chars, i);
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::Block(d) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = State::Block(d + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if d == 1 { State::Normal } else { State::Block(d - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Keep a trailing line-continuation backslash from
                    // swallowing the newline (line accounting must hold).
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(h) => {
                if c == '"' && chars[i + 1..].iter().take(h).filter(|&&x| x == '#').count() == h {
                    cur.code.push('"');
                    st = State::Normal;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Skip a char literal starting at the opening `'` at `chars[i]`; returns
/// the index just past the closing quote. Nothing is emitted to the code
/// half — no rule inspects char contents.
fn skip_char_literal(chars: &[char], i: usize) -> usize {
    if chars.get(i + 1) == Some(&'\\') {
        // Escaped: '\n', '\'', '\u{1F600}', ... — skip the escape head,
        // then scan to the closing quote.
        let mut j = i + 3;
        while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
            j += 1;
        }
        j + 1
    } else {
        // Plain 'x' — one payload char (possibly multi-byte) + quote.
        i + 3
    }
}

/// Mark every line that falls inside a `#[cfg(test)]`-gated item body.
///
/// The scan arms on the attribute token and claims the region from the
/// next opening brace to its match (by depth). A `;` while armed — an
/// out-of-line `#[cfg(test)] mod tests;` — disarms without a region.
/// Nested `#[cfg(test)]` regions collapse into the enclosing one.
pub fn test_regions(lines: &[Line]) -> Vec<bool> {
    const ATTR: &str = "#[cfg(test)]";
    let mut region = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut open_at: Vec<i64> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let arm_from = line.code.find(ATTR).map(|p| p + ATTR.len());
        let mut in_region_here = !open_at.is_empty();
        for (pos, c) in line.code.char_indices() {
            if arm_from == Some(pos) {
                armed = true;
            }
            match c {
                '{' => {
                    if armed {
                        open_at.push(depth);
                        armed = false;
                        in_region_here = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if open_at.last() == Some(&depth) {
                        open_at.pop();
                    }
                }
                ';' => armed = false,
                _ => {}
            }
        }
        if arm_from.is_some_and(|p| p >= line.code.len()) {
            armed = true;
        }
        region[idx] = in_region_here || !open_at.is_empty();
    }
    region
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_stripped_from_code() {
        let lines = lex("let x = 1; // unsafe in prose\n/* unsafe */ let y = 2;\n");
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].comment.contains("unsafe in prose"));
        assert!(!lines[1].code.contains("unsafe"));
        assert!(lines[1].code.contains("let y = 2;"));
    }

    #[test]
    fn string_contents_are_elided() {
        let lines = lex("let s = \".send(1, 42)\";\nlet r = r#\"recv(0, 7)\"#;\n");
        assert!(!lines[0].code.contains("send"));
        assert!(!lines[1].code.contains("recv"));
        assert!(lines[0].code.contains("let s ="));
    }

    #[test]
    fn multiline_string_spans_lines() {
        let lines = lex("let s = \"first\nsecond .unwrap() line\";\nlet t = 3;\n");
        assert_eq!(lines.len(), 3);
        assert!(!lines[1].code.contains("unwrap"));
        assert!(lines[2].code.contains("let t = 3;"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lines = lex("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x';\nlet q = '\\'';\n");
        assert!(lines[0].code.contains("fn f<'a>"));
        // The char literal payloads are gone but the line structure holds.
        assert_eq!(lines.len(), 3);
        assert!(lines[1].code.contains("let c ="));
        assert!(lines[2].code.contains("let q ="));
    }

    #[test]
    fn nested_block_comments() {
        let lines = lex("/* outer /* inner */ still comment */ let z = 1;\n");
        assert!(lines[0].code.contains("let z = 1;"));
        assert!(!lines[0].code.contains("inner"));
    }

    #[test]
    fn test_region_tracking() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let lines = lex(src);
        let region = test_regions(&lines);
        assert_eq!(region, vec![false, false, true, true, true, false]);
    }

    #[test]
    fn out_of_line_test_mod_disarms() {
        let src = "#[cfg(test)]\nmod tests;\nfn after() { let x = 1; }\n";
        let lines = lex(src);
        let region = test_regions(&lines);
        assert!(!region[2], "out-of-line mod must not open a region");
    }
}
