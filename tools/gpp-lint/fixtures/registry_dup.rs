// Fixture: one duplicated tag value and one duplicated verb value
// within the same prefix group — two diagnostics. SRV_Z reusing 1.0 is
// fine (different prefix group from CMD_*).
pub const TAG_A: u64 = 7;
pub const TAG_B: u64 = 7;
pub const CMD_X: f64 = 1.0;
pub const CMD_Y: f64 = 1.0;
pub const SRV_Z: f64 = 1.0;
