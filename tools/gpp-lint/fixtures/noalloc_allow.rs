// Fixture: one audited allocation inside a marked body, silenced by the
// escape hatch.
impl Scratch {
    // lint: no-alloc
    fn drain(&mut self, comm: &mut Comm) -> Result<()> {
        // lint: allow(no-alloc-hot-path) — empty sentinel, never grows.
        comm.bcast(0, Vec::new())?;
        Ok(())
    }
}
