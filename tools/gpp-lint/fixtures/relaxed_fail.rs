// Fixture: a Relaxed atomic with no justification — one diagnostic.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}
