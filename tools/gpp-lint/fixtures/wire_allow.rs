// Fixture: the escape hatch silences the raw-tag rule at one site.
pub fn probe(comm: &mut Comm) -> Result<()> {
    // lint: allow(wire-registry) — fixture exercising the escape hatch;
    // a probe tag outside the registered vocabulary, documented here.
    comm.send(1, 999, &[])?;
    Ok(())
}
