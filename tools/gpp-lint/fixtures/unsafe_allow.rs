// Fixture: the escape hatch silences the rule at one audited site.
pub fn read_first(a: &[f64]) -> f64 {
    // lint: allow(unsafe-safety) — fixture exercising the escape hatch;
    // a real site would carry the audit trail here instead.
    unsafe { *a.get_unchecked(0) }
}
