// Fixture: a miniature protocol registry with unique tag values and
// verb values unique within each prefix group (CMD_ vs SRV_ ride
// different wire contexts, so 0.0 may appear once in each).
pub const TAG_A: u64 = 100;
pub const TAG_B: u64 = u64::MAX - 1;
pub const TAG_C: u64 = u64::MAX;
pub const CMD_STOP: f64 = 0.0;
pub const CMD_GO: f64 = 1.0;
pub const SRV_DONE: f64 = 0.0;
