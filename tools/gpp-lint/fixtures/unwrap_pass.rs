// Fixture: the sanctioned alternatives — fallible plumbing with
// `ok_or_else` + `?`, the poison-tolerant lock path, and unwraps inside
// a `#[cfg(test)]` module — all clean.
pub fn tail(wire: &[f64]) -> Result<f64> {
    wire.last().copied().ok_or_else(|| anyhow!("empty reduce wire"))
}

pub fn take(slot: &std::sync::Mutex<Option<f64>>) -> Result<f64> {
    slot.lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .take()
        .ok_or_else(|| anyhow!("slot already taken"))
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        let v = vec![1.0];
        assert_eq!(*v.last().unwrap(), 1.0);
    }
}
