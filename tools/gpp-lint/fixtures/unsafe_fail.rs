// Fixture: an `unsafe` block with no SAFETY comment — must be flagged.
pub fn dot_unchecked(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..a.len().min(b.len()) {
        acc += unsafe { *a.get_unchecked(i) * *b.get_unchecked(i) };
    }
    acc
}
