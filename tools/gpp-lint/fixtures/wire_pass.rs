// Fixture: named tags from the registry, a single-argument mpsc send
// (no wire tag to check, even with a nested comma), and a call spanning
// lines — all clean.
use crate::collectives::protocol::TAG_XSTAR;

pub fn ping(comm: &mut Comm) -> Result<()> {
    comm.send(1, TAG_XSTAR, &[1.0])?;
    let _ = comm.recv(
        1,
        TAG_XSTAR,
    )?;
    Ok(())
}

pub fn forward(tx: &std::sync::mpsc::Sender<(usize, f64)>) {
    let _ = tx.send(pack(3, 0.5));
}
