// Fixture: a documented invariant silences the rule at one site.
pub fn drive(ev: &mut Evaluator) -> f64 {
    // lint: allow(no-unwrap-protocol) — the session is checked open by
    // the caller and nothing closes it mid-run; a miss here is a local
    // logic bug, not a recoverable wire condition.
    ev.sharded.as_mut().expect("session checked open").step()
}
