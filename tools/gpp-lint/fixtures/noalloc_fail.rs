// Fixture: allocation tokens inside a marked body — two diagnostics.
// The unmarked function below the body allocates freely.
impl Scratch {
    // lint: no-alloc
    fn seal(&mut self, xs: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        out.extend_from_slice(xs);
        out.to_vec()
    }

    fn cold(&self) -> Vec<f64> {
        vec![0.0; 4]
    }
}
