// Fixture: justified Relaxed uses — trailing comment, preceding block,
// and a `use` import line — all clean.
use std::sync::atomic::Ordering::Relaxed;
use std::sync::atomic::AtomicU64;

pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Relaxed); // Relaxed: statistics counter, no ordering
}

pub fn read(c: &AtomicU64) -> u64 {
    // Relaxed: monotone snapshot for reporting; nothing synchronises
    // with this load.
    c.load(Relaxed)
}
