// Fixture: raw numeric tags at send/recv sites and a wire constant
// declared outside the registry — three diagnostics.
pub const TAG_ROGUE: u64 = 9; // declared outside collectives::protocol

pub fn ping(comm: &mut Comm) -> Result<()> {
    comm.send(1, 300, &[1.0])?;
    let _ = comm.recv(1, 300)?;
    Ok(())
}
