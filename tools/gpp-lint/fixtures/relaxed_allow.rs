// Fixture: the escape hatch silences the Relaxed rule at one site.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) {
    // lint: allow(relaxed-ordering-justified) — fixture exercising the
    // escape hatch.
    c.fetch_add(1, Ordering::Relaxed);
}
