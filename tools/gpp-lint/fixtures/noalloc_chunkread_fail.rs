// Fixture: the chunk-store steady-state read path regresses to a
// per-read allocation — one diagnostic. Models `FileStoreReader::
// read_chunk`, whose byte buffer must be preallocated at open so
// sequential chunk reads never touch the allocator.
impl FileStoreReader {
    // The steady-state read path: the byte buffer is preallocated at
    // open for a full chunk, so `resize` never reallocates here.
    // lint: no-alloc
    fn read_chunk(&mut self, k: usize, x_out: &mut [f64], y_out: &mut [f64]) -> Result<()> {
        let want = self.manifest.payload_len(k);
        let mut buf = Vec::new(); // the regression: fresh buffer per read
        buf.resize(want, 0);
        self.file.read_exact(&mut buf)?;
        decode_payload(&buf, x_out, y_out);
        Ok(())
    }

    fn open_scratch(&self) -> Vec<u8> {
        // cold path: allocating at open time is exactly what the marker
        // pushes the hot path towards, so this stays legal
        Vec::with_capacity(self.manifest.chunk_rows * 8)
    }
}
