// Fixture: SAFETY comments in both sanctioned positions — preceding
// block (attributes skipped) and same-line — must pass.

// SAFETY preconditions (caller): `a` and `b` are the same length and the
// host supports AVX2 (checked by the dispatcher).
#[inline]
pub unsafe fn dot_avx(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..a.len() {
        // SAFETY: i < a.len() == b.len() by the loop bound above.
        acc += unsafe { *a.get_unchecked(i) * *b.get_unchecked(i) };
    }
    acc
}
