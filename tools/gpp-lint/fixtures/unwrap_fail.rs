// Fixture: unwrap/expect in non-test code — two diagnostics when the
// file sits in a protocol layer, none elsewhere.
pub fn tail(wire: &[f64]) -> f64 {
    *wire.last().unwrap()
}

pub fn must(map: &std::collections::BTreeMap<u64, f64>, k: u64) -> f64 {
    *map.get(&k).expect("tag present")
}
