// Fixture: a marked body that only reuses scratch storage — clean.
impl Scratch {
    // lint: no-alloc
    fn seal(&mut self, xs: &[f64]) {
        self.wire.clear();
        self.wire.extend_from_slice(xs);
        self.wire.resize(xs.len() + 1, 0.0);
    }

    fn cold(&self) -> Vec<f64> {
        vec![0.0; 4]
    }
}
