"""L1 correctness: Pallas psi kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the accelerated path: hypothesis
sweeps shapes/dtypes/parameter magnitudes and asserts allclose against
the reference, plus structural invariants (symmetry, PSD, masking, the
S->0 exact-kernel limit, tile-size invariance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import psi_rbf, ref

jax.config.update("jax_enable_x64", True)


def make_inputs(seed, n, m, q, dtype=np.float64, scale=1.0):
    rng = np.random.default_rng(seed)
    mu = jnp.asarray(rng.normal(0, scale, (n, q)), dtype)
    s = jnp.asarray(rng.uniform(0.05, 2.0 * scale, (n, q)), dtype)
    w = jnp.asarray(rng.integers(0, 2, n), dtype)
    z = jnp.asarray(rng.normal(0, scale, (m, q)), dtype)
    log_hyp = jnp.asarray(rng.normal(0, 0.5, q + 1), dtype)
    return mu, s, w, z, log_hyp


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, dtypes, scales
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 70),
    m=st.integers(1, 33),
    q=st.integers(1, 5),
)
def test_psi1_matches_ref(seed, n, m, q):
    mu, s, w, z, lh = make_inputs(seed, n, m, q)
    got = psi_rbf.psi1_pallas(mu, s, z, lh)
    want = ref.psi1_ref(mu, s, z, lh)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-14)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 70),
    m=st.integers(1, 33),
    q=st.integers(1, 4),
)
def test_psi2_matches_ref(seed, n, m, q):
    mu, s, w, z, lh = make_inputs(seed, n, m, q)
    got = psi_rbf.psi2_pallas(mu, s, w, z, lh)
    want = ref.psi2_ref(mu, s, w, z, lh)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-13)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    dtype=st.sampled_from([np.float32, np.float64]),
    scale=st.floats(0.1, 5.0),
)
def test_psi_dtypes_and_scales(seed, dtype, scale):
    mu, s, w, z, lh = make_inputs(seed, 24, 8, 2, dtype=dtype, scale=scale)
    tol = dict(rtol=2e-5, atol=2e-6) if dtype == np.float32 else \
        dict(rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(
        psi_rbf.psi1_pallas(mu, s, z, lh), ref.psi1_ref(mu, s, z, lh), **tol)
    np.testing.assert_allclose(
        psi_rbf.psi2_pallas(mu, s, w, z, lh), ref.psi2_ref(mu, s, w, z, lh),
        **tol)
    assert psi_rbf.psi1_pallas(mu, s, z, lh).dtype == dtype


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bn=st.integers(1, 64),
    bm=st.integers(1, 16),
)
def test_tile_size_invariance(seed, bn, bm):
    """The result must not depend on the Pallas tile decomposition."""
    mu, s, w, z, lh = make_inputs(seed, 48, 12, 2)
    base2 = psi_rbf.psi2_pallas(mu, s, w, z, lh, bn=48, bm=12)
    got2 = psi_rbf.psi2_pallas(mu, s, w, z, lh, bn=bn, bm=bm)
    np.testing.assert_allclose(got2, base2, rtol=1e-12, atol=1e-14)
    base1 = psi_rbf.psi1_pallas(mu, s, z, lh, bn=48, bm=12)
    got1 = psi_rbf.psi1_pallas(mu, s, z, lh, bn=bn, bm=bm)
    np.testing.assert_allclose(got1, base1, rtol=1e-12, atol=1e-14)


# ---------------------------------------------------------------------------
# structural invariants
# ---------------------------------------------------------------------------

def test_psi2_symmetric_psd():
    mu, s, w, z, lh = make_inputs(3, 64, 16, 3)
    w = jnp.ones_like(w)
    p2 = psi_rbf.psi2_pallas(mu, s, w, z, lh)
    np.testing.assert_allclose(p2, p2.T, rtol=0, atol=1e-12)
    evals = np.linalg.eigvalsh(np.asarray(p2))
    assert evals.min() > -1e-10, f"Psi2 not PSD: min eig {evals.min()}"


def test_s_zero_recovers_exact_kernel():
    """S=0 must give Psi1 == K_fu and Psi2 == K_uf K_fu exactly — this is
    what makes the SGPR path share the BGP-LVM kernels."""
    mu, _, w, z, lh = make_inputs(7, 40, 10, 2)
    w = jnp.ones_like(w)
    s0 = jnp.zeros_like(mu)
    sigma2, alpha = ref.unpack_hyp(lh)
    d = mu[:, None, :] - z[None, :, :]
    kfu = sigma2 * jnp.exp(-0.5 * jnp.sum(alpha * d * d, axis=-1))
    np.testing.assert_allclose(psi_rbf.psi1_pallas(mu, s0, z, lh), kfu,
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(psi_rbf.psi2_pallas(mu, s0, w, z, lh),
                               kfu.T @ kfu, rtol=1e-12, atol=1e-12)


def test_mask_drops_points():
    """Masked-out rows must contribute nothing to Psi2/psi0, exactly as a
    shorter chunk would."""
    mu, s, _, z, lh = make_inputs(11, 32, 8, 2)
    w_full = jnp.concatenate([jnp.ones(20), jnp.zeros(12)])
    got = psi_rbf.psi2_pallas(mu, s, w_full, z, lh)
    want = ref.psi2_ref(mu[:20], s[:20], jnp.ones(20), z, lh)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-13)
    np.testing.assert_allclose(ref.psi0_ref(w_full, lh),
                               ref.psi0_ref(jnp.ones(20), lh))


def test_psi1_monotone_in_distance():
    """Psi1 decays as |mu - z| grows (RBF sanity)."""
    q = 1
    z = jnp.zeros((1, q))
    lh = jnp.zeros(q + 1)
    s = jnp.full((3, q), 0.5)
    mu = jnp.asarray([[0.0], [1.0], [3.0]])
    p1 = np.asarray(psi_rbf.psi1_pallas(mu, s, z, lh)).ravel()
    assert p1[0] > p1[1] > p1[2] > 0


def test_blocked_ref_matches_ref():
    mu, s, w, z, lh = make_inputs(13, 100, 9, 2)
    np.testing.assert_allclose(
        ref.psi2_ref_blocked(mu, s, w, z, lh, block=17),
        ref.psi2_ref(mu, s, w, z, lh), rtol=1e-12, atol=1e-13)


def test_pick_block():
    assert psi_rbf.pick_block(100, 32) == 25
    assert psi_rbf.pick_block(64, 256) == 64
    assert psi_rbf.pick_block(17, 4) == 1
    for n in [1, 7, 24, 100, 1024]:
        for t in [1, 3, 16, 999]:
            b = psi_rbf.pick_block(n, t)
            assert n % b == 0 and 1 <= b <= max(1, min(n, t))


# ---------------------------------------------------------------------------
# custom_vjp gradients (the Table-2 analog) vs autodiff of the reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_custom_vjp_matches_ref_grad(seed):
    mu, s, w, z, lh = make_inputs(seed, 24, 8, 2)
    w = jnp.ones_like(w)
    ct1 = jnp.asarray(np.random.default_rng(seed).normal(size=(24, 8)))
    ct2 = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(8, 8)))

    def via_kernel(mu_, s_, z_, lh_):
        return (jnp.sum(psi_rbf.psi1(mu_, s_, z_, lh_) * ct1)
                + jnp.sum(psi_rbf.psi2(mu_, s_, w, z_, lh_) * ct2))

    def via_ref(mu_, s_, z_, lh_):
        return (jnp.sum(ref.psi1_ref(mu_, s_, z_, lh_) * ct1)
                + jnp.sum(ref.psi2_ref(mu_, s_, w, z_, lh_) * ct2))

    gk = jax.grad(via_kernel, argnums=(0, 1, 2, 3))(mu, s, z, lh)
    gr = jax.grad(via_ref, argnums=(0, 1, 2, 3))(mu, s, z, lh)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-10, atol=1e-12)
