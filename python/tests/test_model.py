"""L2 correctness: the distributed objective equals the monolithic one,
gradients agree with finite differences, and the SGPR bound collapses to
the exact GP log-marginal-likelihood when Z = X (Titsias 2009)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def make_problem(seed, n, m, q, d):
    rng = np.random.default_rng(seed)
    mu = jnp.asarray(rng.normal(size=(n, q)))
    s = jnp.asarray(rng.uniform(0.2, 1.5, (n, q)))
    y = jnp.asarray(rng.normal(size=(n, d)))
    z = jnp.asarray(rng.normal(size=(m, q)))
    log_hyp = jnp.asarray(rng.normal(0, 0.3, q + 1))
    log_beta = jnp.asarray(rng.normal() * 0.3)
    return mu, s, y, z, log_hyp, log_beta


def reduce_chunks(mu, s, y, z, lh, chunk):
    """Emulate the coordinator: per-chunk stats (with padding on the tail),
    summed — must equal the monolithic stats exactly."""
    n = mu.shape[0]
    tot = None
    for i in range(0, n, chunk):
        end = min(i + chunk, n)
        c = end - i
        pad = chunk - c
        mu_c = jnp.pad(mu[i:end], ((0, pad), (0, 0)))
        s_c = jnp.pad(s[i:end], ((0, pad), (0, 0)), constant_values=1.0)
        y_c = jnp.pad(y[i:end], ((0, pad), (0, 0)))
        w_c = jnp.pad(jnp.ones(c), (0, pad))
        st = model.bgplvm_stats_fwd(mu_c, s_c, w_c, y_c, z, lh)
        tot = st if tot is None else tuple(a + b for a, b in zip(tot, st))
    return tot


# ---------------------------------------------------------------------------
# distributed == monolithic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunk", [(50, 16), (64, 64), (33, 10), (7, 32)])
def test_chunked_stats_equal_full(n, chunk):
    mu, s, y, z, lh, _ = make_problem(0, n, 12, 2, 3)
    w = jnp.ones(n)
    full = model.bgplvm_stats_fwd(mu, s, w, y, z, lh)
    summed = reduce_chunks(mu, s, y, z, lh, chunk)
    for a, b in zip(summed, full):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("chunk", [8, 16, 37])
def test_chunked_bound_equals_full(chunk):
    mu, s, y, z, lh, lb = make_problem(1, 48, 10, 2, 3)
    st = reduce_chunks(mu, s, y, z, lh, chunk)
    f_dist = model.bound_from_stats(*st, z, lh, lb, jnp.asarray(48.0))
    f_full = model.bgplvm_bound_full(mu, s, y, z, lh, lb)
    np.testing.assert_allclose(f_dist, f_full, rtol=1e-12)


# ---------------------------------------------------------------------------
# gradients: the fwd/bound/vjp decomposition equals jax.grad of the full
# bound — i.e. the distributed chain rule is exact.
# ---------------------------------------------------------------------------

def test_distributed_gradients_equal_monolithic():
    n, m, q, d = 40, 8, 2, 3
    mu, s, y, z, lh, lb = make_problem(2, n, m, q, d)
    w = jnp.ones(n)

    # distributed path: fwd -> bound_and_grads -> vjp
    st = model.bgplvm_stats_fwd(mu, s, w, y, z, lh)
    out = model.bound_and_grads(*st, z, lh, lb, jnp.asarray(float(n)))
    f, c_psi0, c_p, c_psi2, c_tryy, c_kl, dz_dir, dhyp_dir, dbeta = out
    dmu, ds, dz_part, dhyp_part = model.bgplvm_stats_vjp(
        mu, s, w, y, z, lh, c_psi0, c_p, c_psi2, c_tryy, c_kl)
    dz = dz_dir + dz_part
    dhyp = dhyp_dir + dhyp_part

    # monolithic autodiff
    def full(mu_, s_, z_, lh_, lb_):
        return model.bgplvm_bound_full(mu_, s_, y, z_, lh_, lb_)

    f_ref, g = jax.value_and_grad(full, argnums=(0, 1, 2, 3, 4))(
        mu, s, z, lh, lb)
    np.testing.assert_allclose(f, f_ref, rtol=1e-12)
    for got, want, name in zip((dmu, ds, dz, dhyp, dbeta), g,
                               ("dmu", "ds", "dz", "dhyp", "dbeta")):
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-10,
                                   err_msg=name)


def test_sgpr_distributed_gradients():
    n, m, q, d = 30, 6, 2, 2
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, q)))
    y = jnp.asarray(rng.normal(size=(n, d)))
    z = jnp.asarray(rng.normal(size=(m, q)))
    lh = jnp.asarray(rng.normal(0, 0.3, q + 1))
    lb = jnp.asarray(0.2)
    w = jnp.ones(n)

    st = model.sgpr_stats_fwd(x, w, y, z, lh)
    out = model.bound_and_grads(st[0], st[1], st[2], st[3],
                                jnp.asarray(0.0), z, lh, lb,
                                jnp.asarray(float(n)))
    f, c_psi0, c_p, c_psi2, c_tryy, _c_kl, dz_dir, dhyp_dir, dbeta = out
    dz_part, dhyp_part = model.sgpr_stats_vjp(
        x, w, y, z, lh, c_psi0, c_p, c_psi2, c_tryy)

    def full(z_, lh_, lb_):
        return model.sgpr_bound_full(x, y, z_, lh_, lb_)

    f_ref, g = jax.value_and_grad(full, argnums=(0, 1, 2))(z, lh, lb)
    np.testing.assert_allclose(f, f_ref, rtol=1e-12)
    np.testing.assert_allclose(dz_dir + dz_part, g[0], rtol=1e-9, atol=1e-10)
    np.testing.assert_allclose(dhyp_dir + dhyp_part, g[1], rtol=1e-9,
                               atol=1e-10)
    np.testing.assert_allclose(dbeta, g[2], rtol=1e-9, atol=1e-10)


def test_bound_grads_finite_difference():
    mu, s, y, z, lh, lb = make_problem(4, 20, 5, 1, 2)
    w = jnp.ones(20)
    st = model.bgplvm_stats_fwd(mu, s, w, y, z, lh)
    n_eff = jnp.asarray(20.0)

    out = model.bound_and_grads(*st, z, lh, lb, n_eff)
    dbeta = out[8]
    eps = 1e-6
    f_p = model.bound_from_stats(*st, z, lh, lb + eps, n_eff)
    f_m = model.bound_from_stats(*st, z, lh, lb - eps, n_eff)
    np.testing.assert_allclose(dbeta, (f_p - f_m) / (2 * eps), rtol=1e-5)

    dhyp = out[7]
    for i in range(lh.shape[0]):
        e = jnp.zeros_like(lh).at[i].set(eps)
        # direct term only: stats held fixed
        f_p = model.bound_from_stats(*st, z, lh + e, lb, n_eff)
        f_m = model.bound_from_stats(*st, z, lh - e, lb, n_eff)
        np.testing.assert_allclose(dhyp[i], (f_p - f_m) / (2 * eps),
                                   rtol=1e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# the Titsias Z=X collapse: SGPR bound == exact GP log marginal likelihood
# ---------------------------------------------------------------------------

def test_sgpr_bound_tight_at_z_equals_x():
    n, q, d = 25, 2, 2
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(n, q)))
    y = jnp.asarray(rng.normal(size=(n, d)))
    lh = jnp.asarray([0.2, -0.1, 0.15])
    lb = jnp.asarray(1.1)
    beta = jnp.exp(lb)

    f_sparse = model.sgpr_bound_full(x, y, x, lh, lb)

    # exact dense GP: sum_d log N(y_d | 0, Kff + beta^{-1} I)
    kff = ref.kuu(x, lh, jitter=0.0) - 1e-12 * jnp.eye(n)
    cov = kff + (1.0 / beta) * jnp.eye(n)
    l = jnp.linalg.cholesky(cov)
    alpha_ = jax.scipy.linalg.cho_solve((l, True), y)
    f_exact = (-0.5 * n * d * model.LOG2PI
               - d * jnp.sum(jnp.log(jnp.diagonal(l)))
               - 0.5 * jnp.sum(y * alpha_))
    # With Z=X the bound is tight up to jitter effects.
    np.testing.assert_allclose(f_sparse, f_exact, rtol=1e-5)


def test_bound_decreases_with_worse_beta():
    """Perturbing the noise away from a fitted-ish value lowers F."""
    mu, s, y, z, lh, _ = make_problem(6, 30, 8, 2, 3)
    f = [float(model.bgplvm_bound_full(mu, s, y, z, lh, jnp.asarray(lb)))
         for lb in (-8.0, 0.0, 8.0)]
    assert f[1] > f[0] and f[1] > f[2]
