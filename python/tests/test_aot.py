"""AOT artifact sanity: the manifest matches the lowered functions, HLO
text parses as HLO, and the declared shapes agree with an actual eval.

(The executable round-trip through PJRT is covered by the Rust
integration tests — rust/tests/xla_vs_rust.rs — which load these very
files, run them, and compare against the pure-Rust implementation.)
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_enable_x64", True)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


@needs_artifacts
def test_manifest_complete():
    man = json.load(open(MANIFEST))
    assert man["dtype"] == "f64"
    by_cfg = {}
    for e in man["modules"]:
        by_cfg.setdefault(e["config"], set()).add(e["module"])
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{e['file']} is not HLO text"
    for cfg, mods in by_cfg.items():
        assert mods == {"bgplvm_fwd", "bgplvm_vjp", "sgpr_fwd", "sgpr_vjp",
                        "bound"}, (cfg, mods)


@needs_artifacts
def test_manifest_shapes_match_eval():
    """Evaluate each module's python function on zeros/ones of the declared
    input shapes; output shapes must match the manifest."""
    man = json.load(open(MANIFEST))
    cfgs = {e["config"] for e in man["modules"]}
    for name in cfgs:
        cfg = aot.CONFIGS[name]
        for mod_name, ms in aot.module_specs(cfg).items():
            entry = next(e for e in man["modules"]
                         if e["config"] == name and e["module"] == mod_name)
            args = []
            for spec_name, shape in ms["in"]:
                if spec_name in ("s", "w"):
                    args.append(jnp.ones(shape, jnp.float64))
                elif spec_name == "psi2":
                    args.append(jnp.eye(shape[0], dtype=jnp.float64))
                elif spec_name == "n_eff":
                    args.append(jnp.asarray(float(cfg.c)))
                else:
                    args.append(jnp.zeros(shape, jnp.float64) + 0.1)
            out = ms["fn"](*args)
            out = out if isinstance(out, tuple) else (out,)
            assert len(out) == len(entry["outputs"]), (name, mod_name)
            for o, decl in zip(out, entry["outputs"]):
                assert list(o.shape) == decl["shape"], (name, mod_name,
                                                        decl["name"])
                assert jnp.all(jnp.isfinite(o)), (name, mod_name,
                                                  decl["name"])


def test_to_hlo_text_roundtrip():
    """Lower a tiny function and check the emitted text is parseable HLO
    with a tuple root (what HloModuleProto::from_text_file expects)."""
    def f(x):
        return (jnp.sum(x * x),)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float64))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f64" in text


def test_config_tags_unique():
    tags = [c.tag for c in aot.CONFIGS.values()]
    assert len(set(tags)) == len(tags)
    for c in aot.CONFIGS.values():
        assert c.c % 2 == 0 or c.c == 1
        assert c.m >= 2 and c.q >= 1 and c.d >= 1
