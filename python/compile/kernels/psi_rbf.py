"""Layer-1 Pallas kernels: the psi statistics of the Bayesian GP-LVM.

This is the paper's GPU contribution (its Table 1) re-thought for the
TPU/Pallas programming model rather than mechanically ported from CUDA:

  paper (CUDA, Table 1)                 here (Pallas)
  -------------------------------       --------------------------------
  one *block* per inducing point m      one *program instance* per tile of
  (per pair (m1, m2) for Phi)           inducing points (pair of tiles for
                                        Psi2) — the grid axes
  *threads* over datapoints n           the datapoint axis is the leading
                                        (vectorised) axis of the block: the
                                        VPU/MXU consumes it densely
  per-thread partials in shared         per-tile partials live in VMEM; the
  memory, tree-reduced, then written    sum over the datapoint grid axis is
  to global memory                      an accumulation into the output
                                        block across sequential grid steps
                                        (no cross-block sync needed at all,
                                        which is the constraint the paper's
                                        §3 works around on CC-2.0 cards)

The BlockSpec expresses the HBM<->VMEM schedule that the paper expressed
with its block/thread division: for Psi2 the grid is
(M/bm, M/bm, N/bn) with the datapoint axis innermost, so each (m1, m2)
output tile stays resident in VMEM while datapoint tiles stream past it.

Default tile sizes are tuned for the CPU-interpret execution path (large
tiles, few grid steps — each grid step costs an interpreter dispatch).
On a real TPU the VMEM budget would push toward bn=256, bm=25 for the
paper config (see DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md
§Perf for the structural analysis); both shapes are correctness-tested.

All kernels run with interpret=True: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation). Numerics are identical either way.

Gradients: the kernels are wrapped in jax.custom_vjp whose backward pass
is the analytic VJP obtained from the pure-jnp reference (ref.py). This
is the analog of the paper's Table 2 (the dedicated gradient kernels):
the cotangents dL/dPsi1, dL/dPsi2 arrive from the leader's M x M core and
are pulled back to (mu, S, Z, log_hyp) entirely on-device, lowered and
fused by XLA into the same artifact as the forward statistics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

jax.config.update("jax_enable_x64", True)


def pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (tiles must divide the axis)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Psi1 kernel: out[n, m] over a (N/bn, M/bm) grid.
# ---------------------------------------------------------------------------

def _psi1_kernel(mu_ref, s_ref, z_ref, alpha_ref, sigma2_ref, out_ref):
    mu = mu_ref[...]          # [bn, Q]
    s = s_ref[...]            # [bn, Q]
    z = z_ref[...]            # [bm, Q]
    alpha = alpha_ref[...]    # [Q]
    sigma2 = sigma2_ref[0]

    denom = alpha * s + 1.0                                   # [bn, Q]
    q = mu.shape[1]
    # Accumulate the exponent one latent dimension at a time: keeps the
    # largest VMEM temporary at [bn, bm] instead of [bn, bm, Q].
    expo = jnp.zeros((mu.shape[0], z.shape[0]), dtype=mu.dtype)
    for qi in range(q):
        d = mu[:, qi:qi + 1] - z[:, qi][None, :]              # [bn, bm]
        expo = expo + alpha[qi] * d * d / denom[:, qi:qi + 1]
    coef = sigma2 * jnp.prod(denom, axis=1) ** (-0.5)         # [bn]
    out_ref[...] = coef[:, None] * jnp.exp(-0.5 * expo)


def psi1_pallas(mu, s, z, log_hyp, *, bn=1024, bm=64, interpret=True):
    """Psi1 [N, M] via Pallas; tile sizes are clamped to divisors."""
    n, q = mu.shape
    m = z.shape[0]
    bn = pick_block(n, bn)
    bm = pick_block(m, bm)
    sigma2, alpha = ref.unpack_hyp(log_hyp)
    sigma2 = sigma2[None]

    grid = (n // bn, m // bm)
    return pl.pallas_call(
        _psi1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, q), lambda i, j: (i, 0)),   # mu
            pl.BlockSpec((bn, q), lambda i, j: (i, 0)),   # s
            pl.BlockSpec((bm, q), lambda i, j: (j, 0)),   # z
            pl.BlockSpec((q,), lambda i, j: (0,)),        # alpha
            pl.BlockSpec((1,), lambda i, j: (0,)),        # sigma2
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), mu.dtype),
        interpret=interpret,
    )(mu, s, z, alpha, sigma2)


# ---------------------------------------------------------------------------
# Psi2 kernel: out[m1, m2] over a (M/bm, M/bm, N/bn) grid; the datapoint
# axis is the innermost grid axis and accumulates into the output tile.
# ---------------------------------------------------------------------------

def _psi2_kernel(mu_ref, s_ref, w_ref, z1_ref, z2_ref, alpha_ref,
                 sigma2_ref, out_ref):
    k = pl.program_id(2)

    mu = mu_ref[...]          # [bn, Q]
    s = s_ref[...]            # [bn, Q]
    w = w_ref[...]            # [bn]
    z1 = z1_ref[...]          # [bm1, Q]
    z2 = z2_ref[...]          # [bm2, Q]
    alpha = alpha_ref[...]    # [Q]
    sigma2 = sigma2_ref[0]

    q = mu.shape[1]
    bn, bm1, bm2 = mu.shape[0], z1.shape[0], z2.shape[0]
    denom = 2.0 * alpha * s + 1.0                              # [bn, Q]

    # Inducing-pair distance term and the streamed datapoint term, both
    # accumulated per latent dimension (VMEM: [bm1,bm2] + [bn,bm1,bm2]).
    dist_zz = jnp.zeros((bm1, bm2), dtype=mu.dtype)
    dist_mz = jnp.zeros((bn, bm1, bm2), dtype=mu.dtype)
    for qi in range(q):
        dz = z1[:, qi][:, None] - z2[:, qi][None, :]           # [bm1, bm2]
        dist_zz = dist_zz + 0.25 * alpha[qi] * dz * dz
        zb = 0.5 * (z1[:, qi][:, None] + z2[:, qi][None, :])   # [bm1, bm2]
        dmu = mu[:, qi][:, None, None] - zb[None, :, :]        # [bn, bm1, bm2]
        dist_mz = dist_mz + alpha[qi] * dmu * dmu / denom[:, qi][:, None, None]

    coef = (sigma2 * sigma2) * jnp.prod(denom, axis=1) ** (-0.5) * w  # [bn]
    tile = jnp.einsum("n,nab->ab", coef, jnp.exp(-dist_zz[None, :, :] - dist_mz))

    # First datapoint tile initialises the output block; the rest add.
    @pl.when(k == 0)
    def _init():
        out_ref[...] = tile

    @pl.when(k != 0)
    def _acc():
        out_ref[...] = out_ref[...] + tile


def psi2_pallas(mu, s, w, z, log_hyp, *, bn=1024, bm=50, interpret=True):
    """Psi2 [M, M] (already summed over datapoints) via Pallas."""
    n, q = mu.shape
    m = z.shape[0]
    bn = pick_block(n, bn)
    bm = pick_block(m, bm)
    sigma2, alpha = ref.unpack_hyp(log_hyp)
    sigma2 = sigma2[None]

    grid = (m // bm, m // bm, n // bn)
    return pl.pallas_call(
        _psi2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, q), lambda i, j, k: (k, 0)),   # mu
            pl.BlockSpec((bn, q), lambda i, j, k: (k, 0)),   # s
            pl.BlockSpec((bn,), lambda i, j, k: (k,)),       # w
            pl.BlockSpec((bm, q), lambda i, j, k: (i, 0)),   # z tile (rows)
            pl.BlockSpec((bm, q), lambda i, j, k: (j, 0)),   # z tile (cols)
            pl.BlockSpec((q,), lambda i, j, k: (0,)),        # alpha
            pl.BlockSpec((1,), lambda i, j, k: (0,)),        # sigma2
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), mu.dtype),
        interpret=interpret,
    )(mu, s, w, z, z, alpha, sigma2)


# ---------------------------------------------------------------------------
# Differentiable wrappers. Forward = Pallas kernel; backward = analytic VJP
# pulled from the jnp reference (the Table-2 analog, fused by XLA).
# ---------------------------------------------------------------------------

@jax.custom_vjp
def psi1(mu, s, z, log_hyp):
    return psi1_pallas(mu, s, z, log_hyp)


def _psi1_fwd(mu, s, z, log_hyp):
    return psi1(mu, s, z, log_hyp), (mu, s, z, log_hyp)


def _psi1_bwd(res, ct):
    _, vjp = jax.vjp(ref.psi1_ref, *res)
    return vjp(ct)


psi1.defvjp(_psi1_fwd, _psi1_bwd)


@jax.custom_vjp
def psi2(mu, s, w, z, log_hyp):
    return psi2_pallas(mu, s, w, z, log_hyp)


def _psi2_fwd(mu, s, w, z, log_hyp):
    return psi2(mu, s, w, z, log_hyp), (mu, s, w, z, log_hyp)


def _psi2_bwd(res, ct):
    _, vjp = jax.vjp(ref.psi2_ref, *res)
    return vjp(ct)


psi2.defvjp(_psi2_fwd, _psi2_bwd)
