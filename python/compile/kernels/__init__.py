from . import ref  # noqa: F401
from .psi_rbf import psi1, psi2, psi1_pallas, psi2_pallas  # noqa: F401
