"""Pure-jnp reference implementations (correctness oracles) of the
RBF-ARD psi statistics of the Bayesian GP-LVM.

These are the quantities the paper calls phi (psi0), Psi (via Psi1) and
Phi (Psi2) — closed forms from Titsias & Lawrence (2010) for the
RBF/exponentiated-quadratic kernel with a diagonal-Gaussian variational
posterior q(x_n) = N(mu_n, diag(S_n)):

  k(x, x') = sigma2 * exp(-0.5 * sum_q alpha_q (x_q - x'_q)^2)

  psi0      = sum_n w_n * sigma2
  Psi1[n,m] = sigma2 * prod_q (alpha_q S_nq + 1)^(-1/2)
              * exp(-0.5 sum_q alpha_q (mu_nq - Z_mq)^2 / (alpha_q S_nq + 1))
  Psi2[m,m']= sum_n w_n sigma2^2 * prod_q (2 alpha_q S_nq + 1)^(-1/2)
              * exp(- sum_q [ alpha_q (Z_mq - Z_m'q)^2 / 4
                              + alpha_q (mu_nq - Zb_q)^2 / (2 alpha_q S_nq + 1) ])
  with Zb = (Z_m + Z_m') / 2.

`w` is a {0,1} padding mask over datapoints so that fixed-shape (AOT)
chunks can represent ragged tails; every reference honours it.

Setting S = 0 recovers the *exact* kernel quantities of supervised sparse
GP regression: Psi1 -> K_fu, Psi2 -> K_fu^T diag(w) K_fu, psi0 -> sum(w)*sigma2.
That limit is exercised in tests and used by the sgpr_* model functions.

The hyperparameter vector is always `log_hyp = [log sigma2, log ls_1..ls_Q]`
with alpha_q = ls_q^(-2).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def unpack_hyp(log_hyp):
    """log_hyp = [log variance, log lengthscale_1, ..., log lengthscale_Q]
    -> (sigma2, alpha) with alpha_q = 1/ls_q^2."""
    sigma2 = jnp.exp(log_hyp[0])
    alpha = jnp.exp(-2.0 * log_hyp[1:])
    return sigma2, alpha


def kuu(z, log_hyp, jitter=1e-8):
    """Exact RBF-ARD covariance among inducing inputs, with jitter.

    jitter is scaled by the signal variance (GPy convention) plus an
    absolute floor, and must match rust/src/kern/rbf.rs exactly so the
    XLA and Rust paths agree to rounding error.
    """
    sigma2, alpha = unpack_hyp(log_hyp)
    d = z[:, None, :] - z[None, :, :]
    r2 = jnp.sum(alpha * d * d, axis=-1)
    k = sigma2 * jnp.exp(-0.5 * r2)
    eye = jnp.eye(z.shape[0], dtype=z.dtype)
    return k + (jitter * sigma2 + 1e-12) * eye


def psi0_ref(w, log_hyp):
    sigma2, _ = unpack_hyp(log_hyp)
    return sigma2 * jnp.sum(w)


def psi1_ref(mu, s, z, log_hyp):
    """[N, M] expected cross-covariance <K_fu>_{q(X)} (no mask: Psi1 rows
    for padded points are garbage-in-garbage-out; the mask is applied by
    the consumer, e.g. Psi1^T (w*Y))."""
    sigma2, alpha = unpack_hyp(log_hyp)
    denom = alpha * s + 1.0                        # [N, Q]
    d = mu[:, None, :] - z[None, :, :]             # [N, M, Q]
    expo = -0.5 * jnp.sum(alpha * d * d / denom[:, None, :], axis=-1)
    coef = sigma2 * jnp.prod(denom, axis=-1) ** (-0.5)  # [N]
    return coef[:, None] * jnp.exp(expo)


def psi2_ref(mu, s, w, z, log_hyp):
    """[M, M] sum_n w_n <(K_fu)_n^T (K_fu)_n>_{q(x_n)}."""
    sigma2, alpha = unpack_hyp(log_hyp)
    denom = 2.0 * alpha * s + 1.0                  # [N, Q]
    dz = z[:, None, :] - z[None, :, :]             # [M, M, Q]
    zb = 0.5 * (z[:, None, :] + z[None, :, :])     # [M, M, Q]
    dist_zz = 0.25 * jnp.sum(alpha * dz * dz, axis=-1)   # [M, M]
    dmu = mu[:, None, None, :] - zb[None, :, :, :]       # [N, M, M, Q]
    dist_mz = jnp.sum(alpha * dmu * dmu / denom[:, None, None, :], axis=-1)
    coef = (sigma2**2) * jnp.prod(denom, axis=-1) ** (-0.5) * w   # [N]
    return jnp.einsum("n,nab->ab", coef, jnp.exp(-dist_zz[None] - dist_mz))


def psi2_ref_blocked(mu, s, w, z, log_hyp, block=256):
    """Same as psi2_ref but streaming over datapoint blocks — the memory
    shape the Pallas kernel uses; also an independent oracle."""
    n = mu.shape[0]
    m = z.shape[0]
    out = jnp.zeros((m, m), dtype=mu.dtype)
    for i in range(0, n, block):
        sl = slice(i, min(i + block, n))
        out = out + psi2_ref(mu[sl], s[sl], w[sl], z, log_hyp)
    return out
