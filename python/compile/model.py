"""Layer-2 JAX model: the distributed variational sparse-GP objective.

Three function families, mirroring the per-iteration dataflow of the
paper's §2 (and of rust/src/coordinator/):

  *_stats_fwd   worker side, distributable: a chunk of datapoints ->
                partial statistics (psi0, P = Psi1^T Y, Psi2, trYY, KL).
                Calls the Layer-1 Pallas kernels.
  bound_and_grads
                leader side, indistributable: reduced global statistics ->
                bound value F, the cotangents dF/d(stats) that are
                scattered back to workers, and the *direct* gradients
                w.r.t. the global parameters (Z, log_hyp, log_beta).
  *_stats_vjp   worker side, distributable: chunk + cotangents ->
                gradients w.r.t. the chunk-local variational parameters
                (mu, S) and this chunk's partial contribution to the
                global-parameter gradients.

Everything is pure and fixed-shape so `aot.py` can lower each function
once per shape configuration; the effective number of datapoints enters
`bound_and_grads` as the runtime scalar `n_eff = sum(w)` over all chunks,
so one `bound` artifact serves any dataset size.

All math is float64 (jax_enable_x64) to match the Rust side bit-for-bit
in cross-implementation tests.
"""

import jax
import jax.numpy as jnp

from .kernels import psi1, psi2, ref

jax.config.update("jax_enable_x64", True)

LOG2PI = 1.8378770664093453  # log(2*pi)


# ---------------------------------------------------------------------------
# Pure-jnp Cholesky + triangular solves.
#
# jnp.linalg.cholesky / scipy cho_solve lower to LAPACK custom-calls with
# the typed-FFI API on CPU, which the xla crate's xla_extension 0.5.1
# runtime rejects ("Unknown custom-call API version: API_VERSION_TYPED_FFI").
# These fori_loop formulations lower to plain HLO (while + dynamic slices),
# run on any PJRT backend, and are reverse-mode differentiable. M ≈ 100,
# so the sequential loop is irrelevant to the iteration budget.
# ---------------------------------------------------------------------------

def cholesky(a):
    """Lower-triangular Cholesky factor (column-oriented, fori_loop)."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        row_j = l[j, :]
        diag = jnp.sqrt(a[j, j] - jnp.dot(row_j, row_j))
        col = (a[:, j] - l @ row_j) / diag
        col = jnp.where(idx > j, col, 0.0).at[j].set(diag)
        return l.at[:, j].set(col)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a), unroll=False)


def solve_lower(l, b):
    """Solve L x = b (L lower-triangular), b of shape [n] or [n, k]."""
    vec = b.ndim == 1
    if vec:
        b = b[:, None]
    n = l.shape[0]

    def body(i, x):
        xi = (b[i, :] - l[i, :] @ x) / l[i, i]
        return x.at[i, :].set(xi)

    x = jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))
    return x[:, 0] if vec else x


def solve_upper_t(l, b):
    """Solve Lᵀ x = b given lower-triangular L."""
    vec = b.ndim == 1
    if vec:
        b = b[:, None]
    n = l.shape[0]

    def body(k, x):
        i = n - 1 - k
        xi = (b[i, :] - l[:, i] @ x) / l[i, i]
        return x.at[i, :].set(xi)

    x = jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))
    return x[:, 0] if vec else x


def cho_solve(l, b):
    """A⁻¹ b from the Cholesky factor L of A."""
    return solve_upper_t(l, solve_lower(l, b))


# ---------------------------------------------------------------------------
# Worker-side statistics (Bayesian GP-LVM: latent inputs q(x_n)=N(mu_n,S_n))
# ---------------------------------------------------------------------------

def bgplvm_stats_fwd(mu, s, w, y, z, log_hyp):
    """Chunk -> (psi0, P, psi2, tryy, kl); w is the {0,1} padding mask.

    P is the paper's `Psi` (an M x D matrix): Psi1^T (w ⊙ Y). Only the
    M-sized reductions leave the worker, never anything O(N).
    """
    p1 = psi1(mu, s, z, log_hyp)                     # [C, M]  (Pallas)
    wy = w[:, None] * y
    p = p1.T @ wy                                    # [M, D]
    p2 = psi2(mu, s, w, z, log_hyp)                  # [M, M]  (Pallas)
    psi0 = ref.psi0_ref(w, log_hyp)
    tryy = jnp.sum(w * jnp.sum(y * y, axis=1))
    # KL(q(x_n) || N(0, I)) for the chunk's live rows. Padded rows carry
    # (mu, S) = (0, 1) from the coordinator, so log S is finite there.
    kl = 0.5 * jnp.sum(w[:, None] * (s + mu * mu - 1.0 - jnp.log(s)))
    return psi0, p, p2, tryy, kl


def _stats_block(mu, s, w, y, z, log_hyp):
    """Reference statistics of one datapoint block (differentiable)."""
    p1 = ref.psi1_ref(mu, s, z, log_hyp)
    wy = w[:, None] * y
    p = p1.T @ wy
    p2 = ref.psi2_ref(mu, s, w, z, log_hyp)
    psi0 = ref.psi0_ref(w, log_hyp)
    tryy = jnp.sum(w * jnp.sum(y * y, axis=1))
    kl = 0.5 * jnp.sum(w[:, None] * (s + mu * mu - 1.0 - jnp.log(s)))
    return psi0, p, p2, tryy, kl


def _bgplvm_stats_fwd_ref(mu, s, w, y, z, log_hyp, block=64):
    """Statistics via a rematerialised scan over datapoint blocks — the
    formulation the VJP modules differentiate.

    Why not one monolithic expression: its backward pass streams several
    full [C, M, M] tensors through memory (the exp tensor alone is 80 MB
    at C=1024, M=100), which made the lowered vjp artifact ~2x *slower*
    than the scalar Rust loops. `lax.scan` over blocks with
    `jax.checkpoint` on the body keeps every intermediate at
    [block, M, M] (~3 MB: cache-resident), and the backward recomputes
    each block's tile instead of fetching stored residuals from RAM —
    compute is cheaper than memory traffic here. Measured 2.7x on the
    vjp artifact (EXPERIMENTS.md §Perf).
    """
    n, q = mu.shape
    b = block
    while n % b != 0:
        b -= 1
    nb = n // b
    d = y.shape[1]
    m = z.shape[0]

    @jax.checkpoint
    def body(carry, inp):
        mu_b, s_b, w_b, y_b = inp
        st = _stats_block(mu_b, s_b, w_b, y_b, z, log_hyp)
        return tuple(c + v for c, v in zip(carry, st)), None

    init = (jnp.zeros((), mu.dtype), jnp.zeros((m, d), mu.dtype),
            jnp.zeros((m, m), mu.dtype), jnp.zeros((), mu.dtype),
            jnp.zeros((), mu.dtype))
    xs = (mu.reshape(nb, b, q), s.reshape(nb, b, q), w.reshape(nb, b),
          y.reshape(nb, b, d))
    out, _ = jax.lax.scan(body, init, xs)
    return out


def bgplvm_stats_vjp(mu, s, w, y, z, log_hyp,
                     c_psi0, c_p, c_psi2, c_tryy, c_kl):
    """Pull the leader's cotangents back to this chunk's parameters.

    Returns (dmu, ds, dz_partial, dhyp_partial): the first two are owned
    by this chunk; the last two are summed across chunks by the reducer.
    """
    def f(mu_, s_, z_, lh_):
        return _bgplvm_stats_fwd_ref(mu_, s_, w, y, z_, lh_)

    _, vjp = jax.vjp(f, mu, s, z, log_hyp)
    return vjp((c_psi0, c_p, c_psi2, c_tryy, c_kl))


# ---------------------------------------------------------------------------
# Worker-side statistics (supervised sparse GP regression: X observed)
# ---------------------------------------------------------------------------

def sgpr_stats_fwd(x, w, y, z, log_hyp):
    """Supervised chunk -> (psi0, P, psi2, tryy). S == 0 collapses the
    psi statistics to the exact kernel quantities; we still route through
    the Pallas kernels (with S = 0) so the same Layer-1 code serves both
    models, exactly as GPy shares its psi-statistics code path."""
    s0 = jnp.zeros_like(x)
    p1 = psi1(x, s0, z, log_hyp)                     # == K_fu
    wy = w[:, None] * y
    p = p1.T @ wy
    p2 = psi2(x, s0, w, z, log_hyp)                  # == K_uf diag(w) K_fu
    psi0 = ref.psi0_ref(w, log_hyp)
    tryy = jnp.sum(w * jnp.sum(y * y, axis=1))
    return psi0, p, p2, tryy


def sgpr_stats_vjp(x, w, y, z, log_hyp, c_psi0, c_p, c_psi2, c_tryy):
    """Cotangents -> (dz_partial, dhyp_partial). X is observed: no dmu/ds."""
    s0 = jnp.zeros_like(x)

    def f(z_, lh_):
        st = _bgplvm_stats_fwd_ref(x, s0, w, y, z_, lh_)
        return st[0], st[1], st[2], st[3]

    _, vjp = jax.vjp(f, z, log_hyp)
    return vjp((c_psi0, c_p, c_psi2, c_tryy))


# ---------------------------------------------------------------------------
# Leader-side bound (the indistributable M x M core)
# ---------------------------------------------------------------------------

def bound_from_stats(psi0, p, psi2_, tryy, kl, z, log_hyp, log_beta, n_eff):
    """Variational lower bound F (paper eq. 3 / 4) from reduced statistics.

    A = K_uu + beta * Psi2 (+ jitter); P = Psi1^T Y reduced over all chunks.

      F = D/2 (N log beta - N log 2pi + logdet K_uu - logdet A)
          - beta/2 trYY + beta^2/2 tr(P^T A^{-1} P)
          - beta D/2 psi0 + beta D/2 tr(K_uu^{-1} Psi2) - KL
    """
    d = p.shape[1]
    beta = jnp.exp(log_beta)
    kuu = ref.kuu(z, log_hyp)
    a = kuu + beta * psi2_

    lk = cholesky(kuu)
    la = cholesky(a)
    logdet_kuu = 2.0 * jnp.sum(jnp.log(jnp.diagonal(lk)))
    logdet_a = 2.0 * jnp.sum(jnp.log(jnp.diagonal(la)))

    ainv_p = cho_solve(la, p)            # [M, D]
    kuuinv_psi2 = cho_solve(lk, psi2_)   # [M, M]

    f = (0.5 * d * (n_eff * log_beta - n_eff * LOG2PI + logdet_kuu - logdet_a)
         - 0.5 * beta * tryy
         + 0.5 * beta * beta * jnp.sum(p * ainv_p)
         - 0.5 * beta * d * psi0
         + 0.5 * beta * d * jnp.trace(kuuinv_psi2)
         - kl)
    return f


def bound_and_grads(psi0, p, psi2_, tryy, kl, z, log_hyp, log_beta, n_eff):
    """F plus gradients w.r.t. every input except n_eff.

    The gradients w.r.t. (psi0, p, psi2, tryy, kl) are the cotangents the
    coordinator broadcasts back to the workers; the gradients w.r.t.
    (z, log_hyp, log_beta) are the *direct* terms, to which the workers'
    partial dz/dhyp contributions are added by the reducer.
    """
    def f(psi0_, p_, psi2__, tryy_, kl_, z_, lh_, lb_):
        return bound_from_stats(psi0_, p_, psi2__, tryy_, kl_, z_, lh_, lb_,
                                n_eff)

    val, grads = jax.value_and_grad(f, argnums=tuple(range(8)))(
        psi0, p, psi2_, tryy, kl, z, log_hyp, log_beta)
    return (val,) + grads


# ---------------------------------------------------------------------------
# Whole-model references (used by tests and by aot smoke checks)
# ---------------------------------------------------------------------------

def bgplvm_bound_full(mu, s, y, z, log_hyp, log_beta):
    """Single-machine bound over a full (unpadded) dataset — the oracle the
    distributed implementation must match exactly."""
    w = jnp.ones(mu.shape[0], dtype=mu.dtype)
    psi0, p, p2, tryy, kl = bgplvm_stats_fwd(mu, s, w, y, z, log_hyp)
    return bound_from_stats(psi0, p, p2, tryy, kl, z, log_hyp, log_beta,
                            jnp.sum(w))


def sgpr_bound_full(x, y, z, log_hyp, log_beta):
    w = jnp.ones(x.shape[0], dtype=x.dtype)
    psi0, p, p2, tryy = sgpr_stats_fwd(x, w, y, z, log_hyp)
    return bound_from_stats(psi0, p, p2, tryy, jnp.asarray(0.0, x.dtype),
                            z, log_hyp, log_beta, jnp.sum(w))
