"""AOT lowering: JAX (L2, calling the Pallas L1 kernels) -> HLO text
artifacts consumed by the Rust runtime (rust/src/runtime/).

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Each shape configuration produces five modules:

  <cfg>_bgplvm_fwd    (mu,s,w,y,z,log_hyp) -> (psi0,P,psi2,tryy,kl)
  <cfg>_bgplvm_vjp    (... , cotangents)   -> (dmu,ds,dz,dhyp)
  <cfg>_sgpr_fwd      (x,w,y,z,log_hyp)    -> (psi0,P,psi2,tryy)
  <cfg>_sgpr_vjp      (... , cotangents)   -> (dz,dhyp)
  <cfg>_bound         (stats..,z,log_hyp,log_beta,n_eff)
                      -> (f, c_psi0,c_p,c_psi2,c_tryy,c_kl, dz,dhyp,dbeta)

plus `manifest.json` describing every module's inputs/outputs (name,
shape, dtype) in positional order — the Rust side validates against it.

Usage:  python -m compile.aot --out-dir ../artifacts [--configs name,...]
The build is make-driven and incremental at the Makefile level.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

F64 = jnp.float64


class Config:
    """A static shape configuration: chunk size C, inducing count M,
    latent dim Q, output dim D."""

    def __init__(self, name, c, m, q, d):
        self.name, self.c, self.m, self.q, self.d = name, c, m, q, d

    @property
    def tag(self):
        return f"c{self.c}_m{self.m}_q{self.q}_d{self.d}"


# Shipped configurations. `paper` matches the paper's experiment
# (M=100, Q=1, D=3, chunked at 1024); `test` is the small config the
# integration tests use; the others serve the examples.
CONFIGS = {
    "test": Config("test", 64, 16, 2, 3),
    "paper": Config("paper", 1024, 100, 1, 3),
    "quickstart": Config("quickstart", 256, 16, 1, 1),
    "mrd": Config("mrd", 256, 20, 3, 4),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple so the Rust
    side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), F64)


def module_specs(cfg):
    """Positional input/output specs for every module of one config."""
    c, m, q, d = cfg.c, cfg.m, cfg.q, cfg.d
    scalar = []
    stats_out = [("psi0", scalar), ("p", [m, d]), ("psi2", [m, m]),
                 ("tryy", scalar)]
    cts_in = [("c_psi0", scalar), ("c_p", [m, d]), ("c_psi2", [m, m]),
              ("c_tryy", scalar)]
    return {
        "bgplvm_fwd": {
            "fn": model.bgplvm_stats_fwd,
            "in": [("mu", [c, q]), ("s", [c, q]), ("w", [c]), ("y", [c, d]),
                   ("z", [m, q]), ("log_hyp", [q + 1])],
            "out": stats_out + [("kl", scalar)],
        },
        "bgplvm_vjp": {
            "fn": model.bgplvm_stats_vjp,
            "in": [("mu", [c, q]), ("s", [c, q]), ("w", [c]), ("y", [c, d]),
                   ("z", [m, q]), ("log_hyp", [q + 1])]
                  + cts_in + [("c_kl", scalar)],
            "out": [("dmu", [c, q]), ("ds", [c, q]), ("dz", [m, q]),
                    ("dhyp", [q + 1])],
        },
        "sgpr_fwd": {
            "fn": model.sgpr_stats_fwd,
            "in": [("x", [c, q]), ("w", [c]), ("y", [c, d]),
                   ("z", [m, q]), ("log_hyp", [q + 1])],
            "out": stats_out,
        },
        "sgpr_vjp": {
            "fn": model.sgpr_stats_vjp,
            "in": [("x", [c, q]), ("w", [c]), ("y", [c, d]),
                   ("z", [m, q]), ("log_hyp", [q + 1])] + cts_in,
            "out": [("dz", [m, q]), ("dhyp", [q + 1])],
        },
        "bound": {
            "fn": model.bound_and_grads,
            "in": [("psi0", scalar), ("p", [m, d]), ("psi2", [m, m]),
                   ("tryy", scalar), ("kl", scalar), ("z", [m, q]),
                   ("log_hyp", [q + 1]), ("log_beta", scalar),
                   ("n_eff", scalar)],
            "out": [("f", scalar), ("c_psi0", scalar), ("c_p", [m, d]),
                    ("c_psi2", [m, m]), ("c_tryy", scalar), ("c_kl", scalar),
                    ("dz", [m, q]), ("dhyp", [q + 1]), ("dbeta", scalar)],
        },
    }


def lower_config(cfg, out_dir):
    entries = []
    for mod_name, ms in module_specs(cfg).items():
        in_specs = [spec(shape) for _, shape in ms["in"]]
        lowered = jax.jit(ms["fn"], keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}_{mod_name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entries.append({
            "config": cfg.name,
            "tag": cfg.tag,
            "module": mod_name,
            "file": fname,
            "dims": {"c": cfg.c, "m": cfg.m, "q": cfg.q, "d": cfg.d},
            "inputs": [{"name": n, "shape": s} for n, s in ms["in"]],
            "outputs": [{"name": n, "shape": s} for n, s in ms["out"]],
            "dtype": "f64",
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  lowered {fname}  ({len(text)} chars)")
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="test,paper,quickstart,mrd")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        print(f"config {name} (tag {cfg.tag}):")
        entries.extend(lower_config(cfg, args.out_dir))

    manifest = {"version": 1, "dtype": "f64", "modules": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} modules to {args.out_dir}")


if __name__ == "__main__":
    main()
