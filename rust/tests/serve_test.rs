//! Sharded-serving equivalence + posterior correctness.
//!
//! Five layers of guarantees:
//! 1. the single-node `Posterior` agrees with the dense O(N³) GP oracle
//!    when the inducing set is the full training set (where the
//!    variational sparse posterior is exact);
//! 2. `DistributedPosterior` reproduces the single-node `Posterior`
//!    **bit for bit** for every cluster size 1–9 and both CPU backends
//!    (prediction rows are independent, so sharding reorders nothing);
//! 3. the distributed **stats-only pass** (the STATS verb) reproduces
//!    the serial chunked construction `sgpr_stats_fwd_chunked` bit for
//!    bit for every cluster size 1–9 and both CPU backends — each chunk
//!    owns a slot of the reduction wire, so the tree reduction only
//!    adds exact zeros and the leader's chunk-order fold is
//!    rank-count-invariant;
//! 4. the training→serving hand-off (`Engine::train_then_predict`)
//!    serves exactly the posterior implied by the fitted parameters,
//!    with no leader-side full-data recompute;
//! 5. a **posterior hot-swap** mid-session (`refit_and_swap`) produces
//!    predictions bit-identical to a fresh session opened directly at
//!    the new parameters, and the serving protocol survives a
//!    malformed shard wire as a clean error.

use gpparallel::collectives::Cluster;
use gpparallel::baselines::DenseGp;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use gpparallel::coordinator::{Backend, DistributedEvaluator, Engine, EngineConfig,
                              OptChoice, ParallelCpuBackend, Partition, Problem,
                              RustCpuBackend};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::math::predict::PosteriorCore;
use gpparallel::math::stats::{sgpr_stats_fwd, sgpr_stats_fwd_chunked, Stats};
use gpparallel::models::{Posterior, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::{Prop, Rng64};

/// Sparse posterior with Z = X must match the exact dense GP (mean and
/// variance), since the variational approximation is tight there.
///
/// Training inputs are a jittered grid (guaranteed point separation):
/// with duplicate-prone random inputs, K(X, X) is numerically singular
/// at Z = X and the comparison measures conditioning, not correctness.
/// The 1e-4 tolerance carries ~60x margin over the worst error observed
/// in a 1000-case float simulation of this exact algorithm.
#[test]
fn prop_posterior_matches_dense_gp_at_full_inducing() {
    Prop::new("posterior_vs_dense").cases(10).run(|rng| {
        let n = 12 + (rng.next_u64() % 8) as usize;
        let q = 1 + (rng.next_u64() % 2) as usize;
        let d = 1 + (rng.next_u64() % 2) as usize;
        let mut x = Mat::zeros(n, q);
        for qq in 0..q {
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            for i in 0..n {
                let base = -2.0 + 4.0 * perm[i] as f64 / (n - 1) as f64;
                x[(i, qq)] = base + rng.uniform_range(-0.05, 0.05);
            }
        }
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let kern = RbfArd::new(
            rng.uniform_range(0.5, 1.5),
            (0..q).map(|_| rng.uniform_range(0.5, 1.0)).collect(),
        );
        let beta = rng.uniform_range(5.0, 20.0); // moderate noise: well-conditioned
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let sparse = Posterior::new(kern.clone(), x.clone(), beta, &st).unwrap();
        let dense = DenseGp::with_params(x.clone(), &y, kern, beta).unwrap();

        let xstar = Mat::from_fn(7, q, |_, _| rng.uniform_range(-2.0, 2.0));
        let (sm, sv) = sparse.predict(&xstar);
        let (dm, dv) = dense.predict(&xstar);
        assert!(sm.max_abs_diff(&dm) < 1e-4,
                "mean mismatch: {}", sm.max_abs_diff(&dm));
        for (a, b) in sv.iter().zip(&dv) {
            assert!((a - b).abs() < 1e-4, "var mismatch: {a} vs {b}");
        }
    });
}

fn toy_core(seed: u64, n: usize, m: usize, q: usize, d: usize) -> PosteriorCore {
    let mut rng = Rng64::new(seed);
    let x = Mat::from_fn(n, q, |_, _| rng.normal());
    let y = Mat::from_fn(n, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::new(1.4, (0..q).map(|_| rng.uniform_range(0.7, 1.3)).collect());
    let w = vec![1.0; n];
    let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
    PosteriorCore::new(kern, z, 15.0, &st).unwrap()
}

fn backend_for(kind: BackendKind) -> Box<dyn Backend> {
    match kind {
        BackendKind::RustCpu => Box::new(RustCpuBackend),
        BackendKind::ParallelCpu { threads } => Box::new(ParallelCpuBackend::new(threads)),
        BackendKind::Xla => unreachable!("not exercised here"),
    }
}

/// The acceptance-criteria matrix: sharded output must be bit-identical
/// to the single-node posterior for ranks 1–9 on both CPU backends,
/// including ragged batches (Nt not divisible by the chunk) and batches
/// smaller than the rank count.
#[test]
fn distributed_matches_single_node_ranks_1_to_9() {
    let core = toy_core(7, 60, 10, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(8);
    let batches: Vec<Mat> = [37usize, 3, 37]
        .iter()
        .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
        .collect();
    let expect: Vec<(Mat, Vec<f64>)> = batches.iter().map(|b| single.predict(b)).collect();

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let (core_ref, batches_ref) = (&core, &batches);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = backend_for(kind);
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                             &mut comm);
                    let out: Vec<(Mat, Vec<f64>)> = batches_ref
                        .iter()
                        .map(|b| dp.predict(&mut comm, backend.as_mut(), b).unwrap())
                        .collect();
                    dp.finish(&mut comm);
                    Some(out)
                } else {
                    worker_serve(&mut comm, backend.as_mut()).unwrap();
                    None
                }
            });
            let got = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in got.iter().zip(&expect).enumerate() {
                assert!(gm.max_abs_diff(em) == 0.0,
                        "{kind:?} size {size} batch {i}: mean differs");
                assert_eq!(gv, ev, "{kind:?} size {size} batch {i}: var differs");
            }
        }
    }
}

/// Training → serving hand-off on one cluster: `train_then_predict`
/// must serve exactly the posterior implied by the fitted parameters
/// (cross-checked against a freshly built single-node posterior), for a
/// worker count with ragged chunk assignment. The serving posterior is
/// now built by the distributed stats-only pass, whose summation
/// discipline is the serial **chunked** construction at the engine's
/// chunk size — so that is the single-node reference to rebuild with.
#[test]
fn train_then_predict_matches_single_node_posterior() {
    let spec = SyntheticSpec { n: 96, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 5);
    let x = ds.x.clone().unwrap();
    let cfg = EngineConfig {
        workers: 3,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 5, ..Default::default() }),
        pipeline: true,
        verbose: false,
    };
    let problem = SparseGpRegression::problem(&x, &ds.y, 8, "test", 5);
    let engine = Engine::new(problem, cfg).unwrap();

    let mut rng = Rng64::new(6);
    let xstar = Mat::from_fn(29, 1, |_, _| rng.normal());
    let (result, mean, var) = engine.train_then_predict(&xstar, 8).unwrap();
    assert!(result.f.is_finite());
    assert_eq!(mean.rows(), 29);
    assert_eq!(var.len(), 29);

    // rebuild the posterior single-node from the same fitted parameters
    // and the same chunk-ordered statistics discipline
    let fitted = &result.fitted;
    let w = vec![1.0; x.rows()];
    let st = sgpr_stats_fwd_chunked(&fitted.kerns[0], &x, &w, &ds.y, &fitted.zs[0], 16);
    let single = Posterior::new(fitted.kerns[0].clone(), fitted.zs[0].clone(),
                                fitted.betas[0], &st).unwrap();
    let (em, ev) = single.predict(&xstar);
    assert!(mean.max_abs_diff(&em) == 0.0, "served mean differs from single-node");
    assert_eq!(var, ev, "served variance differs from single-node");

    // and the chunked construction matches the old monolithic one to
    // rounding error (sanity that the discipline change is benign)
    let st_full = sgpr_stats_fwd(&fitted.kerns[0], &x, &w, &ds.y, &fitted.zs[0]);
    assert!(st.p.max_abs_diff(&st_full.p) < 1e-10);
    assert!(st.psi2.max_abs_diff(&st_full.psi2) < 1e-10);
}

fn eval_cfg(workers: usize, chunk: usize, backend: BackendKind) -> EngineConfig {
    EngineConfig {
        workers,
        chunk,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs::default()),
        pipeline: true,
        verbose: false,
    }
}

/// Run a distributed stats-only pass at `x0` on a `size`-rank cluster.
fn run_stats_pass(problem: &Problem, x0: &[f64], chunk: usize, size: usize,
                  backend: BackendKind) -> Stats {
    let part = Partition::new(problem.n(), chunk, size);
    let cfg = eval_cfg(size, chunk, backend);
    let results = Cluster::run(size, |comm| {
        let mut ev = DistributedEvaluator::new(problem, &cfg, &part, comm).unwrap();
        if ev.rank() == 0 {
            let st = ev.stats_pass(x0).unwrap();
            ev.finish();
            Some(st)
        } else {
            ev.serve().unwrap();
            None
        }
    });
    results.into_iter().next().unwrap().expect("leader stats")
}

/// Assert two stats are bit-identical (as observed by `==`).
fn assert_stats_identical(got: &Stats, want: &Stats, ctx: &str) {
    assert_eq!(got.psi0, want.psi0, "{ctx}: psi0");
    assert_eq!(got.tryy, want.tryy, "{ctx}: tryy");
    assert_eq!(got.kl, want.kl, "{ctx}: kl");
    assert_eq!(got.n_eff, want.n_eff, "{ctx}: n_eff");
    assert!(got.p.max_abs_diff(&want.p) == 0.0, "{ctx}: P");
    assert!(got.psi2.max_abs_diff(&want.psi2) == 0.0, "{ctx}: Psi2");
}

/// The STATS-parity acceptance matrix: the distributed stats-only pass
/// must be **bit-identical** to the serial chunked construction
/// (`sgpr_stats_fwd_chunked` at the engine's chunk size) for every
/// cluster size 1–9 and both CPU backends (N=77, C=8 → 10 chunks with
/// a ragged, padded tail), plus a cluster with more ranks than chunks
/// (chunkless ranks must contribute exact zeros and stay in lockstep).
#[test]
fn stats_pass_parity_ranks_1_to_9() {
    let spec = SyntheticSpec { n: 77, q: 2, d: 3, ..Default::default() };
    let ds = generate_supervised(&spec, 11);
    let x = ds.x.clone().unwrap();
    let chunk = 8;
    let problem = SparseGpRegression::problem(&x, &ds.y, 6, "test", 11);
    let x0 = problem.initial_params();

    // the serial reference, through the same log-hyp round-trip the
    // broadcast parameters take
    let kern = RbfArd::from_log_hyp(&problem.views[0].kern0.to_log_hyp());
    let w = vec![1.0; x.rows()];
    let want = sgpr_stats_fwd_chunked(&kern, &x, &w, &ds.y, &problem.views[0].z0, chunk);

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let got = run_stats_pass(&problem, &x0, chunk, size, kind);
            assert_stats_identical(&got, &want, &format!("{kind:?} size {size}"));
        }
    }

    // more ranks than chunks: N=20, C=8 → 3 chunks over 7 ranks
    let spec = SyntheticSpec { n: 20, q: 2, d: 3, ..Default::default() };
    let ds = generate_supervised(&spec, 12);
    let x = ds.x.clone().unwrap();
    let problem = SparseGpRegression::problem(&x, &ds.y, 5, "test", 12);
    let x0 = problem.initial_params();
    let kern = RbfArd::from_log_hyp(&problem.views[0].kern0.to_log_hyp());
    let w = vec![1.0; x.rows()];
    let want = sgpr_stats_fwd_chunked(&kern, &x, &w, &ds.y, &problem.views[0].z0, chunk);
    let got = run_stats_pass(&problem, &x0, chunk, 7, BackendKind::RustCpu);
    assert_stats_identical(&got, &want, "chunkless ranks");
}

/// Posterior hot-swap: a serving session opened at parameters A and
/// `refit_and_swap`ped to parameters B must serve predictions
/// **bit-identical** to (a) a fresh session opened directly at B and
/// (b) the single-node posterior built from the serial chunked stats at
/// B — at several cluster sizes. The pre-swap batch must differ, so the
/// swap demonstrably took effect.
#[test]
fn hot_swap_matches_fresh_session_at_new_params() {
    let spec = SyntheticSpec { n: 61, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 17);
    let x = ds.x.clone().unwrap();
    let chunk = 8;
    let m = 7;
    let problem = SparseGpRegression::problem(&x, &ds.y, m, "test", 17);
    let xa = problem.initial_params();
    // layout (q=1): [log σ², log ℓ, log β, Z (m)] — perturb all four kinds
    let mut xb = xa.clone();
    xb[0] += 0.3;
    xb[1] -= 0.25;
    xb[2] += 0.2;
    xb[3] += 0.1;

    let mut rng = Rng64::new(18);
    let xstar = Mat::from_fn(23, 1, |_, _| rng.normal());

    // single-node expectation at B (serial chunked stats discipline)
    let kern_b = RbfArd::from_log_hyp(&xb[0..2]);
    let z_b = Mat::from_vec(m, 1, xb[3..3 + m].to_vec());
    let w = vec![1.0; x.rows()];
    let st_b = sgpr_stats_fwd_chunked(&kern_b, &x, &w, &ds.y, &z_b, chunk);
    let single_b = Posterior::new(kern_b, z_b, xb[2].exp(), &st_b).unwrap();
    let (em, ev) = single_b.predict(&xstar);

    for size in [1usize, 2, 5] {
        let part = Partition::new(problem.n(), chunk, size);
        let cfg = eval_cfg(size, chunk, BackendKind::RustCpu);

        // session opened at A, served, hot-swapped to B, served again
        let (p, xa_r, xb_r, xs) = (&problem, &xa, &xb, &xstar);
        let results = Cluster::run(size, |comm| {
            let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
            if ev.rank() == 0 {
                let core = ev.posterior_core_at(xa_r).unwrap();
                ev.begin_serving(core, 4).unwrap();
                let pre = ev.predict_sharded(xs).unwrap();
                ev.refit_and_swap(xb_r).unwrap();
                let post = ev.predict_sharded(xs).unwrap();
                ev.end_serving().unwrap();
                ev.finish();
                Some((pre, post))
            } else {
                ev.serve().unwrap();
                None
            }
        });
        let (pre, post) = results.into_iter().next().unwrap().expect("leader output");

        // fresh session opened directly at B
        let results = Cluster::run(size, |comm| {
            let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
            if ev.rank() == 0 {
                let core = ev.posterior_core_at(xb_r).unwrap();
                ev.begin_serving(core, 4).unwrap();
                let out = ev.predict_sharded(xs).unwrap();
                ev.end_serving().unwrap();
                ev.finish();
                Some(out)
            } else {
                ev.serve().unwrap();
                None
            }
        });
        let fresh = results.into_iter().next().unwrap().expect("leader output");

        assert!(post.0.max_abs_diff(&fresh.0) == 0.0,
                "size {size}: post-swap mean != fresh session at B");
        assert_eq!(post.1, fresh.1, "size {size}: post-swap var != fresh session");
        assert!(post.0.max_abs_diff(&em) == 0.0,
                "size {size}: post-swap mean != single-node at B");
        assert_eq!(post.1, ev, "size {size}: post-swap var != single-node");
        assert!(pre.0.max_abs_diff(&post.0) > 0.0,
                "size {size}: the swap changed nothing — test is vacuous");
    }
}

/// A malformed (truncated) shard wire must surface as a fail-flagged
/// gather + a clean worker error, not a `Mat::from_vec` panic or a
/// silently wrong shard. The leader half of the batch protocol is
/// hand-rolled so a short wire can be injected (sub-command 1.0 =
/// PREDICT, tag 300 = the X* shard channel).
#[test]
fn malformed_shard_wire_is_a_clean_error() {
    let core = toy_core(13, 40, 6, 2, 2);
    let core_ref = &core;
    let results = Cluster::run(2, move |mut comm| {
        if comm.rank() == 0 {
            let mut dp = DistributedPosterior::leader(core_ref.clone(), 4, &mut comm);
            // announce an 8-row batch: rank 1 owns rows 4..8 and expects
            // 4 rows × Q=2 = 8 wire elements; ship 3 instead
            comm.bcast(0, vec![1.0, 8.0]);
            comm.send(1, 300, &[0.5; 3]);
            let gathered = comm.gather(0, &[0.0]).expect("root");
            dp.finish(&mut comm);
            Some(gathered[1].clone())
        } else {
            let mut backend = RustCpuBackend;
            let err = worker_serve(&mut comm, &mut backend)
                .expect_err("short wire must be an error");
            assert!(format!("{err:#}").contains("shard wire length"),
                    "unhelpful error: {err:#}");
            None
        }
    });
    // the worker reported the failure through the flag payload, keeping
    // the gather in lockstep
    assert_eq!(results[0].as_ref().expect("leader"), &vec![1.0]);
}

/// The stats-only pass must refuse variational problems on the leader
/// *before* any broadcast, so the cluster stays in lockstep and shuts
/// down cleanly.
#[test]
fn stats_pass_refuses_variational_problems() {
    use gpparallel::models::BayesianGplvm;
    let spec = SyntheticSpec { n: 24, q: 1, d: 2, ..Default::default() };
    let ds = gpparallel::data::synthetic::generate(&spec, 3);
    let problem = BayesianGplvm::problem(&ds.y, 1, 6, "test", 3);
    let x0 = problem.initial_params();
    let part = Partition::new(problem.n(), 8, 2);
    let cfg = eval_cfg(2, 8, BackendKind::RustCpu);
    let (p, x0_r) = (&problem, &x0);
    let results = Cluster::run(2, |comm| {
        let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
        if ev.rank() == 0 {
            let err = ev.stats_pass(x0_r).expect_err("variational must refuse");
            ev.finish();
            Some(format!("{err:#}"))
        } else {
            ev.serve().unwrap();
            None
        }
    });
    let msg = results[0].as_ref().expect("leader");
    assert!(msg.contains("supervised"), "unhelpful error: {msg}");
}

/// A variational problem must refuse the serving hand-off with a clear
/// error instead of desyncing the cluster.
#[test]
fn train_then_predict_rejects_unsupervised_problems() {
    use gpparallel::models::BayesianGplvm;
    let spec = SyntheticSpec { n: 32, q: 1, d: 2, ..Default::default() };
    let ds = gpparallel::data::synthetic::generate(&spec, 2);
    let problem = BayesianGplvm::problem(&ds.y, 1, 8, "test", 2);
    let cfg = EngineConfig {
        workers: 2,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 2, ..Default::default() }),
        pipeline: true,
        verbose: false,
    };
    let engine = Engine::new(problem, cfg).unwrap();
    let xstar = Mat::from_fn(4, 1, |i, _| i as f64);
    let err = engine.train_then_predict(&xstar, 4).err().expect("must refuse");
    assert!(format!("{err}").contains("supervised"), "unhelpful error: {err}");
}
