//! Sharded-serving equivalence + posterior correctness.
//!
//! Seven layers of guarantees:
//! 1. the single-node `Posterior` agrees with the dense O(N³) GP oracle
//!    when the inducing set is the full training set (where the
//!    variational sparse posterior is exact);
//! 2. `DistributedPosterior` reproduces the single-node `Posterior`
//!    **bit for bit** for every cluster size 1–9 and both CPU backends
//!    (prediction rows are independent, so sharding reorders nothing);
//! 3. **streamed** serving (`predict_stream`: batch k+1 issued before
//!    batch k's gather) is bit-identical to the sequential path for
//!    every cluster size 1–9 and both CPU backends, including ragged,
//!    tiny and empty batches, a mid-stream hot-swap, and a fail-flagged
//!    batch inside the stream;
//! 4. the distributed **stats-only pass** (the STATS verb) reproduces
//!    the serial chunked construction `sgpr_stats_fwd_chunked` bit for
//!    bit for every cluster size 1–9 and both CPU backends — each chunk
//!    owns a slot of the reduction wire, so the tree reduction only
//!    adds exact zeros and the leader's chunk-order fold is
//!    rank-count-invariant;
//! 5. the training→serving hand-off (`Engine::train_then_predict`)
//!    serves the posterior implied by the fitted parameters with no
//!    leader-side full-data recompute — and when the final accepted
//!    evaluation's captured statistics match, with **zero extra
//!    collective rounds** (asserted via the cluster message counters);
//! 6. a **posterior hot-swap** mid-session (`refit_and_swap`) produces
//!    predictions bit-identical to a fresh session opened directly at
//!    the new parameters, and the serving protocol survives a
//!    malformed shard wire as a clean error;
//! 7. streamed and sequential `Engine`-level serving agree bit for bit
//!    (`train_then_predict_stream` vs `train_then_predict`).

use gpparallel::collectives::protocol::TAG_XSTAR;
use gpparallel::collectives::Cluster;
use gpparallel::baselines::DenseGp;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use gpparallel::coordinator::{Backend, DistributedEvaluator, Engine, EngineConfig,
                              OptChoice, ParallelCpuBackend, Partition, Problem,
                              RustCpuBackend};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::math::predict::PosteriorCore;
use gpparallel::math::stats::{sgpr_stats_fwd, sgpr_stats_fwd_chunked, Stats};
use gpparallel::models::{Posterior, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::{Prop, Rng64};

/// Sparse posterior with Z = X must match the exact dense GP (mean and
/// variance), since the variational approximation is tight there.
///
/// Training inputs are a jittered grid (guaranteed point separation):
/// with duplicate-prone random inputs, K(X, X) is numerically singular
/// at Z = X and the comparison measures conditioning, not correctness.
/// The 1e-4 tolerance carries ~60x margin over the worst error observed
/// in a 1000-case float simulation of this exact algorithm.
#[test]
fn prop_posterior_matches_dense_gp_at_full_inducing() {
    Prop::new("posterior_vs_dense").cases(10).run(|rng| {
        let n = 12 + (rng.next_u64() % 8) as usize;
        let q = 1 + (rng.next_u64() % 2) as usize;
        let d = 1 + (rng.next_u64() % 2) as usize;
        let mut x = Mat::zeros(n, q);
        for qq in 0..q {
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            for i in 0..n {
                let base = -2.0 + 4.0 * perm[i] as f64 / (n - 1) as f64;
                x[(i, qq)] = base + rng.uniform_range(-0.05, 0.05);
            }
        }
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let kern = RbfArd::new(
            rng.uniform_range(0.5, 1.5),
            (0..q).map(|_| rng.uniform_range(0.5, 1.0)).collect(),
        );
        let beta = rng.uniform_range(5.0, 20.0); // moderate noise: well-conditioned
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let sparse = Posterior::new(kern.clone(), x.clone(), beta, &st).unwrap();
        let dense = DenseGp::with_params(x.clone(), &y, kern, beta).unwrap();

        let xstar = Mat::from_fn(7, q, |_, _| rng.uniform_range(-2.0, 2.0));
        let (sm, sv) = sparse.predict(&xstar);
        let (dm, dv) = dense.predict(&xstar);
        assert!(sm.max_abs_diff(&dm) < 1e-4,
                "mean mismatch: {}", sm.max_abs_diff(&dm));
        for (a, b) in sv.iter().zip(&dv) {
            assert!((a - b).abs() < 1e-4, "var mismatch: {a} vs {b}");
        }
    });
}

fn toy_core(seed: u64, n: usize, m: usize, q: usize, d: usize) -> PosteriorCore {
    let mut rng = Rng64::new(seed);
    let x = Mat::from_fn(n, q, |_, _| rng.normal());
    let y = Mat::from_fn(n, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::new(1.4, (0..q).map(|_| rng.uniform_range(0.7, 1.3)).collect());
    let w = vec![1.0; n];
    let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
    PosteriorCore::new(kern, z, 15.0, &st).unwrap()
}

fn backend_for(kind: BackendKind) -> Box<dyn Backend> {
    match kind {
        BackendKind::RustCpu => Box::new(RustCpuBackend),
        BackendKind::ParallelCpu { threads } => Box::new(ParallelCpuBackend::new(threads)),
        BackendKind::Xla => unreachable!("not exercised here"),
    }
}

/// The acceptance-criteria matrix: sharded output must be bit-identical
/// to the single-node posterior for ranks 1–9 on both CPU backends,
/// including ragged batches (Nt not divisible by the chunk) and batches
/// smaller than the rank count.
#[test]
fn distributed_matches_single_node_ranks_1_to_9() {
    let core = toy_core(7, 60, 10, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(8);
    let batches: Vec<Mat> = [37usize, 3, 37]
        .iter()
        .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
        .collect();
    let expect: Vec<(Mat, Vec<f64>)> = batches.iter().map(|b| single.predict(b)).collect();

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let (core_ref, batches_ref) = (&core, &batches);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = backend_for(kind);
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                             &mut comm).unwrap();
                    let out: Vec<(Mat, Vec<f64>)> = batches_ref
                        .iter()
                        .map(|b| dp.predict(&mut comm, backend.as_mut(), b).unwrap())
                        .collect();
                    dp.finish(&mut comm).unwrap();
                    Some(out)
                } else {
                    worker_serve(&mut comm, backend.as_mut()).unwrap();
                    None
                }
            });
            let got = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in got.iter().zip(&expect).enumerate() {
                assert!(gm.max_abs_diff(em) == 0.0,
                        "{kind:?} size {size} batch {i}: mean differs");
                assert_eq!(gv, ev, "{kind:?} size {size} batch {i}: var differs");
            }
        }
    }
}

/// Regression for the leader-side compute/gather overlap: before
/// computing its own shard, the leader now drains already-arrived worker
/// payloads into its parked queue (`Comm::drain_pending`), so delivery
/// overlaps the rank-0 compute instead of queueing behind it. The drain
/// moves messages — it never sends — so the session's total message
/// count must be exactly the protocol formula (open + per-batch
/// announce/shards/gather + close), and the assembled output must stay
/// bit-identical to the single-node posterior.
#[test]
fn leader_overlap_drain_sends_nothing_and_stays_bit_identical() {
    let core = toy_core(71, 60, 10, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(72);
    let batches: Vec<Mat> = [17usize, 3, 9]
        .iter()
        .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
        .collect();
    let expect: Vec<(Mat, Vec<f64>)> =
        batches.iter().map(|b| single.predict(b)).collect();
    let rpc = 4usize;

    for size in [2usize, 3, 5] {
        let (core_ref, bs) = (&core, &batches);
        let results = Cluster::run(size, move |mut comm| {
            let mut backend = RustCpuBackend;
            let out = if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), rpc,
                                                          &mut comm).unwrap();
                let out: Vec<(Mat, Vec<f64>)> = bs
                    .iter()
                    .map(|b| dp.predict(&mut comm, &mut backend, b).unwrap())
                    .collect();
                dp.finish(&mut comm).unwrap();
                Some(out)
            } else {
                worker_serve(&mut comm, &mut backend).unwrap();
                None
            };
            // linear fan-in sync: when the root returns, every rank's
            // prior sends are on the shared counter (a tree barrier
            // leaks in-flight forwards, so the count would be racy)
            comm.reduce_sum_linear(0, &[]).unwrap();
            out.map(|o| (o, comm.messages_sent()))
        });
        let (got, messages) = results[0].as_ref().expect("leader output");
        for (i, ((gm, gv), (em, ev))) in got.iter().zip(&expect).enumerate() {
            assert!(gm.max_abs_diff(em) == 0.0, "size {size} batch {i}: mean");
            assert_eq!(gv, ev, "size {size} batch {i}: var");
        }
        // open bcast + per-batch (announce bcast + shard sends + gather)
        // + DONE bcast + the sync reduce itself; each tree bcast and
        // each gather moves exactly P−1 messages cluster-wide
        let p1 = (size - 1) as u64;
        let shard_sends: u64 = batches
            .iter()
            .map(|b| {
                let part = Partition::new(b.rows(), rpc, size);
                (1..size).filter(|&r| part.worker_span(r).is_some()).count() as u64
            })
            .sum();
        let want = p1 * (3 + 2 * batches.len() as u64) + shard_sends;
        assert_eq!(*messages, want,
                   "size {size}: the overlap drain must not add or drop messages");
    }
}

/// Training → serving hand-off on one cluster: `train_then_predict`
/// must serve the posterior implied by the fitted parameters
/// (cross-checked against a freshly built single-node posterior), for a
/// worker count with ragged chunk assignment.
///
/// Reference discipline: when the final accepted evaluation's captured
/// statistics match the fitted parameters, the serving posterior is
/// built from the *training* reduction (rank partials summed over the
/// tree); otherwise from the slot-wire STATS round (global chunk-order
/// fold). The two differ only in float summation order, so the serial
/// chunked single-node reference matches to reduction-order tolerance
/// at several ranks — and **bit for bit** on a single-rank engine,
/// where both folds are the serial chunk-order sum.
#[test]
fn train_then_predict_matches_single_node_posterior() {
    let spec = SyntheticSpec { n: 96, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 5);
    let x = ds.x().unwrap();
    let mut rng = Rng64::new(6);
    let xstar = Mat::from_fn(29, 1, |_, _| rng.normal());
    let w = vec![1.0; x.rows()];

    for workers in [1usize, 3] {
        let cfg = EngineConfig {
            workers,
            chunk: 16,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt: OptChoice::Lbfgs(Lbfgs { max_iters: 5, ..Default::default() }),
            pipeline: true,
            verbose: false,
            simd: None,
        };
        let problem = SparseGpRegression::problem(&x, &ds.y(), 8, "test", 5);
        let engine = Engine::new(problem, cfg).unwrap();

        let (result, mean, var) = engine.train_then_predict(&xstar, 8).unwrap();
        assert!(result.f.is_finite());
        assert_eq!(mean.rows(), 29);
        assert_eq!(var.len(), 29);

        // rebuild the posterior single-node from the same fitted
        // parameters and the chunk-ordered statistics discipline
        let fitted = &result.fitted;
        let st = sgpr_stats_fwd_chunked(&fitted.kerns[0], &x, &w, &ds.y(),
                                        &fitted.zs[0], 16);
        let single = Posterior::new(fitted.kerns[0].clone(), fitted.zs[0].clone(),
                                    fitted.betas[0], &st).unwrap();
        let (em, ev) = single.predict(&xstar);
        if workers == 1 {
            assert!(mean.max_abs_diff(&em) == 0.0,
                    "1-rank served mean differs from single-node");
            assert_eq!(var, ev, "1-rank served variance differs from single-node");
        } else {
            assert!(mean.max_abs_diff(&em) < 1e-8,
                    "served mean beyond reduction-order tolerance: {}",
                    mean.max_abs_diff(&em));
            for (a, b) in var.iter().zip(&ev) {
                assert!((a - b).abs() < 1e-8, "served var: {a} vs {b}");
            }
        }

        // and the chunked construction matches the old monolithic one to
        // rounding error (sanity that the discipline change is benign)
        let st_full = sgpr_stats_fwd(&fitted.kerns[0], &x, &w, &ds.y(), &fitted.zs[0]);
        assert!(st.p.max_abs_diff(&st_full.p) < 1e-10);
        assert!(st.psi2.max_abs_diff(&st_full.psi2) < 1e-10);
    }
}

fn eval_cfg(workers: usize, chunk: usize, backend: BackendKind) -> EngineConfig {
    EngineConfig {
        workers,
        chunk,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs::default()),
        pipeline: true,
        verbose: false,
        simd: None,
    }
}

/// Run a distributed stats-only pass at `x0` on a `size`-rank cluster.
fn run_stats_pass(problem: &Problem, x0: &[f64], chunk: usize, size: usize,
                  backend: BackendKind) -> Stats {
    let part = Partition::new(problem.n(), chunk, size);
    let cfg = eval_cfg(size, chunk, backend);
    let results = Cluster::run(size, |comm| {
        let mut ev = DistributedEvaluator::new(problem, &cfg, &part, comm).unwrap();
        if ev.rank() == 0 {
            let st = ev.stats_pass(x0).unwrap();
            ev.finish();
            Some(st)
        } else {
            ev.serve().unwrap();
            None
        }
    });
    results.into_iter().next().unwrap().expect("leader stats")
}

/// Assert two stats are bit-identical (as observed by `==`).
fn assert_stats_identical(got: &Stats, want: &Stats, ctx: &str) {
    assert_eq!(got.psi0, want.psi0, "{ctx}: psi0");
    assert_eq!(got.tryy, want.tryy, "{ctx}: tryy");
    assert_eq!(got.kl, want.kl, "{ctx}: kl");
    assert_eq!(got.n_eff, want.n_eff, "{ctx}: n_eff");
    assert!(got.p.max_abs_diff(&want.p) == 0.0, "{ctx}: P");
    assert!(got.psi2.max_abs_diff(&want.psi2) == 0.0, "{ctx}: Psi2");
}

/// The STATS-parity acceptance matrix: the distributed stats-only pass
/// must be **bit-identical** to the serial chunked construction
/// (`sgpr_stats_fwd_chunked` at the engine's chunk size) for every
/// cluster size 1–9 and both CPU backends (N=77, C=8 → 10 chunks with
/// a ragged, padded tail), plus a cluster with more ranks than chunks
/// (chunkless ranks must contribute exact zeros and stay in lockstep).
#[test]
fn stats_pass_parity_ranks_1_to_9() {
    let spec = SyntheticSpec { n: 77, q: 2, d: 3, ..Default::default() };
    let ds = generate_supervised(&spec, 11);
    let x = ds.x().unwrap();
    let chunk = 8;
    let problem = SparseGpRegression::problem(&x, &ds.y(), 6, "test", 11);
    let x0 = problem.initial_params();

    // the serial reference, through the same log-hyp round-trip the
    // broadcast parameters take
    let kern = RbfArd::from_log_hyp(&problem.views[0].kern0.to_log_hyp());
    let w = vec![1.0; x.rows()];
    let want = sgpr_stats_fwd_chunked(&kern, &x, &w, &ds.y(), &problem.views[0].z0, chunk);

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let got = run_stats_pass(&problem, &x0, chunk, size, kind);
            assert_stats_identical(&got, &want, &format!("{kind:?} size {size}"));
        }
    }

    // more ranks than chunks: N=20, C=8 → 3 chunks over 7 ranks
    let spec = SyntheticSpec { n: 20, q: 2, d: 3, ..Default::default() };
    let ds = generate_supervised(&spec, 12);
    let x = ds.x().unwrap();
    let problem = SparseGpRegression::problem(&x, &ds.y(), 5, "test", 12);
    let x0 = problem.initial_params();
    let kern = RbfArd::from_log_hyp(&problem.views[0].kern0.to_log_hyp());
    let w = vec![1.0; x.rows()];
    let want = sgpr_stats_fwd_chunked(&kern, &x, &w, &ds.y(), &problem.views[0].z0, chunk);
    let got = run_stats_pass(&problem, &x0, chunk, 7, BackendKind::RustCpu);
    assert_stats_identical(&got, &want, "chunkless ranks");
}

/// Posterior hot-swap: a serving session opened at parameters A and
/// `refit_and_swap`ped to parameters B must serve predictions
/// **bit-identical** to (a) a fresh session opened directly at B and
/// (b) the single-node posterior built from the serial chunked stats at
/// B — at several cluster sizes. The pre-swap batch must differ, so the
/// swap demonstrably took effect.
#[test]
fn hot_swap_matches_fresh_session_at_new_params() {
    let spec = SyntheticSpec { n: 61, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 17);
    let x = ds.x().unwrap();
    let chunk = 8;
    let m = 7;
    let problem = SparseGpRegression::problem(&x, &ds.y(), m, "test", 17);
    let xa = problem.initial_params();
    // layout (q=1): [log σ², log ℓ, log β, Z (m)] — perturb all four kinds
    let mut xb = xa.clone();
    xb[0] += 0.3;
    xb[1] -= 0.25;
    xb[2] += 0.2;
    xb[3] += 0.1;

    let mut rng = Rng64::new(18);
    let xstar = Mat::from_fn(23, 1, |_, _| rng.normal());

    // single-node expectation at B (serial chunked stats discipline)
    let kern_b = RbfArd::from_log_hyp(&xb[0..2]);
    let z_b = Mat::from_vec(m, 1, xb[3..3 + m].to_vec());
    let w = vec![1.0; x.rows()];
    let st_b = sgpr_stats_fwd_chunked(&kern_b, &x, &w, &ds.y(), &z_b, chunk);
    let single_b = Posterior::new(kern_b, z_b, xb[2].exp(), &st_b).unwrap();
    let (em, ev) = single_b.predict(&xstar);

    for size in [1usize, 2, 5] {
        let part = Partition::new(problem.n(), chunk, size);
        let cfg = eval_cfg(size, chunk, BackendKind::RustCpu);

        // session opened at A, served, hot-swapped to B, served again
        let (p, xa_r, xb_r, xs) = (&problem, &xa, &xb, &xstar);
        let results = Cluster::run(size, |comm| {
            let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
            if ev.rank() == 0 {
                let core = ev.posterior_core_at(xa_r).unwrap();
                ev.begin_serving(core, 4).unwrap();
                let pre = ev.predict_sharded(xs).unwrap();
                ev.refit_and_swap(xb_r).unwrap();
                let post = ev.predict_sharded(xs).unwrap();
                ev.end_serving().unwrap();
                ev.finish();
                Some((pre, post))
            } else {
                ev.serve().unwrap();
                None
            }
        });
        let (pre, post) = results.into_iter().next().unwrap().expect("leader output");

        // fresh session opened directly at B
        let results = Cluster::run(size, |comm| {
            let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
            if ev.rank() == 0 {
                let core = ev.posterior_core_at(xb_r).unwrap();
                ev.begin_serving(core, 4).unwrap();
                let out = ev.predict_sharded(xs).unwrap();
                ev.end_serving().unwrap();
                ev.finish();
                Some(out)
            } else {
                ev.serve().unwrap();
                None
            }
        });
        let fresh = results.into_iter().next().unwrap().expect("leader output");

        assert!(post.0.max_abs_diff(&fresh.0) == 0.0,
                "size {size}: post-swap mean != fresh session at B");
        assert_eq!(post.1, fresh.1, "size {size}: post-swap var != fresh session");
        assert!(post.0.max_abs_diff(&em) == 0.0,
                "size {size}: post-swap mean != single-node at B");
        assert_eq!(post.1, ev, "size {size}: post-swap var != single-node");
        assert!(pre.0.max_abs_diff(&post.0) > 0.0,
                "size {size}: the swap changed nothing — test is vacuous");
    }
}

/// A malformed (truncated) shard wire must surface as a fail-flagged
/// gather + a clean worker error, not a `Mat::from_vec` panic or a
/// silently wrong shard. The leader half of the batch protocol is
/// hand-rolled so a short wire can be injected (sub-command 1.0 =
/// PREDICT, `TAG_XSTAR` = the X* shard channel).
#[test]
fn malformed_shard_wire_is_a_clean_error() {
    let core = toy_core(13, 40, 6, 2, 2);
    let core_ref = &core;
    let results = Cluster::run(2, move |mut comm| {
        if comm.rank() == 0 {
            let mut dp =
                DistributedPosterior::leader(core_ref.clone(), 4, &mut comm).unwrap();
            // announce an 8-row batch: rank 1 owns rows 4..8 and expects
            // 4 rows × Q=2 = 8 wire elements; ship 3 instead
            comm.bcast(0, vec![1.0, 8.0]).unwrap();
            comm.send(1, TAG_XSTAR, &[0.5; 3]).unwrap();
            let gathered = comm.gather(0, &[0.0]).unwrap().expect("root");
            dp.finish(&mut comm).unwrap();
            Some(gathered[1].clone())
        } else {
            let mut backend = RustCpuBackend;
            let err = worker_serve(&mut comm, &mut backend)
                .expect_err("short wire must be an error");
            assert!(format!("{err:#}").contains("shard wire length"),
                    "unhelpful error: {err:#}");
            None
        }
    });
    // the worker reported the failure through the flag payload, keeping
    // the gather in lockstep
    assert_eq!(results[0].as_ref().expect("leader"), &vec![1.0]);
}

/// The stats-only pass must refuse variational problems on the leader
/// *before* any broadcast, so the cluster stays in lockstep and shuts
/// down cleanly.
#[test]
fn stats_pass_refuses_variational_problems() {
    use gpparallel::models::BayesianGplvm;
    let spec = SyntheticSpec { n: 24, q: 1, d: 2, ..Default::default() };
    let ds = gpparallel::data::synthetic::generate(&spec, 3);
    let problem = BayesianGplvm::problem(&ds.y(), 1, 6, "test", 3);
    let x0 = problem.initial_params();
    let part = Partition::new(problem.n(), 8, 2);
    let cfg = eval_cfg(2, 8, BackendKind::RustCpu);
    let (p, x0_r) = (&problem, &x0);
    let results = Cluster::run(2, |comm| {
        let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
        if ev.rank() == 0 {
            let err = ev.stats_pass(x0_r).expect_err("variational must refuse");
            ev.finish();
            Some(format!("{err:#}"))
        } else {
            ev.serve().unwrap();
            None
        }
    });
    let msg = results[0].as_ref().expect("leader");
    assert!(msg.contains("supervised"), "unhelpful error: {msg}");
}

/// Tentpole acceptance: **streamed** serving ≡ sequential serving, bit
/// for bit, for every cluster size 1–9 on both CPU backends — with
/// ragged batches, an empty batch, and a batch smaller than the rank
/// count inside the stream, plus a sequential batch through the same
/// session afterwards (the stream leaves the session in lockstep).
#[test]
fn streamed_serving_matches_sequential_ranks_1_to_9() {
    let core = toy_core(19, 60, 10, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(20);
    let batches: Vec<Mat> = [23usize, 0, 3, 23, 1]
        .iter()
        .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
        .collect();
    let expect: Vec<(Mat, Vec<f64>)> =
        batches.iter().map(|b| single.predict(b)).collect();

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let (core_ref, bs) = (&core, &batches);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = backend_for(kind);
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                              &mut comm).unwrap();
                    let streamed = dp
                        .predict_stream(&mut comm, backend.as_mut(), bs)
                        .unwrap();
                    let tail = dp.predict(&mut comm, backend.as_mut(), &bs[0]).unwrap();
                    dp.finish(&mut comm).unwrap();
                    Some((streamed, tail))
                } else {
                    worker_serve(&mut comm, backend.as_mut()).unwrap();
                    None
                }
            });
            let (streamed, tail) = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in streamed.iter().zip(&expect).enumerate() {
                assert_eq!(gm.rows(), em.rows(), "{kind:?} size {size} batch {i}");
                if em.rows() > 0 {
                    assert!(gm.max_abs_diff(em) == 0.0,
                            "{kind:?} size {size} batch {i}: streamed mean differs");
                }
                assert_eq!(gv, ev, "{kind:?} size {size} batch {i}: streamed var differs");
            }
            assert!(tail.0.max_abs_diff(&expect[0].0) == 0.0,
                    "{kind:?} size {size}: post-stream sequential batch differs");
            assert_eq!(tail.1, expect[0].1, "{kind:?} size {size}: post-stream var");
        }
    }
}

/// A hot-swap broadcast landing *between* two streamed batch
/// announcements must apply after the earlier batch and before the
/// later one — broadcast order — even though the worker prefetches it
/// before computing the earlier batch. The leader half is hand-rolled
/// so the exact interleaving can be pinned (sub-command 1.0 = PREDICT
/// with trailing stream flag, 2.0 = SWAP, `TAG_XSTAR` = the X* shard
/// channel).
#[test]
fn mid_stream_hot_swap_applies_from_the_next_batch() {
    let core_a = toy_core(61, 50, 8, 2, 3);
    let core_b = toy_core(62, 50, 8, 2, 3);
    let single_a = Posterior::from_core(core_a.clone());
    let single_b = Posterior::from_core(core_b.clone());
    let mut rng = Rng64::new(63);
    let xstar = Mat::from_fn(8, 2, |_, _| rng.normal());
    let (ma, va) = single_a.predict(&xstar);
    let (mb, vb) = single_b.predict(&xstar);
    assert!(ma.max_abs_diff(&mb) > 0.0, "cores must differ for the test to bite");

    let (ca, cb, xs) = (&core_a, &core_b, &xstar);
    let results = Cluster::run(2, move |mut comm| {
        if comm.rank() == 0 {
            // session open (granularity 4): rank 1 owns rows 4..8 of an
            // 8-row batch
            let _dp =
                DistributedPosterior::leader(ca.clone(), 4, &mut comm).unwrap();
            // batch 0, stream flag set: the next announcement is in flight
            comm.bcast(0, vec![1.0, 8.0, 1.0]).unwrap();
            comm.send(1, TAG_XSTAR, &xs.as_slice()[4 * 2..8 * 2]).unwrap();
            // the swap lands between the two streamed announcements
            let mut swap = vec![2.0];
            cb.pack_into(&mut swap);
            comm.bcast(0, swap).unwrap();
            let g0 = comm.gather(0, &[0.0]).unwrap().expect("root")[1].clone();
            // batch 1, the stream's tail
            comm.bcast(0, vec![1.0, 8.0, 0.0]).unwrap();
            comm.send(1, TAG_XSTAR, &xs.as_slice()[4 * 2..8 * 2]).unwrap();
            let g1 = comm.gather(0, &[0.0]).unwrap().expect("root")[1].clone();
            comm.bcast(0, vec![0.0]).unwrap();
            Some((g0, g1))
        } else {
            let mut backend = RustCpuBackend;
            worker_serve(&mut comm, &mut backend).unwrap();
            None
        }
    });
    let (g0, g1) = results[0].as_ref().expect("leader");
    // worker payload: mean rows 4..8 (row-major, D = 3) ++ var ++ [flag]
    let expect = |m: &Mat, v: &[f64]| {
        let mut e = m.as_slice()[4 * 3..8 * 3].to_vec();
        e.extend_from_slice(&v[4..8]);
        e.push(0.0);
        e
    };
    assert_eq!(g0, &expect(&ma, &va),
               "batch announced before the swap must serve the old core");
    assert_eq!(g1, &expect(&mb, &vb),
               "batch announced after the swap must serve the new core");
}

/// A malformed shard wire on a *streamed* batch fail-flags that batch
/// only: the prefetched next batch still serves exactly, every gather
/// stays in lockstep, and the worker reports the short wire at close.
#[test]
fn fail_flagged_batch_inside_a_stream_keeps_lockstep() {
    let core = toy_core(65, 50, 8, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(66);
    let xstar = Mat::from_fn(8, 2, |_, _| rng.normal());
    let (em, ev) = single.predict(&xstar);

    let (core_ref, xs) = (&core, &xstar);
    let results = Cluster::run(2, move |mut comm| {
        if comm.rank() == 0 {
            let _dp =
                DistributedPosterior::leader(core_ref.clone(), 4, &mut comm).unwrap();
            // batch 0 (streamed): rank 1 expects 4 rows × Q 2 = 8 wire
            // elements; ship 3 instead
            comm.bcast(0, vec![1.0, 8.0, 1.0]).unwrap();
            comm.send(1, TAG_XSTAR, &[0.5; 3]).unwrap();
            // batch 1 issued before batch 0's gather — true stream order
            comm.bcast(0, vec![1.0, 8.0, 0.0]).unwrap();
            comm.send(1, TAG_XSTAR, &xs.as_slice()[4 * 2..8 * 2]).unwrap();
            let g0 = comm.gather(0, &[0.0]).unwrap().expect("root")[1].clone();
            let g1 = comm.gather(0, &[0.0]).unwrap().expect("root")[1].clone();
            comm.bcast(0, vec![0.0]).unwrap();
            Some((g0, g1))
        } else {
            let mut backend = RustCpuBackend;
            let err = worker_serve(&mut comm, &mut backend)
                .expect_err("short shard wire must be reported");
            assert!(format!("{err:#}").contains("shard wire length"),
                    "unhelpful error: {err:#}");
            None
        }
    });
    let (g0, g1) = results[0].as_ref().expect("leader");
    assert_eq!(g0, &vec![1.0], "bad batch must come back fail-flagged");
    let mut want = em.as_slice()[4 * 3..8 * 3].to_vec();
    want.extend_from_slice(&ev[4..8]);
    want.push(0.0);
    assert_eq!(g1, &want, "the batch after the failure must serve exactly");
}

/// Free end-of-run stats: after a successful evaluation at `x`, the
/// posterior rebuild at the same `x` must cost **zero messages** (the
/// evaluation's captured statistics are reused), while a rebuild at
/// different parameters pays exactly one STATS round (verb + parameter
/// broadcast + reduction = 3·(P−1) tree messages) and keeps the
/// slot-wire bit-exactness guarantee. On one rank the captured fold
/// *is* the serial chunk-order sum, so the capture-hit core is
/// bit-identical to the chunked single-node reference; across ranks it
/// agrees to float reduction order.
#[test]
fn final_eval_capture_makes_the_stats_round_free() {
    let spec = SyntheticSpec { n: 40, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 31);
    let x = ds.x().unwrap();
    let chunk = 8;
    let problem = SparseGpRegression::problem(&x, &ds.y(), 5, "test", 31);
    let x0 = problem.initial_params();
    let mut x1 = x0.clone();
    x1[0] += 0.25; // log σ² of view 0

    // single-node chunked references at x0 and x1, through the same
    // log-hyp round-trip the broadcast parameters take
    let w = vec![1.0; x.rows()];
    let z0 = problem.views[0].z0.clone();
    let kern0 = RbfArd::from_log_hyp(&x0[0..2]);
    let st0 = sgpr_stats_fwd_chunked(&kern0, &x, &w, &ds.y(), &z0, chunk);
    let single0 = Posterior::new(kern0, z0.clone(), x0[2].exp(), &st0).unwrap();
    let kern1 = RbfArd::from_log_hyp(&x1[0..2]);
    let st1 = sgpr_stats_fwd_chunked(&kern1, &x, &w, &ds.y(), &z0, chunk);
    let single1 = Posterior::new(kern1, z0.clone(), x1[2].exp(), &st1).unwrap();

    let mut rng = Rng64::new(33);
    let xstar = Mat::from_fn(9, 1, |_, _| rng.normal());
    let (e0m, e0v) = single0.predict(&xstar);
    let (e1m, e1v) = single1.predict(&xstar);

    for size in [1usize, 3] {
        let part = Partition::new(problem.n(), chunk, size);
        let cfg = eval_cfg(size, chunk, BackendKind::RustCpu);
        let (p, x0_r, x1_r) = (&problem, &x0, &x1);
        let results = Cluster::run(size, |comm| {
            let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm).unwrap();
            if ev.rank() == 0 {
                ev.eval(x0_r).unwrap();
                let before = ev.messages_sent();
                let hit = ev.posterior_core_at(x0_r).unwrap();
                let after_hit = ev.messages_sent();
                let miss = ev.posterior_core_at(x1_r).unwrap();
                let after_miss = ev.messages_sent();
                ev.finish();
                Some((hit, miss, before, after_hit, after_miss))
            } else {
                ev.serve().unwrap();
                None
            }
        });
        let (hit, miss, before, after_hit, after_miss) =
            results.into_iter().next().unwrap().expect("leader output");
        assert_eq!(after_hit, before,
                   "size {size}: a capture hit must run zero collective rounds");
        assert_eq!(after_miss - after_hit, 3 * (size as u64 - 1),
                   "size {size}: a capture miss must pay exactly one STATS round");

        let (hm, hv) = Posterior::from_core(hit).predict(&xstar);
        if size == 1 {
            assert!(hm.max_abs_diff(&e0m) == 0.0,
                    "size 1: captured fold must equal the serial chunk-order sum");
            assert_eq!(hv, e0v);
        } else {
            assert!(hm.max_abs_diff(&e0m) < 1e-8,
                    "size {size}: capture-hit core beyond reduction-order tolerance \
                     ({})", hm.max_abs_diff(&e0m));
            for (a, b) in hv.iter().zip(&e0v) {
                assert!((a - b).abs() < 1e-8, "size {size}: var {a} vs {b}");
            }
        }
        // the miss path keeps the slot-wire bit-exactness guarantee
        let (mm, mv) = Posterior::from_core(miss).predict(&xstar);
        assert!(mm.max_abs_diff(&e1m) == 0.0,
                "size {size}: a fresh STATS round must stay bit-identical to chunked");
        assert_eq!(mv, e1v);
    }
}

/// `train_then_predict` must not pay any STATS round when the final
/// accepted evaluation's capture hits: the message delta between a
/// train-only run and a train-then-serve run is exactly the serving
/// session's own traffic. `max_iters = 0` makes the hit a certainty
/// (one evaluation, at exactly the returned parameter vector) instead
/// of an optimiser-dependent likelihood.
#[test]
fn train_then_predict_skips_the_stats_round_when_capture_hits() {
    let spec = SyntheticSpec { n: 84, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 41);
    let x = ds.x().unwrap();
    let workers = 3usize;
    let cfg = EngineConfig {
        workers,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 0, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let mk = || SparseGpRegression::problem(&x, &ds.y(), 6, "test", 41);
    let train_only = Engine::new(mk(), cfg.clone()).unwrap().train().unwrap();

    let mut rng = Rng64::new(42);
    let nt = 21usize;
    let rpc = 4usize;
    let xstar = Mat::from_fn(nt, 1, |_, _| rng.normal());
    let (served, mean, var) = Engine::new(mk(), cfg)
        .unwrap()
        .train_then_predict(&xstar, rpc)
        .unwrap();
    assert_eq!(mean.rows(), nt);
    assert_eq!(var.len(), nt);

    // Expected serving-only traffic (a tree bcast or a gather each move
    // P−1 messages): SERVE verb + posterior broadcast + batch
    // announcement + shard sends + gather + DONE. A STATS round would
    // add 3·(P−1) on top — the capture must make it zero.
    let p = Partition::new(nt, rpc, workers);
    let shard_sends =
        (1..workers).filter(|&r| p.worker_span(r).is_some()).count() as u64;
    let serve_only = 5 * (workers as u64 - 1) + shard_sends;
    assert_eq!(served.messages_sent - train_only.messages_sent, serve_only,
               "train_then_predict paid collective rounds beyond the serving \
                session — the final-eval stats capture did not hit");
}

/// `Engine`-level stream ≡ sequential: `train_then_predict_stream`
/// (the batch split + streamed protocol + reassembly) must reproduce
/// `train_then_predict` bit for bit — training is deterministic, the
/// serving posterior is the same, and streaming reorders only the
/// protocol.
#[test]
fn train_then_predict_stream_matches_sequential_serving() {
    let spec = SyntheticSpec { n: 72, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 51);
    let x = ds.x().unwrap();
    let cfg = EngineConfig {
        workers: 3,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 3, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let mk = || SparseGpRegression::problem(&x, &ds.y(), 6, "test", 51);
    let mut rng = Rng64::new(52);
    let xstar = Mat::from_fn(31, 1, |_, _| rng.normal());

    let (r_seq, m_seq, v_seq) = Engine::new(mk(), cfg.clone())
        .unwrap()
        .train_then_predict(&xstar, 4)
        .unwrap();
    // 8-row stream batches: 31 rows → three full batches + a ragged tail
    let (r_str, m_str, v_str) = Engine::new(mk(), cfg)
        .unwrap()
        .train_then_predict_stream(&xstar, 4, 8)
        .unwrap();

    assert_eq!(r_seq.f, r_str.f, "training must be identical across the two runs");
    assert!(m_seq.max_abs_diff(&m_str) == 0.0,
            "streamed serving mean differs from sequential");
    assert_eq!(v_seq, v_str, "streamed serving variance differs from sequential");
}

/// A variational problem must refuse the serving hand-off with a clear
/// error instead of desyncing the cluster.
#[test]
fn train_then_predict_rejects_unsupervised_problems() {
    use gpparallel::models::BayesianGplvm;
    let spec = SyntheticSpec { n: 32, q: 1, d: 2, ..Default::default() };
    let ds = gpparallel::data::synthetic::generate(&spec, 2);
    let problem = BayesianGplvm::problem(&ds.y(), 1, 8, "test", 2);
    let cfg = EngineConfig {
        workers: 2,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 2, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let engine = Engine::new(problem, cfg).unwrap();
    let xstar = Mat::from_fn(4, 1, |i, _| i as f64);
    let err = engine.train_then_predict(&xstar, 4).err().expect("must refuse");
    assert!(format!("{err}").contains("supervised"), "unhelpful error: {err}");
}
