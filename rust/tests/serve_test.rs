//! Sharded-serving equivalence + posterior correctness.
//!
//! Three layers of guarantees:
//! 1. the single-node `Posterior` agrees with the dense O(N³) GP oracle
//!    when the inducing set is the full training set (where the
//!    variational sparse posterior is exact);
//! 2. `DistributedPosterior` reproduces the single-node `Posterior`
//!    **bit for bit** for every cluster size 1–9 and both CPU backends
//!    (prediction rows are independent, so sharding reorders nothing);
//! 3. the training→serving hand-off (`Engine::train_then_predict`)
//!    serves exactly the posterior implied by the fitted parameters.

use gpparallel::baselines::DenseGp;
use gpparallel::collectives::Cluster;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use gpparallel::coordinator::{Backend, EngineConfig, Engine, OptChoice, ParallelCpuBackend,
                              RustCpuBackend};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::math::predict::PosteriorCore;
use gpparallel::math::stats::sgpr_stats_fwd;
use gpparallel::models::{Posterior, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::{Prop, Rng64};

/// Sparse posterior with Z = X must match the exact dense GP (mean and
/// variance), since the variational approximation is tight there.
///
/// Training inputs are a jittered grid (guaranteed point separation):
/// with duplicate-prone random inputs, K(X, X) is numerically singular
/// at Z = X and the comparison measures conditioning, not correctness.
/// The 1e-4 tolerance carries ~60x margin over the worst error observed
/// in a 1000-case float simulation of this exact algorithm.
#[test]
fn prop_posterior_matches_dense_gp_at_full_inducing() {
    Prop::new("posterior_vs_dense").cases(10).run(|rng| {
        let n = 12 + (rng.next_u64() % 8) as usize;
        let q = 1 + (rng.next_u64() % 2) as usize;
        let d = 1 + (rng.next_u64() % 2) as usize;
        let mut x = Mat::zeros(n, q);
        for qq in 0..q {
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            for i in 0..n {
                let base = -2.0 + 4.0 * perm[i] as f64 / (n - 1) as f64;
                x[(i, qq)] = base + rng.uniform_range(-0.05, 0.05);
            }
        }
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let kern = RbfArd::new(
            rng.uniform_range(0.5, 1.5),
            (0..q).map(|_| rng.uniform_range(0.5, 1.0)).collect(),
        );
        let beta = rng.uniform_range(5.0, 20.0); // moderate noise: well-conditioned
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let sparse = Posterior::new(kern.clone(), x.clone(), beta, &st).unwrap();
        let dense = DenseGp::with_params(x.clone(), &y, kern, beta).unwrap();

        let xstar = Mat::from_fn(7, q, |_, _| rng.uniform_range(-2.0, 2.0));
        let (sm, sv) = sparse.predict(&xstar);
        let (dm, dv) = dense.predict(&xstar);
        assert!(sm.max_abs_diff(&dm) < 1e-4,
                "mean mismatch: {}", sm.max_abs_diff(&dm));
        for (a, b) in sv.iter().zip(&dv) {
            assert!((a - b).abs() < 1e-4, "var mismatch: {a} vs {b}");
        }
    });
}

fn toy_core(seed: u64, n: usize, m: usize, q: usize, d: usize) -> PosteriorCore {
    let mut rng = Rng64::new(seed);
    let x = Mat::from_fn(n, q, |_, _| rng.normal());
    let y = Mat::from_fn(n, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::new(1.4, (0..q).map(|_| rng.uniform_range(0.7, 1.3)).collect());
    let w = vec![1.0; n];
    let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
    PosteriorCore::new(kern, z, 15.0, &st).unwrap()
}

fn backend_for(kind: BackendKind) -> Box<dyn Backend> {
    match kind {
        BackendKind::RustCpu => Box::new(RustCpuBackend),
        BackendKind::ParallelCpu { threads } => Box::new(ParallelCpuBackend::new(threads)),
        BackendKind::Xla => unreachable!("not exercised here"),
    }
}

/// The acceptance-criteria matrix: sharded output must be bit-identical
/// to the single-node posterior for ranks 1–9 on both CPU backends,
/// including ragged batches (Nt not divisible by the chunk) and batches
/// smaller than the rank count.
#[test]
fn distributed_matches_single_node_ranks_1_to_9() {
    let core = toy_core(7, 60, 10, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(8);
    let batches: Vec<Mat> = [37usize, 3, 37]
        .iter()
        .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
        .collect();
    let expect: Vec<(Mat, Vec<f64>)> = batches.iter().map(|b| single.predict(b)).collect();

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let (core_ref, batches_ref) = (&core, &batches);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = backend_for(kind);
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                             &mut comm);
                    let out: Vec<(Mat, Vec<f64>)> = batches_ref
                        .iter()
                        .map(|b| dp.predict(&mut comm, backend.as_mut(), b).unwrap())
                        .collect();
                    dp.finish(&mut comm);
                    Some(out)
                } else {
                    worker_serve(&mut comm, backend.as_mut()).unwrap();
                    None
                }
            });
            let got = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in got.iter().zip(&expect).enumerate() {
                assert!(gm.max_abs_diff(em) == 0.0,
                        "{kind:?} size {size} batch {i}: mean differs");
                assert_eq!(gv, ev, "{kind:?} size {size} batch {i}: var differs");
            }
        }
    }
}

/// Training → serving hand-off on one cluster: `train_then_predict`
/// must serve exactly the posterior implied by the fitted parameters
/// (cross-checked against a freshly built single-node posterior), for a
/// worker count with ragged chunk assignment.
#[test]
fn train_then_predict_matches_single_node_posterior() {
    let spec = SyntheticSpec { n: 96, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 5);
    let x = ds.x.clone().unwrap();
    let cfg = EngineConfig {
        workers: 3,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 5, ..Default::default() }),
        pipeline: true,
        verbose: false,
    };
    let problem = SparseGpRegression::problem(&x, &ds.y, 8, "test", 5);
    let engine = Engine::new(problem, cfg).unwrap();

    let mut rng = Rng64::new(6);
    let xstar = Mat::from_fn(29, 1, |_, _| rng.normal());
    let (result, mean, var) = engine.train_then_predict(&xstar, 8).unwrap();
    assert!(result.f.is_finite());
    assert_eq!(mean.rows(), 29);
    assert_eq!(var.len(), 29);

    // rebuild the posterior single-node from the same fitted parameters
    let fitted = &result.fitted;
    let w = vec![1.0; x.rows()];
    let st = sgpr_stats_fwd(&fitted.kerns[0], &x, &w, &ds.y, &fitted.zs[0]);
    let single = Posterior::new(fitted.kerns[0].clone(), fitted.zs[0].clone(),
                                fitted.betas[0], &st).unwrap();
    let (em, ev) = single.predict(&xstar);
    assert!(mean.max_abs_diff(&em) == 0.0, "served mean differs from single-node");
    assert_eq!(var, ev, "served variance differs from single-node");
}

/// A variational problem must refuse the serving hand-off with a clear
/// error instead of desyncing the cluster.
#[test]
fn train_then_predict_rejects_unsupervised_problems() {
    use gpparallel::models::BayesianGplvm;
    let spec = SyntheticSpec { n: 32, q: 1, d: 2, ..Default::default() };
    let ds = gpparallel::data::synthetic::generate(&spec, 2);
    let problem = BayesianGplvm::problem(&ds.y, 1, 8, "test", 2);
    let cfg = EngineConfig {
        workers: 2,
        chunk: 16,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 2, ..Default::default() }),
        pipeline: true,
        verbose: false,
    };
    let engine = Engine::new(problem, cfg).unwrap();
    let xstar = Mat::from_fn(4, 1, |i, _| i as f64);
    let err = engine.train_then_predict(&xstar, 4).err().expect("must refuse");
    assert!(format!("{err}").contains("supervised"), "unhelpful error: {err}");
}
