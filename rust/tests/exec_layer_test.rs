//! Execution-layer equivalence tests for the engine/backend/collectives
//! split: the parallel-CPU backend must reproduce the serial backend
//! exactly, tree collectives must agree with the linear reference at
//! engine level, and the facade refactor must keep the distributed
//! objective intact end to end.

use gpparallel::collectives::{Cluster, Topology};
use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, LatentSpec, OptChoice, Problem,
                              ViewSpec};
use gpparallel::data::synthetic::{generate, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::models::{BayesianGplvm, Mrd};
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::Rng64;

fn cfg(workers: usize, chunk: usize, backend: BackendKind, iters: usize) -> EngineConfig {
    EngineConfig {
        workers,
        chunk,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: iters, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    }
}

/// Two unsupervised views sharing q(X) — exercises the multi-view path
/// (per-view backends, KL attached to view 0 only).
fn multi_view_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Rng64::new(seed);
    let shared: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let v1 = Mat::from_fn(n, 3, |i, j| (shared[i] * (1.0 + 0.3 * j as f64)).sin()
        + 0.05 * ((i * 7 + j) as f64).cos());
    let v2 = Mat::from_fn(n, 4, |i, j| (shared[i] + 0.5 * j as f64).cos()
        + 0.05 * ((i * 3 + j) as f64).sin());
    Mrd::problem(&[v1, v2], 2, 12, &["test", "test"], seed)
}

/// The parallel-CPU backend must produce a bit-identical objective and
/// gradient path to the serial backend: same chunk math, same chunk-order
/// accumulation, only the scheduling differs. `TrainResult.f` is the
/// reduced objective, so exact equality is the real assertion here.
#[test]
fn parallel_cpu_engine_bit_identical_to_rust_cpu() {
    let problem = multi_view_problem(96, 21);
    for workers in [1, 2] {
        let serial = Engine::new(problem.clone(), cfg(workers, 16, BackendKind::RustCpu, 0))
            .unwrap()
            .time_iterations(1)
            .unwrap();
        for threads in [2, 3] {
            let parallel = Engine::new(
                problem.clone(),
                cfg(workers, 16, BackendKind::ParallelCpu { threads }, 0),
            )
            .unwrap()
            .time_iterations(1)
            .unwrap();
            assert_eq!(serial.f, parallel.f,
                       "objective differs (workers={workers}, threads={threads})");
        }
    }
}

/// Short training runs must follow the identical trajectory too — the
/// optimiser sees the same gradients, so every accepted step matches.
#[test]
fn parallel_cpu_training_trajectory_matches() {
    let spec = SyntheticSpec { n: 120, q: 2, d: 3, ..Default::default() };
    let ds = generate(&spec, 22);
    let problem = BayesianGplvm::problem(&ds.y(), 2, 10, "test", 22);

    let serial = Engine::new(problem.clone(), cfg(2, 32, BackendKind::RustCpu, 8))
        .unwrap().train().unwrap();
    let parallel = Engine::new(problem, cfg(2, 32, BackendKind::ParallelCpu { threads: 2 }, 8))
        .unwrap().train().unwrap();

    assert_eq!(serial.trace.len(), parallel.trace.len(), "iteration counts differ");
    for (a, b) in serial.trace.iter().zip(&parallel.trace) {
        assert_eq!(a, b, "trajectories diverged");
    }
}

/// The engine runs on tree collectives by default; pinning the cluster to
/// the linear reference must give the same objective up to reduction
/// order. (The engine itself keeps the default, so this compares the two
/// topologies through the raw collectives on engine-sized payloads.)
#[test]
fn tree_and_linear_collectives_agree_on_engine_payloads() {
    for &size in &[2usize, 3, 5, 8] {
        let payload = 4 + 100 * 3 + 100 * 100; // one view's stats wire at M=100, D=3
        let data: Vec<Vec<f64>> = (0..size)
            .map(|r| {
                let mut rng = Rng64::new(1000 + r as u64);
                rng.normal_vec(payload)
            })
            .collect();
        let ds = &data;
        let run = |topology| {
            Cluster::run_with(size, topology, move |mut comm| {
                comm.reduce_sum(0, &ds[comm.rank()]).unwrap()
            })
        };
        let lin = run(Topology::Linear).remove(0).unwrap();
        let tree = run(Topology::Tree).remove(0).unwrap();
        for (a, b) in lin.iter().zip(&tree) {
            assert!((a - b).abs() < 1e-10 * (1.0 + a.abs()),
                    "size {size}: {a} vs {b}");
        }
    }
}

/// Worker-count invariance must hold for the parallel backend as well
/// (the refactored cycle slices spans identically regardless of backend).
#[test]
fn parallel_backend_worker_count_invariance() {
    let spec = SyntheticSpec { n: 150, q: 2, d: 3, ..Default::default() };
    let ds = generate(&spec, 23);
    let problem = BayesianGplvm::problem(&ds.y(), 2, 16, "test", 23);
    let mut bounds = Vec::new();
    for workers in [1, 2, 4] {
        let r = Engine::new(problem.clone(),
                            cfg(workers, 32, BackendKind::parallel_auto(), 0))
            .unwrap()
            .time_iterations(1)
            .unwrap();
        bounds.push(r.f);
    }
    for w in bounds.windows(2) {
        assert!((w[0] - w[1]).abs() < 1e-9 * (1.0 + w[0].abs()),
                "objective differs across workers: {bounds:?}");
    }
}

/// A leader-side core failure must surface as an `Err`, not a protocol
/// desync or a hang: poison the problem so the M×M core's Cholesky sees
/// a non-finite matrix on the very first evaluation.
#[test]
fn leader_core_failure_aborts_cleanly() {
    let n = 40;
    let mut rng = Rng64::new(24);
    let y = Mat::from_fn(n, 2, |_, _| rng.normal());
    let mu0 = Mat::from_fn(n, 1, |_, _| rng.normal());
    let s0 = Mat::from_vec(n, 1, vec![0.5; n]);
    // duplicate + enormous inducing inputs -> K_uu loses rank and the
    // jittered Cholesky still fails once beta*Psi2 overflows
    let z0 = Mat::from_vec(4, 1, vec![f64::MAX / 1e3; 4]);
    let problem = Problem {
        latent: LatentSpec::Variational { mu0, s0 },
        views: vec![ViewSpec {
            y: y.into(),
            z0,
            kern0: RbfArd::iso(1.0, 1e-300, 1),
            beta0: 1e300,
            aot_config: "test".into(),
        }],
        q: 1,
    };
    let result = Engine::new(problem, cfg(3, 8, BackendKind::RustCpu, 3))
        .unwrap()
        .train();
    assert!(result.is_err(), "poisoned problem must fail");
}
