//! Chaos sweep + collective property tests + structured wire fuzzing.
//!
//! **Tentpole sweep** (`chaos_sweep_*`): for each protocol scenario in
//! `testutil::chaos` — one training cycle, one STATS round, one
//! streamed serve session, one front-end session — count every
//! rank's protocol messages in a fault-free run, then re-run the whole
//! scenario once per (rank, message index, fault kind) with a
//! `FaultyTransport` injecting exactly that fault. Every run must
//! terminate under a watchdog (no deadlock), panic nowhere, surface a
//! sticky error or a clean result on every rank, replay bit-identically
//! from its plan, and — for delay-only faults — be bit-identical to the
//! fault-free run.
//!
//! Replay one failing case alone:
//! `GPPAR_CHAOS_SEED=<scenario:rank:index:kind:seed> cargo test --test
//! chaos_test` (the other sweeps become no-ops; see `docs/TESTING.md`).
//!
//! **Collectives property tests**: `bcast_tree`/`reduce_sum_tree`
//! against their linear references for ranks 1–12 — bit-identical
//! results on exactly-representable data, exact message counts (root
//! sends ⌈log₂P⌉ in the tree vs P−1 linear, P−1 total everywhere), and
//! delay-fault immunity at every message index of every rank.
//!
//! **Wire fuzzers**: seeded malformed wires over every serve verb and
//! the top-level command header; the worker must stay parked with a
//! sticky error (serve verbs, STATS parameter wire) or exit with a
//! clean error (top-level breaches), and the session must still serve a
//! real batch bit-identically afterwards.

// The sweep spins up thousands of multi-threaded clusters with
// wall-clock watchdogs — far past Miri's budget. The transport and
// collective layers get their Miri coverage from the lib unit tests.
#![cfg(not(miri))]

use std::time::Duration;

use gpparallel::collectives::{Cluster, Comm, FaultKind, FaultPlan, FaultyTransport,
                              InMemoryTransport, Topology, Transport};
use gpparallel::config::BackendKind;
use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use gpparallel::coordinator::{DistributedEvaluator, EngineConfig, OptChoice,
                              Partition, Problem, RustCpuBackend};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::math::predict::PosteriorCore;
use gpparallel::math::stats::sgpr_stats_fwd;
use gpparallel::models::{Posterior, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::chaos::{case_id, outcomes_bitwise_equal, parse_case,
                                  run_scenario_watchdog, Scenario, CLUSTER};
use gpparallel::testutil::prop::Rng64;

/// Generous per-run deadline: a healthy run takes milliseconds, so a
/// minute only ever fires on a genuine deadlock.
const TIMEOUT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// tentpole: the fault sweep
// ---------------------------------------------------------------------

/// `GPPAR_CHAOS_SEED=<case id>` pins the whole suite to one case.
fn replay_override() -> Option<(Scenario, FaultPlan)> {
    let v = std::env::var("GPPAR_CHAOS_SEED").ok()?;
    match parse_case(&v) {
        Some(case) => Some(case),
        None => panic!(
            "GPPAR_CHAOS_SEED={v:?} is not a case id \
             (want scenario:rank:index:kind:seed, e.g. \
             serve_stream:1:3:truncate:42)"),
    }
}

/// Deterministic per-case seed so value-level fault randomness differs
/// across the sweep but every case is replayable from its id alone.
fn case_seed(scenario: Scenario, rank: usize, index: u64, kind: FaultKind) -> u64 {
    let k = FaultKind::ALL.iter().position(|&f| f == kind).unwrap() as u64;
    let s = Scenario::ALL.iter().position(|&x| x == scenario).unwrap() as u64;
    (s << 48) ^ ((rank as u64) << 32) ^ (index << 8) ^ k ^ 0xC0A5_1A11
}

/// One faulted case: run, check invariants against the clean baseline,
/// replay, check bit-identity.
fn run_case(scenario: Scenario, plan: FaultPlan,
            clean: &gpparallel::testutil::chaos::RunOutcome) {
    let label = case_id(scenario, &plan);
    let out = run_scenario_watchdog(scenario, Some(plan), TIMEOUT, &label);
    assert_eq!(out.panics, 0, "panic under {label}: {:?}", out.ranks);
    if plan.kind == FaultKind::Delay {
        assert!(outcomes_bitwise_equal(&out, clean),
                "delay-only fault changed the outcome under {label}\n\
                 clean: {clean:?}\n  got: {out:?}");
    }
    let again = run_scenario_watchdog(scenario, Some(plan), TIMEOUT, &label);
    assert!(outcomes_bitwise_equal(&out, &again),
            "replay diverged under {label}\nfirst: {out:?}\nagain: {again:?}");
}

/// The full sweep for one scenario: every rank × every message index ×
/// every fault kind.
fn sweep(scenario: Scenario) {
    if let Some((pinned, plan)) = replay_override() {
        if pinned == scenario {
            let clean = run_scenario_watchdog(
                scenario, None, TIMEOUT, &format!("{}:fault-free", scenario.name()));
            assert!(clean.all_ok(), "fault-free {} run failed: {clean:?}",
                    scenario.name());
            run_case(scenario, plan, &clean);
            println!("replayed {} twice, bit-identical", case_id(scenario, &plan));
        }
        return;
    }

    let clean = run_scenario_watchdog(
        scenario, None, TIMEOUT, &format!("{}:fault-free", scenario.name()));
    assert_eq!(clean.panics, 0, "{}: fault-free run panicked", scenario.name());
    assert!(clean.all_ok(), "{}: fault-free run failed: {clean:?}", scenario.name());

    for rank in 0..CLUSTER {
        let sends = clean.ranks[rank].sent;
        assert!(sends > 0,
                "{}: rank {rank} sent no messages — the sweep would be vacuous",
                scenario.name());
        for index in 0..sends {
            for kind in FaultKind::ALL {
                let seed = case_seed(scenario, rank, index, kind);
                run_case(scenario, FaultPlan { rank, index, kind, seed }, &clean);
            }
        }
    }
}

#[test]
fn chaos_sweep_train_cycle() {
    sweep(Scenario::TrainCycle);
}

#[test]
fn chaos_sweep_stats_round() {
    sweep(Scenario::StatsRound);
}

#[test]
fn chaos_sweep_serve_stream() {
    sweep(Scenario::ServeStream);
}

#[test]
fn chaos_sweep_frontend() {
    sweep(Scenario::Frontend);
}

// ---------------------------------------------------------------------
// satellite: tree collectives vs linear references under delay faults
// ---------------------------------------------------------------------

/// All five collective ops on integer-valued data (addition is exact,
/// so tree and linear accumulation orders agree bit for bit). Returns
/// this rank's digest and the cumulative send counter after each op.
fn collective_digest(mut comm: Comm) -> (Vec<f64>, Vec<u64>) {
    let rank = comm.rank();
    let data: Vec<f64> =
        (0..33).map(|i| (((rank * 31 + i * 7) % 101) as f64) - 50.0).collect();
    let payload: Vec<f64> = (0..33).map(|i| ((i * 13) % 89) as f64).collect();
    let mut digest = Vec::new();
    let mut counts = Vec::new();

    let root_payload = |r: usize| if r == 0 { payload.clone() } else { Vec::new() };
    let bt = comm.bcast_tree(0, root_payload(rank)).expect("bcast_tree");
    counts.push(comm.local_messages_sent());
    let bl = comm.bcast_linear(0, root_payload(rank)).expect("bcast_linear");
    counts.push(comm.local_messages_sent());
    assert_eq!(bt, bl, "tree and linear broadcast payloads differ");
    digest.extend_from_slice(&bt);

    let rt = comm.reduce_sum_tree(0, &data).expect("reduce_sum_tree");
    counts.push(comm.local_messages_sent());
    let rl = comm.reduce_sum_linear(0, &data).expect("reduce_sum_linear");
    counts.push(comm.local_messages_sent());
    if let (Some(t), Some(l)) = (&rt, &rl) {
        assert!(t.iter().zip(l).all(|(a, b)| a.to_bits() == b.to_bits()),
                "tree and linear reductions disagree on exact data");
        digest.extend_from_slice(t);
    }

    if let Some(parts) = comm.gather(0, &data).expect("gather") {
        for part in parts {
            digest.extend_from_slice(&part);
        }
    }
    counts.push(comm.local_messages_sent());
    (digest, counts)
}

fn run_collectives(p: usize, plan: Option<FaultPlan>) -> Vec<(Vec<f64>, Vec<u64>)> {
    let transports: Vec<Box<dyn Transport>> = InMemoryTransport::mesh(p)
        .into_iter()
        .enumerate()
        .map(|(r, t)| match plan {
            Some(pl) if pl.rank == r => {
                Box::new(FaultyTransport::new(Box::new(t), pl)) as Box<dyn Transport>
            }
            _ => Box::new(t) as Box<dyn Transport>,
        })
        .collect();
    Cluster::try_run_on(transports, Topology::Tree, &|comm| collective_digest(comm))
        .into_iter()
        .enumerate()
        .map(|(r, res)| match res {
            Ok(v) => v,
            Err(_) => panic!("collective rank {r} panicked (P={p}, plan {plan:?})"),
        })
        .collect()
}

fn ceil_log2(p: usize) -> u64 {
    let mut k = 0u64;
    let mut m = 1usize;
    while m < p {
        m <<= 1;
        k += 1;
    }
    k
}

/// Collective results and counts must be bitwise equal across two runs.
fn collectives_bitwise_equal(a: &[(Vec<f64>, Vec<u64>)],
                             b: &[(Vec<f64>, Vec<u64>)]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|((da, ca), (db, cb))| {
            ca == cb
                && da.len() == db.len()
                && da.iter().zip(db).all(|(x, y)| x.to_bits() == y.to_bits())
        })
}

/// Ranks 1–12: tree collectives agree with the linear references bit
/// for bit, message counts are exact (root ⌈log₂P⌉ vs P−1; every
/// non-root reduction/gather contribution is a single message; P−1
/// total for every op), and a delay fault at **any** message index on
/// **any** rank changes nothing.
#[test]
fn collectives_vs_linear_counts_and_delay_immunity() {
    if replay_override().is_some() {
        return; // suite pinned to a single tentpole-sweep case
    }
    for p in 1..=12usize {
        let clean = run_collectives(p, None);

        // exact per-op message counts from the cumulative counters
        let delta = |r: usize, op: usize| {
            let c = &clean[r].1;
            if op == 0 { c[0] } else { c[op] - c[op - 1] }
        };
        let total = |op: usize| (0..p).map(|r| delta(r, op)).sum::<u64>();
        assert_eq!(delta(0, 0), ceil_log2(p), "P={p}: tree bcast root sends");
        assert_eq!(total(0), (p - 1) as u64, "P={p}: tree bcast total");
        assert_eq!(delta(0, 1), (p - 1) as u64, "P={p}: linear bcast root sends");
        assert_eq!(total(1), (p - 1) as u64, "P={p}: linear bcast total");
        for op in [2usize, 3, 4] {
            assert_eq!(delta(0, op), 0, "P={p}: op {op} root sends nothing");
            for r in 1..p {
                assert_eq!(delta(r, op), 1,
                           "P={p}: op {op} rank {r} sends exactly one message");
            }
        }

        // delay sweep: every rank, every message index
        for rank in 0..p {
            let sends = *clean[rank].1.last().unwrap();
            for index in 0..sends {
                let plan = FaultPlan { rank, index, kind: FaultKind::Delay,
                                       seed: 0xDE1A_u64 ^ ((p as u64) << 32)
                                             ^ ((rank as u64) << 16) ^ index };
                let (tx, rx) = std::sync::mpsc::channel();
                std::thread::spawn(move || {
                    let _ = tx.send(run_collectives(p, Some(plan)));
                });
                let got = rx.recv_timeout(TIMEOUT).unwrap_or_else(|_| {
                    panic!("collectives P={p} rank {rank} index {index}: \
                            deadlock under delay fault")
                });
                assert!(collectives_bitwise_equal(&got, &clean),
                        "P={p} rank {rank} index {index}: delay fault changed \
                         a collective result\nclean: {clean:?}\n  got: {got:?}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// satellite: structured wire fuzzers
// ---------------------------------------------------------------------

// The serve sub-command vocabulary and top-level cluster command verbs
// come from the cluster-wide registry, so a renumbering there cannot
// silently diverge from what these fuzzers put on the wire.
use gpparallel::collectives::protocol::{CMD_EVAL, CMD_SERVE, CMD_STATS, CMD_STOP,
                                        SRV_PREDICT, SRV_REFIT, SRV_SWAP, TAG_XSTAR};

fn fuzz_core(seed: u64) -> PosteriorCore {
    let (n, m, q, d) = (20usize, 5usize, 2usize, 2usize);
    let mut rng = Rng64::new(seed);
    let x = Mat::from_fn(n, q, |_, _| rng.normal());
    let y = Mat::from_fn(n, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::iso(1.1, 0.9, q);
    let w = vec![1.0; n];
    let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
    PosteriorCore::new(kern, z, 18.0, &st).unwrap()
}

/// Seeded structured fuzz over every malformed serve-wire class —
/// unknown verbs, short/garbled SRV_PREDICT headers (NaN, negative,
/// fractional and absurd row counts, bad stream flags), garbage
/// SRV_SWAP payloads, and wrong-length shard wires — then a real
/// hot-swap (`SRV_SWAP` via `rebroadcast`) and a real batch. The worker
/// must stay parked through all of it, serve the real batch
/// bit-identically to the single-node posterior, and surface the first
/// junk wire as its sticky error at close (`SRV_DONE`).
#[test]
fn serve_wire_fuzzer_worker_stays_parked_then_serves() {
    if replay_override().is_some() {
        return;
    }
    let core = fuzz_core(31);
    let core2 = fuzz_core(32);
    let results = Cluster::run(2, move |mut comm| {
        let mut backend = RustCpuBackend;
        if comm.rank() == 0 {
            let mut dp =
                DistributedPosterior::leader(core.clone(), 2, &mut comm).unwrap();
            let mut rng = Rng64::new(0xF022);
            for _ in 0..40 {
                match rng.next_u64() % 6 {
                    0 => {
                        // unknown sub-command verb
                        let v = [9.0, -1.0, 0.5, f64::NAN, 1e18]
                            [(rng.next_u64() % 5) as usize];
                        let _ = comm.bcast(0, vec![v]).unwrap();
                    }
                    1 => {
                        // SRV_PREDICT header too short to carry a row count
                        let _ = comm.bcast(0, vec![SRV_PREDICT]).unwrap();
                    }
                    2 => {
                        // row counts no honest leader produces
                        let r = [f64::NAN, -3.0, 0.25, 1e17, 0.0]
                            [(rng.next_u64() % 5) as usize];
                        let _ = comm.bcast(0, vec![SRV_PREDICT, r]).unwrap();
                    }
                    3 => {
                        // stream flag that is neither 0 nor 1
                        let _ = comm.bcast(0, vec![SRV_PREDICT, 4.0, 7.5]).unwrap();
                    }
                    4 => {
                        // swap broadcast whose core fails to unpack
                        let mut w = vec![SRV_SWAP];
                        for _ in 0..(rng.next_u64() % 7) {
                            w.push(rng.normal());
                        }
                        let _ = comm.bcast(0, w).unwrap();
                    }
                    _ => {
                        // valid header, wrong-length shard: worker must
                        // fail-flag the gather, never feed the short
                        // buffer to its shard matrix (4 rows over 2
                        // ranks: rank 1 owns 2 rows × Q=2 → wants 4)
                        let _ = comm.bcast(0, vec![SRV_PREDICT, 4.0, 0.0]).unwrap();
                        comm.send(1, TAG_XSTAR, &[0.5; 3]).unwrap();
                        let g = comm.gather(0, &[0.0]).unwrap().expect("root");
                        assert_eq!(g[1], vec![1.0],
                                   "shard-length breach must come back fail-flagged");
                    }
                }
            }
            // the session is still live: a real hot-swap clears any
            // poison, and a real batch serves bit-identically
            dp.rebroadcast(core2.clone(), &mut comm).unwrap();
            let x = Mat::from_fn(9, 2, |_, _| rng.normal());
            let (mean, var) = dp.predict(&mut comm, &mut backend, &x).unwrap();
            let single = Posterior::from_core(core2.clone());
            let (em, ev) = single.predict(&x);
            assert!(mean.max_abs_diff(&em) == 0.0,
                    "post-fuzz batch mean differs from single-node posterior");
            assert_eq!(var, ev, "post-fuzz batch variance differs");
            dp.finish(&mut comm).unwrap();
            None
        } else {
            Some(match worker_serve(&mut comm, &mut backend) {
                Ok(()) => "unexpected clean exit".to_string(),
                Err(e) => format!("{e:#}"),
            })
        }
    });
    let werr = results[1].clone().expect("worker outcome");
    assert!(werr.contains("rank 1"),
            "sticky error must name the rank, got {werr:?}");
}

/// SRV_REFIT against a standalone serving cluster (no training state to
/// refit with) must surface a clean protocol error on the worker, not a
/// hang or a panic.
#[test]
fn refit_verb_outside_training_cluster_errors_cleanly() {
    if replay_override().is_some() {
        return;
    }
    let core = fuzz_core(33);
    let results = Cluster::run(2, move |mut comm| {
        let mut backend = RustCpuBackend;
        if comm.rank() == 0 {
            let mut dp =
                DistributedPosterior::leader(core.clone(), 2, &mut comm).unwrap();
            let _ = comm.bcast(0, vec![SRV_REFIT]).unwrap();
            // the worker has left the session; closing is best-effort
            let _ = dp.finish(&mut comm);
            None
        } else {
            Some(match worker_serve(&mut comm, &mut backend) {
                Ok(()) => "unexpected clean exit".to_string(),
                Err(e) => format!("{e:#}"),
            })
        }
    });
    let werr = results[1].clone().expect("worker outcome");
    assert!(werr.contains("refit requested outside a training cluster"),
            "got {werr:?}");
}

fn fuzz_problem() -> (Problem, EngineConfig, Partition) {
    let spec = SyntheticSpec { n: 12, q: 2, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 41);
    let x = ds.x().unwrap();
    let problem = SparseGpRegression::problem(&x, &ds.y(), 3, "test", 41);
    let cfg = EngineConfig {
        workers: 2,
        chunk: 4,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs::default()),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let part = Partition::new(problem.n(), cfg.chunk, cfg.workers);
    (problem, cfg, part)
}

/// Top-level command-header fuzz: unknown verbs and a wrong-length
/// CMD_EVAL parameter wire are rank-exiting by design (the worker
/// cannot resync a desynced top-level stream) — assert the exit is a
/// clean error, not a panic or a hang.
#[test]
fn cluster_command_header_fuzz_errors_cleanly() {
    if replay_override().is_some() {
        return;
    }
    let bad_runs: Vec<(Vec<Vec<f64>>, &str)> = vec![
        (vec![vec![9.0]], "unknown command verb"),
        (vec![vec![f64::NAN]], "unknown command verb"),
        (vec![vec![-2.0]], "unknown command verb"),
        (vec![vec![0.5]], "unknown command verb"),
        // CMD_EVAL then a parameter wire far too short to be the
        // packed globals (which always hold Z, hyps and noise)
        (vec![vec![CMD_EVAL], vec![0.0]], "global-parameter broadcast"),
    ];
    for (wires, want) in bad_runs {
        let (problem, cfg, part) = fuzz_problem();
        let results = Cluster::run(2, move |mut comm| {
            if comm.rank() == 0 {
                for w in &wires {
                    let _ = comm.bcast(0, w.clone()).unwrap();
                }
                None
            } else {
                let mut ev =
                    DistributedEvaluator::new(&problem, &cfg, &part, comm).unwrap();
                Some(match ev.serve() {
                    Ok(()) => "unexpected clean exit".to_string(),
                    Err(e) => format!("{e:#}"),
                })
            }
        });
        let werr = results[1].clone().expect("worker outcome");
        assert!(werr.contains(want), "want {want:?} in {werr:?}");
    }
}

/// STATS-header fuzz: a wrong-length parameter wire inside a STATS
/// round is **sticky, not rank-exiting** — the worker ships a
/// fail-flagged all-zero reduction (lockstep preserved), parks back at
/// the command broadcast, still serves a full sharded session
/// afterwards (bit-identical to the single-node posterior), and
/// surfaces the breach at STOP.
#[test]
fn stats_header_fuzz_worker_stays_parked_then_serves() {
    if replay_override().is_some() {
        return;
    }
    let core = fuzz_core(34);
    let (problem, cfg, part) = fuzz_problem();
    let results = Cluster::run(2, move |mut comm| {
        let mut backend = RustCpuBackend;
        if comm.rank() == 0 {
            // bad STATS round: header fine, parameter wire too short
            let _ = comm.bcast(0, vec![CMD_STATS]).unwrap();
            let _ = comm.bcast(0, vec![0.0; 2]).unwrap();
            // the worker shipped a fail-flagged all-zero reduction;
            // consume it (our deliberately wrong-length root buffer
            // makes the reduce error out, which still drains the wire)
            let _ = comm.reduce_sum_linear(0, &[0.0]);
            // lockstep held: a whole serving session still works
            let _ = comm.bcast(0, vec![CMD_SERVE]).unwrap();
            let mut dp =
                DistributedPosterior::leader(core.clone(), 2, &mut comm).unwrap();
            let mut rng = Rng64::new(77);
            let x = Mat::from_fn(7, 2, |_, _| rng.normal());
            let (mean, var) = dp.predict(&mut comm, &mut backend, &x).unwrap();
            let single = Posterior::from_core(core.clone());
            let (em, ev) = single.predict(&x);
            assert!(mean.max_abs_diff(&em) == 0.0, "post-breach serve: mean");
            assert_eq!(var, ev, "post-breach serve: var");
            dp.finish(&mut comm).unwrap();
            // shut the cluster down; the sticky error surfaces now
            let _ = comm.bcast(0, vec![CMD_STOP]).unwrap();
            let _ = comm.gather(0, &[0.0]);
            None
        } else {
            let mut ev =
                DistributedEvaluator::new(&problem, &cfg, &part, comm).unwrap();
            Some(match ev.serve() {
                Ok(()) => "unexpected clean exit".to_string(),
                Err(e) => format!("{e:#}"),
            })
        }
    });
    let werr = results[1].clone().expect("worker outcome");
    assert!(werr.contains("global-parameter wire"),
            "sticky STATS breach must surface at STOP, got {werr:?}");
}
