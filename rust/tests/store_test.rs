//! Chunk-store integrity and equivalence tests: the manifest round-trips
//! bit-identically through its JSON document, structurally corrupt
//! manifests are rejected up front, payload corruption is caught by the
//! per-chunk checksums (chaos-fuzzer style single-bit flips), and a
//! `FileStore` drives the engine to the exact trajectory a
//! `ResidentStore` over the same bytes produces — for every cluster size
//! 1–9 and both CPU backends.

use gpparallel::config::{BackendKind, Json};
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::store::{materialize, ChunkReader, ChunkSource, FileStore,
                              ResidentStore, StoreManifest};
use gpparallel::data::synthetic::{generate_supervised_to_store, SyntheticSpec};
use gpparallel::models::SparseGpRegression;
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::Rng64;
use std::path::PathBuf;
use std::sync::Arc;

fn cfg(workers: usize, chunk: usize, backend: BackendKind, iters: usize) -> EngineConfig {
    EngineConfig {
        workers,
        chunk,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: iters, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    }
}

/// Fresh per-test store directory under the system temp dir.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("gpparallel_store_test_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn small_store(name: &str, n: usize, chunk_rows: usize, seed: u64)
               -> (PathBuf, StoreManifest) {
    let dir = tmp(name);
    let spec = SyntheticSpec { n, q: 1, d: 2, ..Default::default() };
    let man = generate_supervised_to_store(&spec, seed, &dir, chunk_rows).unwrap();
    (dir, man)
}

/// The manifest must survive JSON serialisation bit for bit — through
/// the in-memory document, through the rendered text, and through the
/// copy `FileStore::open` reads back off disk.
#[test]
fn manifest_roundtrip_is_bit_identical() {
    let (dir, man) = small_store("roundtrip", 53, 8, 5);

    let back = StoreManifest::from_json(&man.to_json()).unwrap();
    assert_eq!(man, back, "in-memory JSON round-trip changed the manifest");

    let text = man.to_json().to_string_pretty();
    let back = StoreManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(man, back, "rendered-text round-trip changed the manifest");

    let fs = FileStore::open(&dir).unwrap();
    assert_eq!(*fs.manifest(), man, "on-disk manifest differs from the writer's");

    std::fs::remove_dir_all(&dir).ok();
}

/// Every class of structural corruption must be rejected by
/// `StoreManifest::validate` (and hence by `from_json`, which calls it):
/// wrong n/d, partial chunks in the middle, overlapping or gapped
/// offsets, stats-arity mismatches, NaN statistics, min > max.
#[test]
fn corrupt_manifests_are_rejected() {
    let (dir, man) = small_store("corrupt_manifest", 40, 8, 6);
    assert!(man.validate().is_ok());

    let cases: Vec<(&str, fn(&mut StoreManifest))> = vec![
        ("n off by one", |m| m.n += 1),
        ("d zero", |m| m.d = 0),
        ("chunk_rows zero", |m| m.chunk_rows = 0),
        ("partial chunk before the last", |m| m.chunks[0].rows -= 1),
        ("offset gap", |m| m.chunks[1].offset += 8),
        ("offset overlap", |m| m.chunks[1].offset -= 8),
        ("y_mean arity", |m| m.y_mean.push(0.0)),
        ("non-finite y_mean", |m| m.y_mean[0] = f64::INFINITY),
        ("NaN summary statistics", |m| m.chunks[0].y_cols[0].mean = f64::NAN),
        ("min > max", |m| {
            m.chunks[0].y_cols[0].min = 1.0;
            m.chunks[0].y_cols[0].max = -1.0;
        }),
        ("stats arity", |m| m.chunks[0].x_cols.clear()),
        ("no chunks", |m| m.chunks.clear()),
    ];
    for (label, mutate) in cases {
        let mut bad = man.clone();
        mutate(&mut bad);
        assert!(bad.validate().is_err(), "{label}: validate accepted corruption");
        assert!(StoreManifest::from_json(&bad.to_json()).is_err(),
                "{label}: from_json accepted corruption");
    }

    // malformed checksum hex in the rendered document
    let text = man.to_json().to_string_pretty();
    let needle = format!("\"{:016x}\"", man.chunks[0].checksum);
    let bad_text = text.replacen(&needle, "\"zz-not-a-checksum\"", 1);
    assert_ne!(bad_text, text, "checksum needle not found in manifest text");
    assert!(StoreManifest::from_json(&Json::parse(&bad_text).unwrap()).is_err(),
            "malformed checksum hex accepted");

    // a manifest that *lies* about a checksum passes structural
    // validation but the payload fails verification at read time
    let mut lied = man.clone();
    lied.chunks[0].checksum ^= 1;
    let mpath = dir.join("manifest.json");
    std::fs::write(&mpath, lied.to_json().to_string_pretty()).unwrap();
    let fs = FileStore::open(&dir).unwrap();
    let mut x = vec![0.0; man.chunk_rows * man.q];
    let mut y = vec![0.0; man.chunk_rows * man.d];
    let mut reader = fs.open_reader().unwrap();
    assert!(reader.read_chunk(0, &mut x, &mut y).is_err(),
            "payload passed a lying checksum");

    // garbage manifest text: open must fail outright
    std::fs::write(&mpath, "not json").unwrap();
    assert!(FileStore::open(&dir).is_err(), "garbage manifest opened");
    std::fs::write(&mpath, man.to_json().to_string_pretty()).unwrap();

    // truncated data file: the exact-size check rejects it
    let dpath = dir.join(&man.data_file);
    let data = std::fs::read(&dpath).unwrap();
    std::fs::write(&dpath, &data[..data.len() - 1]).unwrap();
    assert!(FileStore::open(&dir).is_err(), "truncated data file opened");

    // clobbered magic
    let mut bad_magic = data.clone();
    bad_magic[0] ^= 0xFF;
    std::fs::write(&dpath, &bad_magic).unwrap();
    assert!(FileStore::open(&dir).is_err(), "bad magic opened");

    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos fuzzer over the data file: flip one random bit anywhere and the
/// store must refuse to serve the bytes — either `open` fails (magic /
/// size) or some chunk fails its FNV-1a checksum on read. FNV-1a's
/// per-byte step is a bijection of the running state, so any single-bit
/// payload flip is guaranteed to change the chunk's checksum.
#[test]
fn corrupt_payload_bits_are_detected() {
    let (dir, man) = small_store("bitflip", 53, 8, 7);
    let dpath = dir.join(&man.data_file);
    let clean = std::fs::read(&dpath).unwrap();

    let mut x = vec![0.0; man.chunk_rows * man.q];
    let mut y = vec![0.0; man.chunk_rows * man.d];
    let mut rng = Rng64::new(0xC0FFEE);
    for trial in 0..24 {
        let mut bytes = clean.clone();
        let pos = (rng.next_u64() as usize) % bytes.len();
        let bit = 1u8 << (rng.next_u64() % 8);
        bytes[pos] ^= bit;
        std::fs::write(&dpath, &bytes).unwrap();
        let detected = match FileStore::open(&dir) {
            Err(_) => true, // hit the magic
            Ok(fs) => {
                let mut reader = fs.open_reader().unwrap();
                (0..man.num_chunks())
                    .any(|k| reader.read_chunk(k, &mut x, &mut y).is_err())
            }
        };
        assert!(detected,
                "trial {trial}: bit {bit:#04x} at byte {pos} went undetected");
    }

    // the intact store still reads clean end to end
    std::fs::write(&dpath, &clean).unwrap();
    let fs = FileStore::open(&dir).unwrap();
    let mut reader = fs.open_reader().unwrap();
    for k in 0..man.num_chunks() {
        reader.read_chunk(k, &mut x, &mut y).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The load-bearing equivalence: an SGPR problem built from a
/// `FileStore` must train to the bit-exact trajectory of one built from
/// a `ResidentStore` wrapping the same bytes — across cluster sizes 1–9
/// (N=96 at chunk 16 leaves tail ranks with zero chunks) and both CPU
/// backends. The manifests themselves must agree bit for bit too: same
/// grid, same stats, same checksums.
#[test]
fn file_store_matches_resident_store_bit_for_bit() {
    let (dir, man) = small_store("equiv", 96, 16, 9);
    let file: Arc<dyn ChunkSource> = Arc::new(FileStore::open(&dir).unwrap());
    let (x, y) = materialize(file.as_ref()).unwrap();
    let resident: Arc<dyn ChunkSource> =
        Arc::new(ResidentStore::from_mats(x, y, man.chunk_rows).unwrap());
    assert_eq!(file.manifest(), resident.manifest(),
               "recomputed resident manifest differs from the on-disk one");

    let p_file = SparseGpRegression::problem_from_store(&file, 8, "test", 9).unwrap();
    let p_res = SparseGpRegression::problem_from_store(&resident, 8, "test", 9).unwrap();

    for workers in 1..=9usize {
        for backend in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 2 }] {
            let rf = Engine::new(p_file.clone(), cfg(workers, 16, backend, 3))
                .unwrap().train().unwrap();
            let rr = Engine::new(p_res.clone(), cfg(workers, 16, backend, 3))
                .unwrap().train().unwrap();
            assert_eq!(rf.f, rr.f,
                       "bounds differ (workers={workers}, backend={backend:?})");
            assert_eq!(rf.trace, rr.trace,
                       "trajectories differ (workers={workers}, backend={backend:?})");
            assert_eq!(rf.fitted.betas, rr.fitted.betas,
                       "betas differ (workers={workers}, backend={backend:?})");
            for (a, b) in rf.fitted.zs.iter().zip(&rr.fitted.zs) {
                assert_eq!(a.as_slice(), b.as_slice(),
                           "inducing inputs differ (workers={workers}, \
                            backend={backend:?})");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
