//! Engine-level integration tests: the distributed objective is exact
//! (worker-count invariant, gradient-checked against finite differences),
//! training improves the bound, and the three models behave.

use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, LatentSpec, OptChoice, Problem,
                              ViewSpec};
use gpparallel::data::synthetic::{generate, generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::models::{BayesianGplvm, Mrd, SparseGpRegression};
use gpparallel::optim::{Adam, Lbfgs};
use gpparallel::testutil::prop::Rng64;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn cfg(workers: usize, chunk: usize, backend: BackendKind, iters: usize) -> EngineConfig {
    EngineConfig {
        workers,
        chunk,
        backend,
        artifacts_dir: artifacts_dir(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: iters, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    }
}

fn small_problem(n: usize, seed: u64) -> Problem {
    let spec = SyntheticSpec { n, q: 2, d: 3, ..Default::default() };
    let ds = generate(&spec, seed);
    BayesianGplvm::problem(&ds.y(), 2, 16, "test", seed)
}

/// The objective must be bit-identical (up to reduction order) across
/// worker counts: the distributed cycle is exact, not approximate.
#[test]
fn worker_count_invariance() {
    let problem = small_problem(150, 11);
    let mut bounds = Vec::new();
    for workers in [1, 2, 4] {
        let engine = Engine::new(problem.clone(),
                                 cfg(workers, 64, BackendKind::RustCpu, 0)).unwrap();
        let r = engine.time_iterations(1).unwrap();
        bounds.push(r.f);
    }
    for w in bounds.windows(2) {
        assert!((w[0] - w[1]).abs() < 1e-9 * (1.0 + w[0].abs()),
                "objective differs across workers: {bounds:?}");
    }
}

/// Chunk size must not change the objective either (padding exactness).
#[test]
fn chunk_size_invariance() {
    let problem = small_problem(130, 12);
    let mut bounds = Vec::new();
    for chunk in [32, 64, 130] {
        let engine = Engine::new(problem.clone(),
                                 cfg(2, chunk, BackendKind::RustCpu, 0)).unwrap();
        let r = engine.time_iterations(1).unwrap();
        bounds.push(r.f);
    }
    for w in bounds.windows(2) {
        assert!((w[0] - w[1]).abs() < 1e-9 * (1.0 + w[0].abs()),
                "objective differs across chunk sizes: {bounds:?}");
    }
}

/// Finite-difference check of the full distributed gradient through the
/// engine (leader + workers + reductions), on a tiny problem.
#[test]
fn distributed_gradient_matches_finite_difference() {
    let n = 24;
    let mut rng = Rng64::new(13);
    let y = Mat::from_fn(n, 2, |_, _| rng.normal());
    let mu0 = Mat::from_fn(n, 1, |_, _| rng.normal());
    let s0 = Mat::from_vec(n, 1, vec![0.5; n]);
    let z0 = Mat::from_fn(5, 1, |_, _| rng.normal());
    let base = Problem {
        latent: LatentSpec::Variational { mu0: mu0.clone(), s0: s0.clone() },
        views: vec![ViewSpec {
            y: y.clone().into(),
            z0: z0.clone(),
            kern0: RbfArd::iso(1.1, 0.9, 1),
            beta0: 2.0,
            aot_config: "test".into(),
        }],
        q: 1,
    };

    // Evaluate F at the initial point via time_iterations (1 worker) and
    // compare against a perturbed problem for a few scalar directions.
    let f_at = |p: &Problem| -> f64 {
        let engine = Engine::new(p.clone(), cfg(2, 8, BackendKind::RustCpu, 0)).unwrap();
        engine.time_iterations(1).unwrap().f // TrainResult.f is F itself
    };

    // analytic gradient from one optimisation step probe: run Adam for 0
    // iters is not available; instead use the engine's objective via a
    // 1-iteration Adam whose first gradient we can recover from the move.
    // Simpler and more robust: exploit that time mode evaluates at x0, so
    // finite-difference the *problem inputs* that map linearly into x0.
    let eps = 1e-5;

    // d/d mu[3,0]
    let mut pp = base.clone();
    let mut pm = base.clone();
    if let LatentSpec::Variational { mu0, .. } = &mut pp.latent {
        mu0[(3, 0)] += eps;
    }
    if let LatentSpec::Variational { mu0, .. } = &mut pm.latent {
        mu0[(3, 0)] -= eps;
    }
    let fd_mu = (f_at(&pp) - f_at(&pm)) / (2.0 * eps);

    // d/d z[2,0]
    let mut pp = base.clone();
    let mut pm = base.clone();
    pp.views[0].z0[(2, 0)] += eps;
    pm.views[0].z0[(2, 0)] -= eps;
    let fd_z = (f_at(&pp) - f_at(&pm)) / (2.0 * eps);

    // analytic: single monolithic Rust evaluation
    use gpparallel::math::bound::bound_and_grads;
    use gpparallel::math::stats::{bgplvm_stats_fwd, bgplvm_stats_vjp};
    let kern = RbfArd::iso(1.1, 0.9, 1);
    let w = vec![1.0; n];
    let st = bgplvm_stats_fwd(&kern, &mu0, &s0, &w, &y, &z0);
    let out = bound_and_grads(&st, &z0, &kern, 2.0f64.ln()).unwrap();
    let g = bgplvm_stats_vjp(&kern, &mu0, &s0, &w, &y, &z0, &out.cts);
    let dmu_analytic = g.dmu[(3, 0)];
    let dz_analytic = out.dz[(2, 0)] + g.dz[(2, 0)];

    assert!((fd_mu - dmu_analytic).abs() < 1e-4 * (1.0 + dmu_analytic.abs()),
            "dmu: fd {fd_mu} vs analytic {dmu_analytic}");
    assert!((fd_z - dz_analytic).abs() < 1e-4 * (1.0 + dz_analytic.abs()),
            "dz: fd {fd_z} vs analytic {dz_analytic}");
}

#[test]
fn training_improves_bound_monotonically() {
    let problem = small_problem(120, 14);
    let engine = Engine::new(problem, cfg(2, 64, BackendKind::RustCpu, 25)).unwrap();
    let r = engine.train().unwrap();
    assert!(r.trace.len() >= 2, "no optimisation happened");
    assert!(*r.trace.last().unwrap() > r.trace.first().unwrap() + 1.0,
            "bound did not improve: {:?}", (r.trace.first(), r.trace.last()));
    for w in r.trace.windows(2) {
        assert!(w[1] >= w[0] - 1e-9, "bound decreased during L-BFGS");
    }
}

#[test]
fn xla_and_rust_training_match() {
    if !have_artifacts() {
        return;
    }
    let problem = small_problem(128, 15);
    let r_cpu = Engine::new(problem.clone(), cfg(2, 64, BackendKind::RustCpu, 10))
        .unwrap().train().unwrap();
    let r_xla = Engine::new(problem, cfg(2, 64, BackendKind::Xla, 10))
        .unwrap().train().unwrap();
    // same trajectory to tight tolerance (same math, different engines)
    assert!((r_cpu.f - r_xla.f).abs() < 1e-5 * (1.0 + r_cpu.f.abs()),
            "final bounds differ: {} vs {}", r_cpu.f, r_xla.f);
}

#[test]
fn sgpr_fits_and_predicts() {
    let spec = SyntheticSpec { n: 300, q: 1, d: 1, noise: 0.01, ..Default::default() };
    let ds = generate_supervised(&spec, 16);
    let x = ds.x().unwrap();
    let model = SparseGpRegression::fit(&x, &ds.y(), 16, "quickstart",
                                        cfg(2, 64, BackendKind::RustCpu, 60), 16).unwrap();
    let rmse = model.rmse(&x, &ds.y());
    // var(y) ~ 1; the fit must beat the mean predictor by a wide margin
    assert!(rmse < 0.3, "train RMSE {rmse}");
    // noise recovery within an order of magnitude
    let beta = model.result.fitted.betas[0];
    assert!(beta > 5.0, "learned beta {beta} vs true 100");
}

#[test]
fn bgplvm_recovers_1d_latent() {
    let spec = SyntheticSpec { n: 200, q: 1, d: 3, noise: 1e-3, ..Default::default() };
    let ds = generate(&spec, 17);
    // Q=2 model on truly-1D data (the test config is Q=2): alignment of
    // the best dimension with the truth should still be high.
    let model = BayesianGplvm::fit(&ds.y(), 2, 16, "test",
                                   cfg(2, 64, BackendKind::RustCpu, 120), 17).unwrap();
    let align = model.latent_alignment(ds.latent_truth().unwrap());
    assert!(align > 0.8, "latent alignment {align}");
}

#[test]
fn mrd_two_views_train() {
    let mut rng = Rng64::new(18);
    let n = 90;
    // shared 1-D signal + per-view distortions, 4-D observations each
    let shared: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mk_view = |rng: &mut Rng64, phase: f64| {
        Mat::from_fn(n, 4, |i, j| {
            (shared[i] * (1.0 + 0.2 * j as f64) + phase).sin() + 0.05 * rng.normal()
        })
    };
    let v1 = mk_view(&mut rng, 0.0);
    let v2 = mk_view(&mut rng, 1.0);
    let model = Mrd::fit(&[v1, v2], 3, 20, &["mrd", "mrd"],
                         cfg(2, 64, BackendKind::RustCpu, 40), 18).unwrap();
    assert!(model.result.f.is_finite());
    assert!(model.result.trace.last().unwrap() > model.result.trace.first().unwrap(),
            "MRD bound did not improve");
    let rel = model.relevance();
    assert_eq!(rel.len(), 2);
    assert_eq!(rel[0].len(), 3);
}

#[test]
fn adam_optimizer_also_trains() {
    let problem = small_problem(100, 19);
    let mut c = cfg(1, 64, BackendKind::RustCpu, 0);
    c.opt = OptChoice::Adam(Adam { lr: 5e-2, max_iters: 60, ..Default::default() });
    let r = Engine::new(problem, c).unwrap().train().unwrap();
    assert!(r.trace.last().unwrap() > r.trace.first().unwrap(),
            "Adam made no progress");
}

#[test]
fn timing_and_comm_accounting_populated() {
    let problem = small_problem(128, 20);
    let engine = Engine::new(problem, cfg(3, 32, BackendKind::RustCpu, 0)).unwrap();
    let r = engine.time_iterations(3).unwrap();
    assert_eq!(r.evaluations, 3);
    assert!(r.sec_per_eval > 0.0);
    assert!(r.bytes_sent > 0, "no traffic counted");
    assert_eq!(r.per_rank_compute.len(), 3);
    assert!(r.per_rank_compute.iter().all(|&t| t > 0.0),
            "per-rank compute missing: {:?}", r.per_rank_compute);
    assert!(r.projected_sec_per_eval() > 0.0);
    let frac = r.timing.indistributable_fraction();
    assert!((0.0..=1.0).contains(&frac));
}
