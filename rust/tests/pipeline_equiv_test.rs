//! Pipelined vs synchronous evaluation-cycle equivalence. The per-view
//! pipeline reorders *when* collectives run, never *what* they carry:
//! the same chunk math reduces element-wise over the same trees, so the
//! objective and every optimiser step must match the synchronous
//! schedule bit for bit — across worker counts (including ranks with
//! zero chunks), backends, and model families. The per-view abort
//! protocol must surface mid-cycle failures as `Err` without desyncing
//! the collectives.

use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, LatentSpec, OptChoice, Problem,
                              ViewSpec};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::models::Mrd;
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::Rng64;

fn cfg(workers: usize, chunk: usize, backend: BackendKind, iters: usize,
       pipeline: bool) -> EngineConfig {
    EngineConfig {
        workers,
        chunk,
        backend,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: iters, ..Default::default() }),
        pipeline,
        verbose: false,
        simd: None,
    }
}

/// Two unsupervised views sharing q(X) — the pipeline's interesting
/// case: cotangents for view 0 arrive while view 1's stats still reduce.
fn multi_view_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Rng64::new(seed);
    let shared: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let v1 = Mat::from_fn(n, 3, |i, j| (shared[i] * (1.0 + 0.3 * j as f64)).sin()
        + 0.05 * ((i * 7 + j) as f64).cos());
    let v2 = Mat::from_fn(n, 4, |i, j| (shared[i] + 0.5 * j as f64).cos()
        + 0.05 * ((i * 3 + j) as f64).sin());
    Mrd::problem(&[v1, v2], 2, 12, &["test", "test"], seed)
}

/// Three views — two fwd reductions can be in flight behind a vjp, the
/// deepest pipelining the schedule produces.
fn three_view_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Rng64::new(seed);
    let shared: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let views: Vec<Mat> = (0..3)
        .map(|k| {
            Mat::from_fn(n, 2 + k, |i, j| (shared[i] + 0.4 * (k * 2 + j) as f64).sin()
                + 0.05 * ((i * 5 + j + k) as f64).cos())
        })
        .collect();
    Mrd::problem(&views, 2, 10, &["test", "test", "test"], seed)
}

/// A supervised single-view problem (SGPR) — exercises the K_fu fwd→vjp
/// cache path end to end.
fn supervised_problem(n: usize, seed: u64) -> Problem {
    let spec = SyntheticSpec { n, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, seed);
    Problem {
        latent: LatentSpec::Observed(ds.x().unwrap()),
        views: vec![ViewSpec {
            y: ds.y().into(),
            z0: Mat::from_fn(8, 1, |i, _| -2.0 + 0.5 * i as f64),
            kern0: RbfArd::iso(1.0, 1.0, 1),
            beta0: 10.0,
            aot_config: "test".into(),
        }],
        q: 1,
    }
}

/// The pipelined objective must equal the synchronous one exactly, for
/// every cluster size 1–9 (N=96 at chunk 16 leaves the tail ranks with
/// zero chunks) and for both CPU backends.
#[test]
fn pipelined_objective_bit_identical_across_ranks() {
    let problem = multi_view_problem(96, 31);
    for workers in 1..=9usize {
        for backend in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 2 }] {
            let sync = Engine::new(problem.clone(), cfg(workers, 16, backend, 0, false))
                .unwrap()
                .time_iterations(1)
                .unwrap();
            let pipe = Engine::new(problem.clone(), cfg(workers, 16, backend, 0, true))
                .unwrap()
                .time_iterations(1)
                .unwrap();
            assert_eq!(sync.f, pipe.f,
                       "objective differs (workers={workers}, backend={backend:?})");
        }
    }

    // three views: two fwd reductions in flight behind each vjp
    let problem = three_view_problem(64, 35);
    for workers in [1usize, 2, 5, 9] {
        let sync = Engine::new(problem.clone(),
                               cfg(workers, 16, BackendKind::RustCpu, 0, false))
            .unwrap().time_iterations(1).unwrap();
        let pipe = Engine::new(problem.clone(),
                               cfg(workers, 16, BackendKind::RustCpu, 0, true))
            .unwrap().time_iterations(1).unwrap();
        assert_eq!(sync.f, pipe.f, "3-view objective differs (workers={workers})");
    }
}

/// Short training runs must follow the identical trajectory — the
/// optimiser is deterministic, so bit-equal traces mean bit-equal
/// gradients at every accepted step.
#[test]
fn pipelined_training_trajectory_bit_identical() {
    let problem = multi_view_problem(72, 32);
    for backend in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 2 }] {
        let sync = Engine::new(problem.clone(), cfg(3, 8, backend, 6, false))
            .unwrap().train().unwrap();
        let pipe = Engine::new(problem.clone(), cfg(3, 8, backend, 6, true))
            .unwrap().train().unwrap();
        assert_eq!(sync.trace.len(), pipe.trace.len(),
                   "iteration counts differ ({backend:?})");
        for (a, b) in sync.trace.iter().zip(&pipe.trace) {
            assert_eq!(a, b, "trajectories diverged ({backend:?})");
        }
    }
}

/// Supervised models ride the same pipeline (no (μ, S) scatter, no
/// gather payload): objective and training must match exactly too.
#[test]
fn pipelined_supervised_matches_sync() {
    let problem = supervised_problem(100, 33);
    for workers in [1usize, 3, 5] {
        let sync = Engine::new(problem.clone(),
                               cfg(workers, 16, BackendKind::RustCpu, 0, false))
            .unwrap().time_iterations(1).unwrap();
        let pipe = Engine::new(problem.clone(),
                               cfg(workers, 16, BackendKind::RustCpu, 0, true))
            .unwrap().time_iterations(1).unwrap();
        assert_eq!(sync.f, pipe.f, "supervised objective differs (workers={workers})");
    }
    let sync = Engine::new(problem.clone(), cfg(2, 32, BackendKind::RustCpu, 5, false))
        .unwrap().train().unwrap();
    let pipe = Engine::new(problem, cfg(2, 32, BackendKind::RustCpu, 5, true))
        .unwrap().train().unwrap();
    for (a, b) in sync.trace.iter().zip(&pipe.trace) {
        assert_eq!(a, b, "supervised trajectories diverged");
    }
}

/// Failure injection for the per-view abort: the *middle* view of a
/// three-view problem is poisoned so its leader-side M×M core fails
/// after view 0's cotangents have already been broadcast and while view
/// 2's forward reduction is already in flight — the mid-cycle abort the
/// pipelined protocol must truncate identically on both sides (the
/// leader absorbs the in-flight reduction, nobody issues view 2's
/// cotangents or gradients). Driving three evaluations through the same
/// evaluator proves each abort left the collectives in lockstep (a
/// desync would hang or panic, not return `Err`).
#[test]
fn per_view_abort_surfaces_err_without_desync() {
    let n = 40;
    let mut rng = Rng64::new(34);
    let y0 = Mat::from_fn(n, 2, |_, _| rng.normal());
    let y1 = Mat::from_fn(n, 2, |_, _| rng.normal());
    let y2 = Mat::from_fn(n, 3, |_, _| rng.normal());
    let mu0 = Mat::from_fn(n, 1, |_, _| rng.normal());
    let s0 = Mat::from_vec(n, 1, vec![0.5; n]);
    let mk_healthy = |y: Mat| ViewSpec {
        y: y.into(),
        z0: Mat::from_fn(4, 1, |i, _| i as f64 - 1.5),
        kern0: RbfArd::iso(1.0, 1.0, 1),
        beta0: 2.0,
        aot_config: "test".into(),
    };
    // duplicate + enormous inducing inputs with a degenerate lengthscale:
    // view 1's statistics go non-finite and its Cholesky fails at the
    // leader, while views 0 and 2 stay healthy.
    let poisoned = ViewSpec {
        y: y1.into(),
        z0: Mat::from_vec(4, 1, vec![f64::MAX / 1e3; 4]),
        kern0: RbfArd::iso(1.0, 1e-300, 1),
        beta0: 1e300,
        aot_config: "test".into(),
    };
    let problem = Problem {
        latent: LatentSpec::Variational { mu0, s0 },
        views: vec![mk_healthy(y0), poisoned, mk_healthy(y2)],
        q: 1,
    };
    for pipeline in [false, true] {
        let result = Engine::new(problem.clone(),
                                 cfg(3, 8, BackendKind::RustCpu, 0, pipeline))
            .unwrap()
            .time_iterations(3);
        assert!(result.is_err(),
                "poisoned view must surface Err (pipeline={pipeline})");
    }
}
