//! Integration test for the process-global SIMD dispatch level.
//!
//! The in-crate property tests pin each tier's *numerics* (scalar
//! reference vs chunked-scalar vs AVX2+FMA, per kernel, via the `_at`
//! variants, without touching the global). This binary exercises the
//! *global* instead: the env-resolved startup level, `set_active`
//! actually redirecting the public kernel wrappers, and `EngineConfig`
//! plumbing the level through `Engine::new`.
//!
//! CI runs the whole test suite twice — once unadorned and once under
//! `GPPAR_SIMD=off` — and the first assertion here is what gives the
//! off-job teeth: with the variable set, every public kernel in that job
//! demonstrably runs the bit-identical pre-SIMD scalar code.
//!
//! Everything lives in ONE `#[test]` on purpose: cargo runs a binary's
//! tests on parallel threads, and these steps mutate the process-global
//! level. Sequencing inside a single test is the only race-free option.

// Miri cannot execute the AVX2 intrinsics this binary exists to
// exercise (`detect_native` reports false under Miri, making every
// assertion here vacuous), and the full train/predict round-trips are
// far past its budget. The scalar tiers get their Miri coverage from
// the lib unit tests.
#![cfg(not(miri))]

use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::synthetic::{generate, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::simd::{self, SimdLevel};
use gpparallel::linalg::Mat;
use gpparallel::models::BayesianGplvm;
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::Rng64;
use gpparallel::testutil::ulp::assert_mat_close_ulps;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run every public kernel the SIMD rewrite touched, at the current
/// global level, and bundle the outputs for comparison across levels.
fn kernel_outputs(seed: u64) -> (Mat, Mat, Mat, Mat, Mat) {
    let mut rng = Rng64::new(seed);
    // deliberately non-multiple-of-4 dims so lane tails are exercised
    let a = Mat::from_fn(19, 13, |_, _| rng.normal());
    let b = Mat::from_fn(13, 17, |_, _| rng.normal());
    let (c, m, q) = (23usize, 7usize, 3usize);
    let mu = Mat::from_fn(c, q, |_, _| rng.normal());
    let s = Mat::from_fn(c, q, |_, _| 0.2 + rng.normal().abs());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let w: Vec<f64> = (0..c).map(|_| 0.5 + rng.normal().abs()).collect();
    let kern = RbfArd::new(1.3, vec![0.8, 1.1, 0.6]);
    (
        a.matmul(&b),
        a.t_matmul(&a),
        kern.k(&mu, &z),
        kern.psi1(&mu, &s, &z),
        kern.psi2(&mu, &s, &w, &z),
    )
}

#[test]
fn global_dispatch_env_set_active_and_engine_config() {
    // -- 1. startup resolution honours GPPAR_SIMD ---------------------
    // `active()` has not been forced yet in this process, so the first
    // call performs the lazy env resolution `Engine::new`-less binaries
    // (and every rank of a cluster) see at startup.
    let startup = simd::active();
    match std::env::var("GPPAR_SIMD").ok().as_deref().and_then(SimdLevel::parse) {
        Some(pinned) => assert_eq!(
            startup, pinned,
            "GPPAR_SIMD is set: the startup level must obey it"
        ),
        None => {
            // auto: never the Off escape hatch, Native only if detected
            assert_ne!(startup, SimdLevel::Off, "auto-detection must never pick Off");
            if startup == SimdLevel::Native {
                assert!(simd::native_available());
            }
        }
    }

    // -- 2. set_active redirects the public kernel wrappers -----------
    // Off twice must be bitwise-reproducible (it is plain sequential
    // scalar code), and every other tier must agree with Off to tight
    // ulps on the same inputs.
    simd::set_active(SimdLevel::Off);
    assert_eq!(simd::active(), SimdLevel::Off);
    let off = kernel_outputs(42);
    let off_again = kernel_outputs(42);
    for (x, y) in [
        (&off.0, &off_again.0),
        (&off.1, &off_again.1),
        (&off.2, &off_again.2),
        (&off.3, &off_again.3),
        (&off.4, &off_again.4),
    ] {
        assert_mat_close_ulps(x, y, 0, 0.0, "Off tier must be deterministic");
    }
    for level in [SimdLevel::Scalar, SimdLevel::Native] {
        simd::set_active(level);
        let got = kernel_outputs(42);
        let what = |k: &str| format!("{k} at {} vs Off", level.name());
        assert_mat_close_ulps(&got.0, &off.0, 64, 1e-12, &what("matmul"));
        assert_mat_close_ulps(&got.1, &off.1, 64, 1e-12, &what("t_matmul"));
        assert_mat_close_ulps(&got.2, &off.2, 4096, 1e-12, &what("k"));
        assert_mat_close_ulps(&got.3, &off.3, 4096, 1e-12, &what("psi1"));
        assert_mat_close_ulps(&got.4, &off.4, 4096, 1e-12, &what("psi2"));
    }

    // -- 3. EngineConfig { simd: Some(level) } wins over everything ---
    let spec = SyntheticSpec { n: 40, q: 1, d: 2, ..Default::default() };
    let ds = generate(&spec, 3);
    for level in [SimdLevel::Scalar, SimdLevel::Off] {
        let cfg = EngineConfig {
            workers: 1,
            chunk: 32,
            backend: BackendKind::RustCpu,
            artifacts_dir: artifacts_dir(),
            opt: OptChoice::Lbfgs(Lbfgs { max_iters: 0, ..Default::default() }),
            pipeline: true,
            verbose: false,
            simd: Some(level),
        };
        let problem = BayesianGplvm::problem(&ds.y(), 1, 8, "test", 3);
        let _engine = Engine::new(problem, cfg).expect("engine construction");
        assert_eq!(simd::active(), level,
                   "Engine::new must apply cfg.simd process-wide");
    }

    // leave the process at its startup level for any later assertions
    simd::set_active(startup);
}
