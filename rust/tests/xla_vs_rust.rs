//! Cross-implementation integration tests: the XLA artifact path (Pallas
//! L1 + JAX L2, AOT-compiled, run through PJRT) must agree with the
//! independent pure-Rust implementation (kern + math) to rounding error
//! on statistics, cotangent pullbacks, and the bound module.
//!
//! Requires `make artifacts`; tests skip (with a note) if missing.

use gpparallel::coordinator::backend::{Backend, ChunkData, RustCpuBackend, ViewParams,
                                       XlaBackend};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::math::bound::bound_and_grads;
use gpparallel::math::stats::{Stats, StatsCts};
use gpparallel::runtime::{Arg, Runtime};
use gpparallel::testutil::prop::Rng64;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

struct Fixture {
    kern: RbfArd,
    chunk: ChunkData,
    mu: Mat,
    s: Mat,
    z: Mat,
    log_hyp: Vec<f64>,
}

/// Random problem matching the `test` AOT config (C=64, M=16, Q=2, D=3),
/// with a masked tail to exercise padding.
fn fixture(seed: u64) -> Fixture {
    let (c, m, q, d) = (64, 16, 2, 3);
    let mut rng = Rng64::new(seed);
    let kern = RbfArd::new(rng.uniform_range(0.5, 1.5),
                           (0..q).map(|_| rng.uniform_range(0.6, 1.6)).collect());
    let mu = Mat::from_fn(c, q, |_, _| rng.normal());
    let mut s = Mat::from_fn(c, q, |_, _| rng.uniform_range(0.2, 1.3));
    let live = c - 7;
    let mut w = vec![0.0; c];
    w[..live].fill(1.0);
    // padded rows carry (mu=0, s=1) like the engine sends
    for i in live..c {
        for j in 0..q {
            s[(i, j)] = 1.0;
        }
    }
    let y = Mat::from_fn(c, d, |i, _| if i < live { rng.normal() } else { 0.0 });
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let log_hyp = kern.to_log_hyp();
    Fixture {
        kern,
        chunk: ChunkData { start: 0, live, y, x: Mat::zeros(0, 0), w },
        mu,
        s,
        z,
        log_hyp,
    }
}

fn assert_stats_close(a: &Stats, b: &Stats, tol: f64, what: &str) {
    assert!((a.psi0 - b.psi0).abs() < tol, "{what}: psi0 {} vs {}", a.psi0, b.psi0);
    assert!(a.p.max_abs_diff(&b.p) < tol, "{what}: P diff {}", a.p.max_abs_diff(&b.p));
    assert!(a.psi2.max_abs_diff(&b.psi2) < tol, "{what}: Psi2 diff {}",
            a.psi2.max_abs_diff(&b.psi2));
    assert!((a.tryy - b.tryy).abs() < tol, "{what}: tryy");
    assert!((a.kl - b.kl).abs() < tol, "{what}: kl {} vs {}", a.kl, b.kl);
}

#[test]
fn bgplvm_stats_fwd_backends_agree() {
    if !have_artifacts() {
        return;
    }
    let (rt, mut xla) = XlaBackend::from_dir(&artifacts_dir(), "test").unwrap();
    let _ = &rt;
    let mut cpu = RustCpuBackend;
    for seed in [1, 2, 3] {
        let fx = fixture(seed);
        let vp = ViewParams { z: &fx.z, log_hyp: &fx.log_hyp };
        let a = cpu.stats_fwd(&fx.chunk, Some((&fx.mu, &fx.s)), &vp, true).unwrap();
        let b = xla.stats_fwd(&fx.chunk, Some((&fx.mu, &fx.s)), &vp, true).unwrap();
        assert_stats_close(&a, &b, 1e-9, "bgplvm fwd");
    }
}

#[test]
fn sgpr_stats_fwd_backends_agree() {
    if !have_artifacts() {
        return;
    }
    let (rt, mut xla) = XlaBackend::from_dir(&artifacts_dir(), "test").unwrap();
    let _ = &rt;
    let mut cpu = RustCpuBackend;
    let mut fx = fixture(4);
    fx.chunk.x = fx.mu.clone(); // supervised inputs
    let vp = ViewParams { z: &fx.z, log_hyp: &fx.log_hyp };
    let a = cpu.stats_fwd(&fx.chunk, None, &vp, false).unwrap();
    let b = xla.stats_fwd(&fx.chunk, None, &vp, false).unwrap();
    assert_stats_close(&a, &b, 1e-9, "sgpr fwd");
}

#[test]
fn bgplvm_vjp_backends_agree() {
    if !have_artifacts() {
        return;
    }
    let (rt, mut xla) = XlaBackend::from_dir(&artifacts_dir(), "test").unwrap();
    let _ = &rt;
    let mut cpu = RustCpuBackend;
    let fx = fixture(5);
    let mut rng = Rng64::new(99);
    let cts = StatsCts {
        c_psi0: rng.normal(),
        c_p: Mat::from_fn(16, 3, |_, _| rng.normal()),
        c_psi2: Mat::from_fn(16, 16, |_, _| rng.normal()),
        c_tryy: rng.normal(),
        c_kl: -1.0,
    };
    let vp = ViewParams { z: &fx.z, log_hyp: &fx.log_hyp };
    let a = cpu.stats_vjp(&fx.chunk, Some((&fx.mu, &fx.s)), &vp, &cts).unwrap();
    let b = xla.stats_vjp(&fx.chunk, Some((&fx.mu, &fx.s)), &vp, &cts).unwrap();
    assert!(a.dmu.max_abs_diff(&b.dmu) < 1e-9, "dmu");
    assert!(a.ds.max_abs_diff(&b.ds) < 1e-9, "ds");
    assert!(a.dz.max_abs_diff(&b.dz) < 1e-9, "dz");
    for (x, y) in a.dhyp.iter().zip(&b.dhyp) {
        assert!((x - y).abs() < 1e-9, "dhyp {x} vs {y}");
    }
}

#[test]
fn sgpr_vjp_backends_agree() {
    if !have_artifacts() {
        return;
    }
    let (rt, mut xla) = XlaBackend::from_dir(&artifacts_dir(), "test").unwrap();
    let _ = &rt;
    let mut cpu = RustCpuBackend;
    let mut fx = fixture(6);
    fx.chunk.x = fx.mu.clone();
    let mut rng = Rng64::new(100);
    let cts = StatsCts {
        c_psi0: rng.normal(),
        c_p: Mat::from_fn(16, 3, |_, _| rng.normal()),
        c_psi2: Mat::from_fn(16, 16, |_, _| rng.normal()),
        c_tryy: rng.normal(),
        c_kl: 0.0,
    };
    let vp = ViewParams { z: &fx.z, log_hyp: &fx.log_hyp };
    let a = cpu.stats_vjp(&fx.chunk, None, &vp, &cts).unwrap();
    let b = xla.stats_vjp(&fx.chunk, None, &vp, &cts).unwrap();
    assert!(a.dz.max_abs_diff(&b.dz) < 1e-9, "dz");
    for (x, y) in a.dhyp.iter().zip(&b.dhyp) {
        assert!((x - y).abs() < 1e-9, "dhyp");
    }
}

/// The `bound` artifact (JAX value_and_grad with the pure-jnp Cholesky)
/// must match the Rust leader core: value, all five cotangents, and the
/// direct (Z, hyp, β) gradients.
#[test]
fn bound_module_matches_rust_leader_core() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let exe = rt.module("test", "bound").unwrap();
    let mut cpu = RustCpuBackend;
    let fx = fixture(7);
    let vp = ViewParams { z: &fx.z, log_hyp: &fx.log_hyp };
    let stats = cpu.stats_fwd(&fx.chunk, Some((&fx.mu, &fx.s)), &vp, true).unwrap();
    let log_beta = 0.4;

    let rust = bound_and_grads(&stats, &fx.z, &fx.kern, log_beta).unwrap();

    let out = exe.call(&[
        Arg::Scalar(stats.psi0),
        Arg::Buf(stats.p.as_slice()),
        Arg::Buf(stats.psi2.as_slice()),
        Arg::Scalar(stats.tryy),
        Arg::Scalar(stats.kl),
        Arg::Buf(fx.z.as_slice()),
        Arg::Buf(&fx.log_hyp),
        Arg::Scalar(log_beta),
        Arg::Scalar(stats.n_eff),
    ]).unwrap();

    // A = K_uu + beta*Psi2 is moderately ill-conditioned; the two Cholesky
    // implementations (Rust Banachiewicz vs the jnp fori-loop) round
    // differently and A^-1 amplifies by the condition number.
    let tol = 1e-5;
    assert!((out[0][0] - rust.f).abs() < tol * (1.0 + rust.f.abs()),
            "F: {} vs {}", out[0][0], rust.f);
    assert!((out[1][0] - rust.cts.c_psi0).abs() < tol, "c_psi0");
    let c_p = Mat::from_vec(16, 3, out[2].clone());
    assert!(c_p.max_abs_diff(&rust.cts.c_p) < tol, "c_p diff {}",
            c_p.max_abs_diff(&rust.cts.c_p));
    // The jnp Cholesky reads only the lower triangle of A, so jax lumps
    // each symmetric pair's gradient into the lower entry; the Rust core
    // distributes it symmetrically. The two cotangents are equivalent on
    // symmetric Psi2 (only c[i,j]+c[j,i] is observable) — compare folded.
    let c_psi2_raw = Mat::from_vec(16, 16, out[3].clone());
    let fold = |m: &Mat| {
        let mut f = m.clone();
        f.axpy(1.0, &m.t());
        f
    };
    let c_psi2 = fold(&c_psi2_raw);
    let rust_c_psi2 = fold(&rust.cts.c_psi2);
    // relative: K_uu^-1 terms can be huge when inducing points are close
    let c_psi2_scale = rust_c_psi2.as_slice().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    assert!(c_psi2.max_abs_diff(&rust_c_psi2) < tol * (1.0 + c_psi2_scale),
            "c_psi2 diff {} (scale {})", c_psi2.max_abs_diff(&rust_c_psi2), c_psi2_scale);
    assert!((out[4][0] - rust.cts.c_tryy).abs() < tol, "c_tryy");
    assert!((out[5][0] - rust.cts.c_kl).abs() < tol, "c_kl");
    let dz = Mat::from_vec(16, 2, out[6].clone());
    let dz_scale = rust.dz.as_slice().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    assert!(dz.max_abs_diff(&rust.dz) < tol * (1.0 + dz_scale),
            "dz diff {}", dz.max_abs_diff(&rust.dz));
    for (a, b) in out[7].iter().zip(&rust.dhyp) {
        assert!((a - b).abs() < tol * (1.0 + b.abs()), "dhyp {a} vs {b}");
    }
    assert!((out[8][0] - rust.dlog_beta).abs() < tol * (1.0 + rust.dlog_beta.abs()),
            "dlog_beta {} vs {}", out[8][0], rust.dlog_beta);
}
