//! Concurrent-client serving front-end: correctness under concurrency.
//!
//! Five layers of guarantees over the micro-batching scheduler
//! (`ServingFrontend`) in front of the sharded serving protocol:
//! 1. with several clients enqueueing interleaved ragged (and empty)
//!    requests, every reply is **bit-identical** to the single-node
//!    posterior's answer for that request alone — for every cluster
//!    size 1–9 and both CPU backends (coalescing is pure row
//!    concatenation and sharded serving is row-independent);
//! 2. a mid-stream hot-swap is applied on a **batch boundary**: every
//!    reply is entirely pre-swap or entirely post-swap (never a mix),
//!    and every request issued after `swap` returned sees the new
//!    posterior;
//! 3. a poisoned worker fails only the in-flight batch — the session
//!    stays usable, later requests (and a good swap) succeed, the
//!    worker reports the sticky error at close, and nothing deadlocks;
//! 4. backpressure bounds the queue: an enqueue that would overflow
//!    `queue_rows` blocks until the queue drains, and both requests
//!    still complete bit-identically;
//! 5. the `Engine`-level hand-off (`train_then_serve`) serves replies
//!    bit-identical to `train_then_predict`, and a mid-session `refit`
//!    swaps to exactly the posterior implied by the serial chunked
//!    stats at the refit parameters.

use anyhow::{bail, Result};
use gpparallel::collectives::Cluster;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use gpparallel::coordinator::{Backend, ChunkData, Engine, EngineConfig, FrontendConfig,
                              OptChoice, ParallelCpuBackend, RustCpuBackend,
                              ServingFrontend, ViewParams};
use gpparallel::data::synthetic::{generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::math::predict::PosteriorCore;
use gpparallel::math::stats::{sgpr_stats_fwd, sgpr_stats_fwd_chunked, ChunkGrads,
                              Stats, StatsCts};
use gpparallel::models::{Posterior, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use gpparallel::testutil::prop::Rng64;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn toy_core(seed: u64, n: usize, m: usize, q: usize, d: usize) -> PosteriorCore {
    let mut rng = Rng64::new(seed);
    let x = Mat::from_fn(n, q, |_, _| rng.normal());
    let y = Mat::from_fn(n, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::new(1.4, (0..q).map(|_| rng.uniform_range(0.7, 1.3)).collect());
    let w = vec![1.0; n];
    let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
    PosteriorCore::new(kern, z, 15.0, &st).unwrap()
}

fn backend_for(kind: BackendKind) -> Box<dyn Backend> {
    match kind {
        BackendKind::RustCpu => Box::new(RustCpuBackend),
        BackendKind::ParallelCpu { threads } => Box::new(ParallelCpuBackend::new(threads)),
        BackendKind::Xla => unreachable!("not exercised here"),
    }
}

/// Assert one reply is bit-identical to an expectation.
fn assert_reply(got: &(Mat, Vec<f64>), want: &(Mat, Vec<f64>), ctx: &str) {
    assert!(got.0.max_abs_diff(&want.0) == 0.0, "{ctx}: mean differs");
    assert_eq!(got.1, want.1, "{ctx}: var differs");
}

/// The acceptance-criteria matrix: three concurrent clients with
/// interleaved ragged (and empty) request streams, every reply
/// bit-identical to the single-node posterior's answer for that request
/// alone — ranks 1–9 × both CPU backends. The micro-batch size (6) is
/// deliberately smaller than most coalesced loads so batches routinely
/// span requests from different clients.
#[test]
fn frontend_replies_bit_identical_ranks_1_to_9() {
    let core = toy_core(21, 60, 10, 2, 3);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(22);
    let client_rows: [&[usize]; 3] = [&[5, 0, 3, 1], &[7, 2], &[1, 1, 4]];
    let requests: Vec<Vec<Mat>> = client_rows
        .iter()
        .map(|rows| rows.iter()
            .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
            .collect())
        .collect();
    let expect: Vec<Vec<(Mat, Vec<f64>)>> = requests
        .iter()
        .map(|c| c.iter().map(|r| single.predict(r)).collect())
        .collect();

    for kind in [BackendKind::RustCpu, BackendKind::ParallelCpu { threads: 3 }] {
        for size in 1..=9usize {
            let (core_ref, reqs) = (&core, &requests);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = backend_for(kind);
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                             &mut comm).unwrap();
                    let fe = ServingFrontend::new(
                        FrontendConfig {
                            max_batch_rows: 6,
                            max_wait: Duration::from_micros(200),
                            queue_rows: 64,
                            dump_every: None,
                        },
                        2, 3);
                    let (served, report) = std::thread::scope(|s| {
                        let clients: Vec<_> = reqs
                            .iter()
                            .map(|reqs_c| {
                                let h = fe.handle();
                                s.spawn(move || -> Vec<(Mat, Vec<f64>)> {
                                    reqs_c.iter()
                                        .map(|r| h.predict(r.clone()).unwrap())
                                        .collect()
                                })
                            })
                            .collect();
                        let closer = {
                            let h = fe.handle();
                            s.spawn(move || {
                                let out: Vec<_> = clients.into_iter()
                                    .map(|c| c.join().unwrap())
                                    .collect();
                                h.close();
                                out
                            })
                        };
                        let report = fe.run(&mut dp, &mut comm, backend.as_mut());
                        (closer.join().unwrap(), report)
                    });
                    dp.finish(&mut comm).unwrap();
                    Some((served, report))
                } else {
                    worker_serve(&mut comm, backend.as_mut()).unwrap();
                    None
                }
            });
            let (served, report) =
                results.into_iter().next().unwrap().expect("leader output");

            for (c, (got_c, want_c)) in served.iter().zip(&expect).enumerate() {
                for (i, (got, want)) in got_c.iter().zip(want_c).enumerate() {
                    assert_reply(got, want,
                                 &format!("{kind:?} size {size} client {c} req {i}"));
                }
            }
            assert_eq!(report.snapshot.requests, 9, "{kind:?} size {size}");
            assert_eq!(report.snapshot.completed, 9, "{kind:?} size {size}");
            assert_eq!(report.snapshot.failed, 0, "{kind:?} size {size}");
            assert_eq!(report.snapshot.rows, 24, "{kind:?} size {size}");
            assert_eq!(report.snapshot.queue_rows, 0, "{kind:?} size {size}");
            assert!(report.snapshot.batches >= 1, "{kind:?} size {size}");
        }
    }
}

/// A mid-stream hot-swap under concurrent load is applied on a batch
/// boundary: every reply bit-equals the old posterior's answer or the
/// new one's — never a row-level mix — and requests issued after `swap`
/// returned see the new posterior. Requests issued and completed before
/// the swap was even enqueued see the old one.
#[test]
fn frontend_swap_applies_on_batch_boundary() {
    let core_a = toy_core(31, 50, 8, 1, 2);
    let core_b = toy_core(32, 50, 8, 1, 2);
    let mut rng = Rng64::new(33);
    let xstar = Mat::from_fn(6, 1, |_, _| rng.normal());
    let want_a = Posterior::from_core(core_a.clone()).predict(&xstar);
    let want_b = Posterior::from_core(core_b.clone()).predict(&xstar);
    assert!(want_a.0.max_abs_diff(&want_b.0) > 0.0,
            "cores A and B predict identically — test is vacuous");

    const PRE: usize = 10; // per-client requests before the swap gate opens
    let swapped = AtomicBool::new(false);
    let pre_done = AtomicUsize::new(0);
    let (ca, cb, xs, fl, pd) = (&core_a, &core_b, &xstar, &swapped, &pre_done);

    let results = Cluster::run(2, move |mut comm| {
        let mut backend = backend_for(BackendKind::RustCpu);
        if comm.rank() == 0 {
            let mut dp =
                DistributedPosterior::leader(ca.clone(), 3, &mut comm).unwrap();
            let fe = ServingFrontend::new(
                FrontendConfig {
                    max_batch_rows: 12,
                    max_wait: Duration::from_micros(100),
                    queue_rows: 256,
                    dump_every: None,
                },
                1, 2);
            type Reply = (Mat, Vec<f64>);
            let served = std::thread::scope(|s| {
                let clients: Vec<_> = (0..2)
                    .map(|_| {
                        let h = fe.handle();
                        s.spawn(move || -> (Vec<Reply>, Vec<Reply>, Vec<Reply>) {
                            // phase 1: completed before the swap can be
                            // enqueued (the swapper waits for both
                            // clients' phase-1 counts) — must be all-A
                            let pre: Vec<Reply> = (0..PRE)
                                .map(|_| h.predict(xs.clone()).unwrap())
                                .collect();
                            pd.fetch_add(1, Ordering::SeqCst);
                            // phase 2: concurrent with the swap — A or B
                            let mut mid = Vec::new();
                            while !fl.load(Ordering::SeqCst) && mid.len() < 200 {
                                mid.push(h.predict(xs.clone()).unwrap());
                            }
                            while !fl.load(Ordering::SeqCst) {
                                std::thread::yield_now();
                            }
                            // phase 3: issued after `swap` returned —
                            // must be all-B
                            let post: Vec<Reply> = (0..3)
                                .map(|_| h.predict(xs.clone()).unwrap())
                                .collect();
                            (pre, mid, post)
                        })
                    })
                    .collect();
                let swapper = {
                    let h = fe.handle();
                    s.spawn(move || {
                        while pd.load(Ordering::SeqCst) < 2 {
                            std::thread::yield_now();
                        }
                        h.swap(cb.clone()).unwrap();
                        fl.store(true, Ordering::SeqCst);
                    })
                };
                let closer = {
                    let h = fe.handle();
                    s.spawn(move || {
                        swapper.join().unwrap();
                        let out: Vec<_> = clients.into_iter()
                            .map(|c| c.join().unwrap())
                            .collect();
                        h.close();
                        out
                    })
                };
                fe.run(&mut dp, &mut comm, backend.as_mut());
                closer.join().unwrap()
            });
            dp.finish(&mut comm).unwrap();
            Some(served)
        } else {
            worker_serve(&mut comm, backend.as_mut()).unwrap();
            None
        }
    });
    let served = results.into_iter().next().unwrap().expect("leader output");

    let is = |r: &(Mat, Vec<f64>), w: &(Mat, Vec<f64>)| {
        r.0.max_abs_diff(&w.0) == 0.0 && r.1 == w.1
    };
    for (c, (pre, mid, post)) in served.iter().enumerate() {
        for (i, r) in pre.iter().enumerate() {
            assert!(is(r, &want_a), "client {c} pre-swap req {i}: not posterior A");
        }
        for (i, r) in mid.iter().enumerate() {
            assert!(is(r, &want_a) || is(r, &want_b),
                    "client {c} concurrent req {i}: mixes posteriors A and B");
        }
        for (i, r) in post.iter().enumerate() {
            assert!(is(r, &want_b), "client {c} post-swap req {i}: not posterior B");
        }
    }
}

/// A backend whose serving compute can be poisoned at runtime; training
/// entry points delegate to the scalar CPU backend untouched.
struct FailingBackend {
    fail: Arc<AtomicBool>,
    inner: RustCpuBackend,
}

impl Backend for FailingBackend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats> {
        self.inner.stats_fwd(chunk, latent, view, include_kl)
    }

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads> {
        self.inner.stats_vjp(chunk, latent, view, cts)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::RustCpu
    }

    fn predict_batch(&mut self, core: &PosteriorCore, xstar: &Mat, row0: usize,
                     rows: usize, mean_out: &mut [f64], var_out: &mut [f64])
                     -> Result<()> {
        if self.fail.load(Ordering::SeqCst) {
            bail!("injected backend failure");
        }
        self.inner.predict_batch(core, xstar, row0, rows, mean_out, var_out)
    }
}

/// A poisoned worker fails only the in-flight request — with a clean
/// error naming the rank — and the front-end session stays usable: once
/// the poison lifts, later requests (and a good swap) serve
/// bit-identically, a standalone `refit` is refused with a clear error,
/// and the worker reports the sticky failure at close. The test
/// completing at all proves nothing deadlocked.
#[test]
fn poisoned_worker_fails_in_flight_only() {
    let core = toy_core(41, 40, 6, 2, 2);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(42);
    // 4 rows at rows_per_chunk=2 on 2 ranks: rank 1 owns rows 2..4, so
    // its poisoned compute fail-flags every batch
    let xstar = Mat::from_fn(4, 2, |_, _| rng.normal());
    let want = single.predict(&xstar);
    let fail = Arc::new(AtomicBool::new(true));
    let (core_ref, xs, fl) = (&core, &xstar, &fail);

    let results = Cluster::run(2, move |mut comm| {
        if comm.rank() == 0 {
            let mut backend = RustCpuBackend;
            let mut dp =
                DistributedPosterior::leader(core_ref.clone(), 2, &mut comm).unwrap();
            let fe = ServingFrontend::new(
                FrontendConfig {
                    max_batch_rows: 8,
                    max_wait: Duration::from_micros(100),
                    queue_rows: 64,
                    dump_every: None,
                },
                2, 2);
            let (out, report) = std::thread::scope(|s| {
                let h = fe.handle();
                let drive = s.spawn(move || {
                    // 1. poisoned worker: the batch fails cleanly
                    let err = h.predict(xs.clone())
                        .expect_err("poisoned worker must fail the request");
                    assert!(format!("{err:#}").contains("rank 1"),
                            "error must name the failing rank: {err:#}");
                    // 2. poison lifted: the session recovered
                    fl.store(false, Ordering::SeqCst);
                    let ok1 = h.predict(xs.clone()).unwrap();
                    // 3. a good swap still works after the failure
                    h.swap(core_ref.clone()).unwrap();
                    let ok2 = h.predict(xs.clone()).unwrap();
                    // 4. standalone front-ends refuse refit clearly
                    let err = h.refit(&[0.0])
                        .expect_err("standalone refit must be refused");
                    assert!(format!("{err:#}").contains("training cluster"),
                            "unhelpful refit error: {err:#}");
                    h.close();
                    (ok1, ok2)
                });
                let report = fe.run(&mut dp, &mut comm, &mut backend);
                (drive.join().unwrap(), report)
            });
            dp.finish(&mut comm).unwrap();
            Some((out, report))
        } else {
            let mut backend = FailingBackend {
                fail: Arc::clone(fl),
                inner: RustCpuBackend,
            };
            let err = worker_serve(&mut comm, &mut backend)
                .expect_err("worker must report the sticky failure at close");
            assert!(format!("{err:#}").contains("injected"),
                    "unhelpful worker error: {err:#}");
            None
        }
    });
    let ((ok1, ok2), report) =
        results.into_iter().next().unwrap().expect("leader output");

    assert_reply(&ok1, &want, "first request after the poison lifted");
    assert_reply(&ok2, &want, "request after the recovery swap");
    assert_eq!(report.snapshot.completed, 2);
    assert_eq!(report.snapshot.failed, 1);
}

/// Backpressure bounds the queue deterministically: with a 4-row bound
/// and the size trigger out of reach, a first request fills the queue,
/// a second blocks in `predict` until the deadline-triggered batch
/// drains, and both still complete bit-identically. The queue high-water
/// mark never exceeds the bound.
#[test]
fn frontend_backpressure_bounds_queue() {
    let core = toy_core(51, 40, 6, 1, 2);
    let single = Posterior::from_core(core.clone());
    let mut rng = Rng64::new(52);
    let xa = Mat::from_fn(4, 1, |_, _| rng.normal());
    let xb = Mat::from_fn(1, 1, |_, _| rng.normal());
    let want_a = single.predict(&xa);
    let want_b = single.predict(&xb);
    let (core_ref, ra, rb) = (&core, &xa, &xb);

    let results = Cluster::run(2, move |mut comm| {
        let mut backend = backend_for(BackendKind::RustCpu);
        if comm.rank() == 0 {
            let mut dp =
                DistributedPosterior::leader(core_ref.clone(), 2, &mut comm).unwrap();
            let fe = ServingFrontend::new(
                FrontendConfig {
                    // size trigger unreachable: only the 100 ms deadline
                    // can close a batch, so client A's rows sit in the
                    // queue long enough for client B to block on them
                    max_batch_rows: 100,
                    max_wait: Duration::from_millis(100),
                    queue_rows: 4,
                    dump_every: None,
                },
                1, 2);
            let (got_a, got_b, report) = std::thread::scope(|s| {
                let ha = fe.handle();
                let a = s.spawn(move || ha.predict(ra.clone()).unwrap());
                let hb = fe.handle();
                let b = s.spawn(move || {
                    // wait until A's 4 rows fill the queue, then enqueue:
                    // 4 + 1 > 4 must block until the deadline batch drains
                    let t0 = Instant::now();
                    while hb.metrics().queue_rows < 4 {
                        assert!(t0.elapsed() < Duration::from_secs(10),
                                "client A's rows never reached the queue");
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    hb.predict(rb.clone()).unwrap()
                });
                let closer = {
                    let h = fe.handle();
                    s.spawn(move || {
                        let (ga, gb) = (a.join().unwrap(), b.join().unwrap());
                        h.close();
                        (ga, gb)
                    })
                };
                let report = fe.run(&mut dp, &mut comm, backend.as_mut());
                let (ga, gb) = closer.join().unwrap();
                (ga, gb, report)
            });
            dp.finish(&mut comm).unwrap();
            Some((got_a, got_b, report))
        } else {
            worker_serve(&mut comm, backend.as_mut()).unwrap();
            None
        }
    });
    let (got_a, got_b, report) =
        results.into_iter().next().unwrap().expect("leader output");

    assert_reply(&got_a, &want_a, "queue-filling request");
    assert_reply(&got_b, &want_b, "backpressured request");
    assert_eq!(report.snapshot.completed, 2);
    assert_eq!(report.snapshot.failed, 0);
    assert_eq!(report.snapshot.batches, 2,
               "the blocked request must land in its own batch");
    assert_eq!(report.snapshot.queue_rows_max, 4,
               "the queue grew past its backpressure bound");
    assert_eq!(report.snapshot.enqueue_blocked, 1);
    assert!(report.snapshot.enqueue_blocked_sec > 0.0);
    assert_eq!(report.snapshot.queue_rows, 0);
}

/// `Engine`-level hand-off: `train_then_serve` replies (ragged chunks +
/// an empty request from the drive closure) are bit-identical to
/// `train_then_predict` rows, and a mid-session `refit` swaps to
/// exactly the posterior implied by the serial chunked stats at the
/// refit parameters (the slot-wire STATS discipline — *not* the
/// captured final-eval statistics the pre-refit posterior came from).
#[test]
fn train_then_serve_matches_train_then_predict() {
    let spec = SyntheticSpec { n: 72, q: 1, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 61);
    let x = ds.x().unwrap();
    let m = 6;
    let chunk = 16;
    let cfg = EngineConfig {
        workers: 3,
        chunk,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs { max_iters: 2, ..Default::default() }),
        pipeline: true,
        verbose: false,
        simd: None,
    };
    let mk = || SparseGpRegression::problem(&x, &ds.y(), m, "test", 61);
    let x0 = mk().initial_params();
    let mut rng = Rng64::new(62);
    let xstar = Mat::from_fn(31, 1, |_, _| rng.normal());

    let (r_ref, m_ref, v_ref) = Engine::new(mk(), cfg.clone())
        .unwrap()
        .train_then_predict(&xstar, 4)
        .unwrap();

    // the front-end run: the same 31 rows as ragged chunks plus an empty
    // request, then a refit back to the initial parameters and a full
    // re-predict under the swapped posterior
    let cuts: [(usize, usize); 4] = [(0, 11), (11, 0), (11, 9), (20, 11)];
    let fcfg = FrontendConfig {
        max_batch_rows: 12,
        max_wait: Duration::from_micros(200),
        queue_rows: 64,
        dump_every: None,
    };
    let (xs, x0r) = (&xstar, &x0);
    let (r_srv, (chunks, refitted), report) = Engine::new(mk(), cfg)
        .unwrap()
        .train_then_serve(4, fcfg, move |h| {
            let chunks: Vec<(Mat, Vec<f64>)> = cuts
                .iter()
                .map(|&(r0, n)| {
                    let sub = Mat::from_fn(n, 1, |i, _| xs[(r0 + i, 0)]);
                    h.predict(sub).unwrap()
                })
                .collect();
            h.refit(x0r).unwrap();
            let refitted = h.predict(xs.clone()).unwrap();
            (chunks, refitted)
        })
        .unwrap();

    // training is deterministic, so both runs fit the same model and the
    // pre-refit replies come from the same (captured-stats) posterior
    assert_eq!(r_ref.f, r_srv.f, "training must be identical across the two runs");
    for (k, (&(r0, n), (gm, gv))) in cuts.iter().zip(&chunks).enumerate() {
        assert_eq!(gm.rows(), n, "request {k}: wrong reply height");
        for i in 0..n {
            for j in 0..2 {
                assert_eq!(gm[(i, j)], m_ref[(r0 + i, j)],
                           "request {k} row {i}: mean differs from train_then_predict");
            }
            assert_eq!(gv[i], v_ref[r0 + i],
                       "request {k} row {i}: var differs from train_then_predict");
        }
    }

    // post-refit: bit-identical to the single-node posterior built from
    // the serial chunked stats at x0 (layout for q=1:
    // [log σ², log ℓ, log β, Z]), per the slot-wire STATS discipline
    let kern0 = RbfArd::from_log_hyp(&x0[0..2]);
    let z0 = Mat::from_vec(m, 1, x0[3..3 + m].to_vec());
    let w = vec![1.0; x.rows()];
    let st0 = sgpr_stats_fwd_chunked(&kern0, &x, &w, &ds.y(), &z0, chunk);
    let single0 = Posterior::new(kern0, z0, x0[2].exp(), &st0).unwrap();
    let want0 = single0.predict(&xstar);
    assert_reply(&refitted, &want0, "post-refit full predict");
    assert!(refitted.0.max_abs_diff(&m_ref) > 0.0,
            "refit to x0 changed nothing — the optimiser never left x0 \
             and the swap is untested");

    assert_eq!(report.snapshot.requests, 5);
    assert_eq!(report.snapshot.completed, 5);
    assert_eq!(report.snapshot.failed, 0);
    assert_eq!(report.snapshot.rows, 62);
}
