//! Sparse-GP predictive equations from the fitted parameters and the
//! reduced statistics (leader-side, pure Rust).
//!
//! With A = K_uu + βΦ and P = ΨᵀY:
//!   mean(x*) = β k*uᵀ A⁻¹ P
//!   var(x*)  = k** − k*uᵀ (K_uu⁻¹ − A⁻¹) k*u + β⁻¹
//! (the standard variational-sparse posterior, e.g. Titsias 2009 eq. 6).

use crate::kern::RbfArd;
use crate::linalg::{Chol, Mat};
use crate::math::stats::Stats;
use anyhow::{Context, Result};

/// Precomputed posterior state for fast repeated prediction.
pub struct Posterior {
    kern: RbfArd,
    z: Mat,
    beta: f64,
    /// A⁻¹ P (M × D).
    ainv_p: Mat,
    /// K_uu⁻¹ − A⁻¹ (M × M).
    woodbury: Mat,
}

impl Posterior {
    /// Build from fitted parameters and reduced statistics.
    pub fn new(kern: RbfArd, z: Mat, beta: f64, stats: &Stats) -> Result<Posterior> {
        let kuu = kern.kuu(&z);
        let mut a = stats.psi2.scale(beta);
        a.axpy(1.0, &kuu);
        let (lk, _) = Chol::new_with_jitter(&kuu, 6).context("K_uu")?;
        let (la, _) = Chol::new_with_jitter(&a, 6).context("A")?;
        let ainv_p = la.solve(&stats.p);
        let mut woodbury = lk.inverse();
        woodbury.axpy(-1.0, &la.inverse());
        Ok(Posterior { kern, z, beta, ainv_p, woodbury })
    }

    /// Predict mean (Nt × D) and per-point predictive variance (Nt),
    /// including the noise term.
    pub fn predict(&self, xstar: &Mat) -> (Mat, Vec<f64>) {
        let ksu = self.kern.k(xstar, &self.z); // Nt × M
        let mut mean = ksu.matmul(&self.ainv_p);
        mean.scale_mut(self.beta);

        let wk = ksu.matmul(&self.woodbury); // Nt × M
        let var: Vec<f64> = (0..xstar.rows())
            .map(|i| {
                let mut reduction = 0.0;
                for mcol in 0..self.z.rows() {
                    reduction += wk[(i, mcol)] * ksu[(i, mcol)];
                }
                (self.kern.variance - reduction + 1.0 / self.beta).max(1e-12)
            })
            .collect();
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::sgpr_stats_fwd;
    use crate::testutil::prop::Rng64;

    /// With Z = X, M = N and low noise the sparse posterior mean must
    /// interpolate the training targets.
    #[test]
    fn interpolates_with_full_inducing_set() {
        let mut rng = Rng64::new(61);
        let n = 30;
        let x = Mat::from_fn(n, 1, |i, _| i as f64 * 0.3 - 4.5 + 0.01 * rng.normal());
        let y = Mat::from_fn(n, 1, |i, _| (x[(i, 0)]).sin());
        let kern = RbfArd::iso(1.0, 1.0, 1);
        let beta = 1e4;
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let post = Posterior::new(kern, x.clone(), beta, &st).unwrap();
        let (mean, var) = post.predict(&x);
        for i in 0..n {
            assert!((mean[(i, 0)] - y[(i, 0)]).abs() < 1e-2,
                    "pred {} vs {}", mean[(i, 0)], y[(i, 0)]);
            assert!(var[i] > 0.0);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let n = 20;
        let x = Mat::from_fn(n, 1, |i, _| i as f64 * 0.1); // data in [0, 2]
        let y = Mat::from_fn(n, 1, |i, _| (x[(i, 0)]).cos());
        let kern = RbfArd::iso(1.0, 0.5, 1);
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let post = Posterior::new(kern, x, 100.0, &st).unwrap();
        let probe = Mat::from_vec(2, 1, vec![1.0, 10.0]); // in-range vs far
        let (_, var) = post.predict(&probe);
        assert!(var[1] > 5.0 * var[0], "far-field variance should dominate: {var:?}");
    }
}
