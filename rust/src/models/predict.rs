//! Sparse-GP predictive equations from the fitted parameters and the
//! reduced statistics (single-node entry point).
//!
//! [`Posterior`] is a thin wrapper over
//! [`PosteriorCore`](crate::math::predict::PosteriorCore), which holds
//! the precomputed state (`A⁻¹P`, the Woodbury matrix, kernel, Z) and
//! the one per-row implementation of the predictive equations. The
//! sharded serving path
//! ([`DistributedPosterior`](crate::coordinator::engine::serve::DistributedPosterior))
//! broadcasts the same core, so its predictions are bit-identical to
//! [`Posterior::predict`] by construction — including after a
//! mid-session posterior **hot-swap**, which replaces the core on every
//! rank with one rebuilt by the engine's distributed stats-only pass.
//!
//! On statistics provenance: the engine's serving path builds its cores
//! from the **chunk-ordered** statistics
//! ([`sgpr_stats_fwd_chunked`](crate::math::stats::sgpr_stats_fwd_chunked),
//! the summation discipline the distributed STATS pass pins), while the
//! single-node [`SparseGpRegression::fit`](crate::models::SparseGpRegression)
//! convenience path uses the monolithic full-data pass — the two agree
//! to rounding error, and each is bit-reproducible against itself.

use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::predict::PosteriorCore;
use crate::math::stats::Stats;
use anyhow::Result;

/// Precomputed posterior state for fast repeated single-node prediction.
pub struct Posterior {
    core: PosteriorCore,
}

impl Posterior {
    /// Build from fitted parameters and reduced statistics.
    pub fn new(kern: RbfArd, z: Mat, beta: f64, stats: &Stats) -> Result<Posterior> {
        Ok(Posterior { core: PosteriorCore::new(kern, z, beta, stats)? })
    }

    /// Wrap an already-built core (e.g. one received over a collective).
    pub fn from_core(core: PosteriorCore) -> Posterior {
        Posterior { core }
    }

    /// The precomputed state — what sharded serving broadcasts.
    pub fn core(&self) -> &PosteriorCore {
        &self.core
    }

    /// Unwrap into the precomputed state.
    pub fn into_core(self) -> PosteriorCore {
        self.core
    }

    /// Predict mean (Nt × D) and per-point predictive variance (Nt),
    /// including the β⁻¹ noise term (floored at
    /// [`MIN_PREDICTIVE_VARIANCE`](crate::math::predict::MIN_PREDICTIVE_VARIANCE)).
    pub fn predict(&self, xstar: &Mat) -> (Mat, Vec<f64>) {
        let nt = xstar.rows();
        let mut mean = Mat::zeros(nt, self.core.d());
        let mut var = vec![0.0; nt];
        self.core.predict_rows_into(xstar, 0, nt, mean.as_mut_slice(), &mut var);
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::sgpr_stats_fwd;
    use crate::testutil::prop::Rng64;

    /// With Z = X, M = N and low noise the sparse posterior mean must
    /// interpolate the training targets.
    #[test]
    fn interpolates_with_full_inducing_set() {
        let mut rng = Rng64::new(61);
        let n = 30;
        let x = Mat::from_fn(n, 1, |i, _| i as f64 * 0.3 - 4.5 + 0.01 * rng.normal());
        let y = Mat::from_fn(n, 1, |i, _| (x[(i, 0)]).sin());
        let kern = RbfArd::iso(1.0, 1.0, 1);
        let beta = 1e4;
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let post = Posterior::new(kern, x.clone(), beta, &st).unwrap();
        let (mean, var) = post.predict(&x);
        for i in 0..n {
            assert!((mean[(i, 0)] - y[(i, 0)]).abs() < 1e-2,
                    "pred {} vs {}", mean[(i, 0)], y[(i, 0)]);
            assert!(var[i] > 0.0);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let n = 20;
        let x = Mat::from_fn(n, 1, |i, _| i as f64 * 0.1); // data in [0, 2]
        let y = Mat::from_fn(n, 1, |i, _| (x[(i, 0)]).cos());
        let kern = RbfArd::iso(1.0, 0.5, 1);
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let post = Posterior::new(kern, x, 100.0, &st).unwrap();
        let probe = Mat::from_vec(2, 1, vec![1.0, 10.0]); // in-range vs far
        let (_, var) = post.predict(&probe);
        assert!(var[1] > 5.0 * var[0], "far-field variance should dominate: {var:?}");
    }

    /// Far from all data the predictive variance must approach
    /// k** + β⁻¹ with k** routed through the kernel's own diagonal.
    #[test]
    fn far_field_variance_is_kdiag_plus_noise() {
        let n = 15;
        let x = Mat::from_fn(n, 1, |i, _| i as f64 * 0.1);
        let y = Mat::from_fn(n, 1, |i, _| (x[(i, 0)]).cos());
        let kern = RbfArd::iso(2.5, 0.4, 1);
        let beta = 50.0;
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &x);
        let expect = kern.kdiag_at(&[100.0]) + 1.0 / beta;
        let post = Posterior::new(kern, x, beta, &st).unwrap();
        let probe = Mat::from_vec(1, 1, vec![100.0]);
        let (_, var) = post.predict(&probe);
        assert!((var[0] - expect).abs() < 1e-6 * expect,
                "far-field var {} vs k** + 1/beta = {}", var[0], expect);
    }
}
