//! Manifold Relevance Determination (Damianou et al. 2012): several
//! observation views sharing one variational latent space — the
//! multi-view member of the family the paper's §1 lists (BGP-LVM, MRD,
//! deep GPs) as transparently accelerated.

use crate::coordinator::{Engine, EngineConfig, LatentSpec, Problem, TrainResult, ViewSpec};
use crate::data::rng::Rng64;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::models::pca::pca_latent_init;
use anyhow::Result;

/// A fitted MRD model.
pub struct Mrd {
    /// Training outcome (bound, trace, fitted parameters, timing).
    pub result: TrainResult,
    /// Shared latent dimensionality Q.
    pub q: usize,
}

impl Mrd {
    /// Fit a shared Q-dimensional latent space to several views. Latents
    /// initialise from PCA on the concatenated views; each view gets its
    /// own ARD kernel, noise and inducing set (all optimised).
    pub fn fit(views: &[Mat], q: usize, m: usize, aot_configs: &[&str],
               cfg: EngineConfig, seed: u64) -> Result<Mrd> {
        let problem = Self::problem(views, q, m, aot_configs, seed);
        let engine = Engine::new(problem, cfg)?;
        let result = engine.train()?;
        Ok(Mrd { result, q })
    }

    /// The Problem (exposed so benches can drive the engine on exactly
    /// the model [`Mrd::fit`] trains).
    pub fn problem(views: &[Mat], q: usize, m: usize, aot_configs: &[&str],
                   seed: u64) -> Problem {
        assert!(!views.is_empty());
        assert_eq!(views.len(), aot_configs.len());
        let n = views[0].rows();
        let mut rng = Rng64::new(seed);

        // PCA on concatenated views
        let d_total: usize = views.iter().map(Mat::cols).sum();
        let mut concat = Mat::zeros(n, d_total);
        let mut off = 0;
        for v in views {
            for i in 0..n {
                concat.row_mut(i)[off..off + v.cols()].copy_from_slice(v.row(i));
            }
            off += v.cols();
        }
        let mu0 = pca_latent_init(&concat, q, seed);
        let s0 = Mat::from_vec(n, q, vec![0.5; n * q]);

        let view_specs = views
            .iter()
            .zip(aot_configs)
            .map(|(y, aot)| {
                let mut idx: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut idx);
                let z0 = Mat::from_fn(m, q, |i, j| mu0[(idx[i], j)] + 0.01 * rng.normal());
                let mut y_var = 0.0;
                for j in 0..y.cols() {
                    let mean: f64 = (0..n).map(|i| y[(i, j)]).sum::<f64>() / n as f64;
                    y_var += (0..n).map(|i| (y[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
                }
                y_var = (y_var / y.cols() as f64).max(1e-6);
                ViewSpec {
                    y: y.clone().into(),
                    z0,
                    kern0: RbfArd::iso(y_var, 1.0, q),
                    beta0: 1.0 / (0.01 * y_var),
                    aot_config: aot.to_string(),
                }
            })
            .collect();

        Problem {
            latent: LatentSpec::Variational { mu0, s0 },
            views: view_specs,
            q,
        }
    }

    /// Per-view ARD relevance profiles: 1/ℓ_q² normalised per view.
    /// A latent dimension is "private" to a view when its relevance is
    /// high in that view and ~0 in the others.
    pub fn relevance(&self) -> Vec<Vec<f64>> {
        self.result
            .fitted
            .kerns
            .iter()
            .map(|k| {
                let alpha = k.alpha();
                let max = alpha.iter().cloned().fold(0.0f64, f64::max).max(1e-300);
                alpha.iter().map(|a| a / max).collect()
            })
            .collect()
    }
}
