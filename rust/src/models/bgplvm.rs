//! Bayesian GP-LVM (Titsias & Lawrence 2010) on the distributed engine —
//! the paper's demonstration model (§4: recover a 1-D latent space from
//! 3-D observations).

use crate::coordinator::{Engine, EngineConfig, LatentSpec, Problem, TrainResult, ViewSpec};
use crate::data::rng::Rng64;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::models::pca::pca_latent_init;
use anyhow::Result;

/// A fitted Bayesian GP-LVM.
pub struct BayesianGplvm {
    /// Training outcome (bound, trace, fitted parameters, timing).
    pub result: TrainResult,
    /// Latent dimensionality Q.
    pub q: usize,
}

impl BayesianGplvm {
    /// Fit a Q-dimensional latent space to `y` with `m` inducing points.
    /// Latent means initialise from PCA, variances at 0.5, inducing
    /// inputs to a random subset of the initial latents (GPy defaults).
    pub fn fit(y: &Mat, q: usize, m: usize, aot_config: &str, cfg: EngineConfig,
               seed: u64) -> Result<BayesianGplvm> {
        let problem = Self::problem(y, q, m, aot_config, seed);
        let engine = Engine::new(problem, cfg)?;
        let result = engine.train()?;
        Ok(BayesianGplvm { result, q })
    }

    /// The Problem (exposed so benches can drive `Engine::time_iterations`
    /// on exactly the model the examples train).
    pub fn problem(y: &Mat, q: usize, m: usize, aot_config: &str, seed: u64) -> Problem {
        let n = y.rows();
        let mut rng = Rng64::new(seed);
        let mu0 = pca_latent_init(y, q, seed);
        let s0 = Mat::from_vec(n, q, vec![0.5; n * q]);

        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let z0 = Mat::from_fn(m.min(n), q, |i, j| mu0[(idx[i], j)] + 0.01 * rng.normal());

        let mut y_var = 0.0;
        for j in 0..y.cols() {
            let mean: f64 = (0..n).map(|i| y[(i, j)]).sum::<f64>() / n as f64;
            y_var += (0..n).map(|i| (y[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
        }
        y_var = (y_var / y.cols() as f64).max(1e-6);

        Problem {
            latent: LatentSpec::Variational { mu0, s0 },
            views: vec![ViewSpec {
                y: y.clone().into(),
                z0,
                kern0: RbfArd::iso(y_var, 1.0, q),
                beta0: 1.0 / (0.01 * y_var),
                aot_config: aot_config.to_string(),
            }],
            q,
        }
    }

    /// Learned latent means (N × Q).
    pub fn latents(&self) -> &Mat {
        &self.result.fitted.mu
    }

    /// |Pearson correlation| between a learned 1-D latent and the ground
    /// truth — the evaluation the paper's synthetic task implies. For
    /// Q > 1, the best single learned dimension is reported.
    pub fn latent_alignment(&self, truth: &Mat) -> f64 {
        let mu = self.latents();
        let n = mu.rows();
        assert_eq!(truth.rows(), n);
        let mut best: f64 = 0.0;
        for qq in 0..mu.cols() {
            for tq in 0..truth.cols() {
                let mx: f64 = (0..n).map(|i| mu[(i, qq)]).sum::<f64>() / n as f64;
                let mt: f64 = (0..n).map(|i| truth[(i, tq)]).sum::<f64>() / n as f64;
                let mut num = 0.0;
                let mut da = 0.0;
                let mut db = 0.0;
                for i in 0..n {
                    let a = mu[(i, qq)] - mx;
                    let b = truth[(i, tq)] - mt;
                    num += a * b;
                    da += a * a;
                    db += b * b;
                }
                let corr = (num / (da.sqrt() * db.sqrt()).max(1e-300)).abs();
                best = best.max(corr);
            }
        }
        best
    }
}
