//! Sparse GP regression (Titsias 2009) on the distributed engine —
//! the supervised member of the model family.

use crate::coordinator::{Engine, EngineConfig, LatentSpec, Problem, TrainResult, ViewData,
                         ViewSpec};
use crate::data::rng::Rng64;
use crate::data::store::ChunkSource;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::stats::sgpr_stats_fwd;
use crate::models::predict::Posterior;
use anyhow::{bail, Result};
use std::sync::Arc;

/// A fitted sparse-GP regressor.
pub struct SparseGpRegression {
    /// Training outcome (bound, trace, fitted parameters, timing).
    pub result: TrainResult,
    posterior: Posterior,
}

impl SparseGpRegression {
    /// The Problem (exposed so the CLI and benches can drive
    /// `Engine::train_then_predict` / `Engine::time_iterations` on
    /// exactly the model this type trains). Inducing inputs initialise
    /// to a random subset of X; σ² to the output variance; β to
    /// 1/(0.01·var(y)); all are then optimised.
    pub fn problem(x: &Mat, y: &Mat, m: usize, aot_config: &str, seed: u64) -> Problem {
        let (n, q) = (x.rows(), x.cols());
        assert!(m <= n, "need M <= N");
        let mut rng = Rng64::new(seed);

        // y variance for scale-aware initialisation
        let mut y_var = 0.0;
        for j in 0..y.cols() {
            let mean: f64 = (0..n).map(|i| y[(i, j)]).sum::<f64>() / n as f64;
            y_var += (0..n).map(|i| (y[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
        }
        y_var = (y_var / y.cols() as f64).max(1e-6);

        // random inducing subset
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let z0 = Mat::from_fn(m, q, |i, j| x[(idx[i], j)]);

        let kern0 = RbfArd::iso(y_var, 1.0, q);
        let beta0 = 1.0 / (0.01 * y_var);

        Problem {
            latent: LatentSpec::Observed(x.clone()),
            views: vec![ViewSpec {
                y: y.clone().into(),
                z0,
                kern0,
                beta0,
                aot_config: aot_config.to_string(),
            }],
            q,
        }
    }

    /// The same Problem built **from a chunk store** without ever
    /// materializing X or Y: the y-variance initialisation streams the
    /// store twice with per-column row-order accumulators (the exact
    /// operand order of the resident loops in
    /// [`SparseGpRegression::problem`]), the RNG consumption is
    /// identical, and the inducing rows are gathered with one chunk read
    /// per distinct chunk — so for a store holding the same (x, y) the
    /// returned problem is **bit-identical** in every initial parameter,
    /// and training it streams each rank's chunks in O(chunk) memory.
    pub fn problem_from_store(source: &Arc<dyn ChunkSource>, m: usize, aot_config: &str,
                              seed: u64) -> Result<Problem> {
        let man = source.manifest();
        let (n, q, d, c) = (man.n, man.q, man.d, man.chunk_rows);
        let num_chunks = man.num_chunks();
        if q == 0 {
            bail!("store has no x block (q = 0): SGPR needs observed inputs");
        }
        if m > n {
            bail!("need M <= N (M = {m}, N = {n})");
        }
        let mut rng = Rng64::new(seed);
        let mut reader = source.open_reader()?;
        let mut xbuf = vec![0.0; c * q];
        let mut ybuf = vec![0.0; c * d];

        // y variance: mean pass then squared-deviation pass, per-column
        // accumulators fed in row order — bit-identical to the resident
        // column loops
        let mut sums = vec![0.0; d];
        for k in 0..num_chunks {
            reader.read_chunk(k, &mut xbuf, &mut ybuf)?;
            for i in 0..man.chunks[k].rows {
                for (j, s) in sums.iter_mut().enumerate() {
                    *s += ybuf[i * d + j];
                }
            }
        }
        let means: Vec<f64> = sums.iter().map(|s| s / n as f64).collect();
        let mut sq = vec![0.0; d];
        for k in 0..num_chunks {
            reader.read_chunk(k, &mut xbuf, &mut ybuf)?;
            for i in 0..man.chunks[k].rows {
                for (j, s) in sq.iter_mut().enumerate() {
                    *s += (ybuf[i * d + j] - means[j]).powi(2);
                }
            }
        }
        let mut y_var = 0.0;
        for s in &sq {
            y_var += s / n as f64;
        }
        y_var = (y_var / d as f64).max(1e-6);

        // random inducing subset — same RNG op sequence as `problem`
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut z0 = Mat::zeros(m, q);
        let mut want: Vec<(usize, usize)> =
            idx[..m].iter().enumerate().map(|(i, &r)| (r, i)).collect();
        want.sort_unstable();
        let mut loaded = usize::MAX;
        for (r, i) in want {
            let k = r / c;
            if k != loaded {
                reader.read_chunk(k, &mut xbuf, &mut ybuf)?;
                loaded = k;
            }
            let off = (r - k * c) * q;
            z0.row_mut(i).copy_from_slice(&xbuf[off..off + q]);
        }

        Ok(Problem {
            latent: LatentSpec::ObservedStore,
            views: vec![ViewSpec {
                y: ViewData::Store(Arc::clone(source)),
                z0,
                kern0: RbfArd::iso(y_var, 1.0, q),
                beta0: 1.0 / (0.01 * y_var),
                aot_config: aot_config.to_string(),
            }],
            q,
        })
    }

    /// Fit to `(x, y)` with `m` inducing points (see
    /// [`SparseGpRegression::problem`] for the initialisation).
    ///
    /// The posterior kept here is built single-node from the monolithic
    /// full-data statistics. The engine's serving entry points
    /// (`Engine::train_then_predict`, hot-swap) instead rebuild theirs
    /// with the distributed stats-only pass, whose chunk-ordered
    /// summation agrees with this one to rounding error.
    pub fn fit(x: &Mat, y: &Mat, m: usize, aot_config: &str, cfg: EngineConfig,
               seed: u64) -> Result<SparseGpRegression> {
        let n = x.rows();
        let problem = Self::problem(x, y, m, aot_config, seed);
        let engine = Engine::new(problem, cfg)?;
        let result = engine.train()?;

        // build the posterior at the fitted parameters
        let fitted = &result.fitted;
        let w = vec![1.0; n];
        let stats = sgpr_stats_fwd(&fitted.kerns[0], x, &w, y, &fitted.zs[0]);
        let posterior = Posterior::new(fitted.kerns[0].clone(), fitted.zs[0].clone(),
                                       fitted.betas[0], &stats)?;
        Ok(SparseGpRegression { result, posterior })
    }

    /// Predictive mean and variance at test inputs.
    pub fn predict(&self, xstar: &Mat) -> (Mat, Vec<f64>) {
        self.posterior.predict(xstar)
    }

    /// The precomputed posterior (its
    /// [`core`](crate::models::Posterior::core) is what sharded serving
    /// broadcasts).
    pub fn posterior(&self) -> &Posterior {
        &self.posterior
    }

    /// Root-mean-square error against held-out targets.
    pub fn rmse(&self, xstar: &Mat, ystar: &Mat) -> f64 {
        let (mean, _) = self.predict(xstar);
        let mut acc = 0.0;
        for i in 0..ystar.rows() {
            for j in 0..ystar.cols() {
                let e = mean[(i, j)] - ystar[(i, j)];
                acc += e * e;
            }
        }
        (acc / (ystar.rows() * ystar.cols()) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::ResidentStore;
    use crate::data::Rng64;

    #[test]
    fn store_problem_matches_resident_problem_bit_for_bit() {
        let (n, q, d, m) = (37, 2, 3, 9);
        let mut rng = Rng64::new(21);
        let x = Mat::from_fn(n, q, |_, _| rng.normal());
        let y = Mat::from_fn(n, d, |_, _| rng.normal() * 3.0 + 1.5);
        let a = SparseGpRegression::problem(&x, &y, m, "test", 7);
        let store: Arc<dyn ChunkSource> = Arc::new(
            ResidentStore::from_mats(Some(x), y, 8).unwrap());
        let b = SparseGpRegression::problem_from_store(&store, m, "test", 7).unwrap();
        assert!(a.views[0].z0.max_abs_diff(&b.views[0].z0) == 0.0, "z0");
        assert!(a.views[0].beta0 == b.views[0].beta0, "beta0");
        assert!(a.views[0].kern0.variance == b.views[0].kern0.variance, "kern0");
        assert!(matches!(b.latent, LatentSpec::ObservedStore));
        b.initial_params(); // layout must accept the store problem
    }
}
