//! Sparse GP regression (Titsias 2009) on the distributed engine —
//! the supervised member of the model family.

use crate::coordinator::{Engine, EngineConfig, LatentSpec, Problem, TrainResult, ViewSpec};
use crate::data::rng::Rng64;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::stats::sgpr_stats_fwd;
use crate::models::predict::Posterior;
use anyhow::Result;

/// A fitted sparse-GP regressor.
pub struct SparseGpRegression {
    /// Training outcome (bound, trace, fitted parameters, timing).
    pub result: TrainResult,
    posterior: Posterior,
}

impl SparseGpRegression {
    /// The Problem (exposed so the CLI and benches can drive
    /// `Engine::train_then_predict` / `Engine::time_iterations` on
    /// exactly the model this type trains). Inducing inputs initialise
    /// to a random subset of X; σ² to the output variance; β to
    /// 1/(0.01·var(y)); all are then optimised.
    pub fn problem(x: &Mat, y: &Mat, m: usize, aot_config: &str, seed: u64) -> Problem {
        let (n, q) = (x.rows(), x.cols());
        assert!(m <= n, "need M <= N");
        let mut rng = Rng64::new(seed);

        // y variance for scale-aware initialisation
        let mut y_var = 0.0;
        for j in 0..y.cols() {
            let mean: f64 = (0..n).map(|i| y[(i, j)]).sum::<f64>() / n as f64;
            y_var += (0..n).map(|i| (y[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
        }
        y_var = (y_var / y.cols() as f64).max(1e-6);

        // random inducing subset
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let z0 = Mat::from_fn(m, q, |i, j| x[(idx[i], j)]);

        let kern0 = RbfArd::iso(y_var, 1.0, q);
        let beta0 = 1.0 / (0.01 * y_var);

        Problem {
            latent: LatentSpec::Observed(x.clone()),
            views: vec![ViewSpec {
                y: y.clone(),
                z0,
                kern0,
                beta0,
                aot_config: aot_config.to_string(),
            }],
            q,
        }
    }

    /// Fit to `(x, y)` with `m` inducing points (see
    /// [`SparseGpRegression::problem`] for the initialisation).
    ///
    /// The posterior kept here is built single-node from the monolithic
    /// full-data statistics. The engine's serving entry points
    /// (`Engine::train_then_predict`, hot-swap) instead rebuild theirs
    /// with the distributed stats-only pass, whose chunk-ordered
    /// summation agrees with this one to rounding error.
    pub fn fit(x: &Mat, y: &Mat, m: usize, aot_config: &str, cfg: EngineConfig,
               seed: u64) -> Result<SparseGpRegression> {
        let n = x.rows();
        let problem = Self::problem(x, y, m, aot_config, seed);
        let engine = Engine::new(problem, cfg)?;
        let result = engine.train()?;

        // build the posterior at the fitted parameters
        let fitted = &result.fitted;
        let w = vec![1.0; n];
        let stats = sgpr_stats_fwd(&fitted.kerns[0], x, &w, y, &fitted.zs[0]);
        let posterior = Posterior::new(fitted.kerns[0].clone(), fitted.zs[0].clone(),
                                       fitted.betas[0], &stats)?;
        Ok(SparseGpRegression { result, posterior })
    }

    /// Predictive mean and variance at test inputs.
    pub fn predict(&self, xstar: &Mat) -> (Mat, Vec<f64>) {
        self.posterior.predict(xstar)
    }

    /// The precomputed posterior (its
    /// [`core`](crate::models::Posterior::core) is what sharded serving
    /// broadcasts).
    pub fn posterior(&self) -> &Posterior {
        &self.posterior
    }

    /// Root-mean-square error against held-out targets.
    pub fn rmse(&self, xstar: &Mat, ystar: &Mat) -> f64 {
        let (mean, _) = self.predict(xstar);
        let mut acc = 0.0;
        for i in 0..ystar.rows() {
            for j in 0..ystar.cols() {
                let e = mean[(i, j)] - ystar[(i, j)];
                acc += e * e;
            }
        }
        (acc / (ystar.rows() * ystar.cols()) as f64).sqrt()
    }
}
