//! PCA by orthogonal power iteration — the standard initialiser for the
//! Bayesian GP-LVM latent means (GPy's `initialize_latent('PCA', ...)`).

use crate::data::rng::Rng64;
use crate::linalg::Mat;

/// Project the (centred) rows of `y` (N × D) onto their top `q` principal
/// directions; returns the N × Q score matrix, scaled to unit column
/// variance (the conventional GP-LVM init).
pub fn pca_latent_init(y: &Mat, q: usize, seed: u64) -> Mat {
    let (n, d) = (y.rows(), y.cols());
    assert!(q <= d.min(n), "q={q} must be <= min(N, D)");

    // centre
    let mut mean = vec![0.0; d];
    for i in 0..n {
        for j in 0..d {
            mean[j] += y[(i, j)];
        }
    }
    for v in &mut mean {
        *v /= n as f64;
    }
    let yc = Mat::from_fn(n, d, |i, j| y[(i, j)] - mean[j]);

    // D × D covariance (D is small in our problems)
    let mut cov = yc.t_matmul(&yc);
    cov.scale_mut(1.0 / n as f64);

    // orthogonal power iteration for the top-q eigenvectors
    let mut rng = Rng64::new(seed ^ 0x9e37);
    let mut v = Mat::from_fn(d, q, |_, _| rng.normal());
    for _ in 0..300 {
        let mut w = cov.matmul(&v);
        // Gram–Schmidt
        for j in 0..q {
            for k in 0..j {
                let dot: f64 = (0..d).map(|i| w[(i, j)] * w[(i, k)]).sum();
                for i in 0..d {
                    let t = w[(i, k)];
                    w[(i, j)] -= dot * t;
                }
            }
            let norm: f64 = (0..d).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt();
            for i in 0..d {
                w[(i, j)] /= norm.max(1e-300);
            }
        }
        v = w;
    }

    // scores, normalised to unit variance per column
    let mut scores = yc.matmul(&v);
    for j in 0..q {
        let var: f64 = (0..n).map(|i| scores[(i, j)] * scores[(i, j)]).sum::<f64>()
            / n as f64;
        let sd = var.sqrt().max(1e-12);
        for i in 0..n {
            scores[(i, j)] /= sd;
        }
    }
    scores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_dominant_direction() {
        // Data living on a 1-D manifold in 3-D (plus small noise): the
        // first PC score must correlate ~1 with the latent coordinate.
        let mut rng = Rng64::new(5);
        let n = 200;
        let t: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = Mat::from_fn(n, 3, |i, j| {
            let dir = [2.0, -1.0, 0.5][j];
            t[i] * dir + 0.01 * rng.normal()
        });
        let x = pca_latent_init(&y, 1, 0);
        let mut num = 0.0;
        let mut den_a = 0.0;
        let mut den_b = 0.0;
        for i in 0..n {
            num += x[(i, 0)] * t[i];
            den_a += x[(i, 0)] * x[(i, 0)];
            den_b += t[i] * t[i];
        }
        let corr = (num / (den_a.sqrt() * den_b.sqrt())).abs();
        assert!(corr > 0.99, "corr {corr}");
    }

    #[test]
    fn unit_variance_columns() {
        let mut rng = Rng64::new(6);
        let y = Mat::from_fn(100, 4, |_, _| rng.normal());
        let x = pca_latent_init(&y, 2, 1);
        for j in 0..2 {
            let var: f64 = (0..100).map(|i| x[(i, j)] * x[(i, j)]).sum::<f64>() / 100.0;
            assert!((var - 1.0).abs() < 1e-9, "var {var}");
        }
    }
}
