//! User-facing models on top of the distributed engine:
//! [`SparseGpRegression`] (supervised), [`BayesianGplvm`] (unsupervised,
//! the paper's §4 demonstration), [`Mrd`] (multi-view), plus the PCA
//! initialiser and the sparse predictive equations.

pub mod bgplvm;
pub mod mrd;
pub mod pca;
pub mod predict;
pub mod sgpr;

pub use bgplvm::BayesianGplvm;
pub use mrd::Mrd;
pub use pca::pca_latent_init;
pub use predict::Posterior;
pub use sgpr::SparseGpRegression;
