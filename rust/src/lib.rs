//! # gpparallel
//!
//! Distributed + accelerated sparse Gaussian process models: a
//! reproduction of *"Gaussian Process Models with Parallelization and GPU
//! acceleration"* (Dai, Damianou, Hensman & Lawrence, 2014) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! - **Layer 1** (`python/compile/kernels/`): Pallas kernels for the psi
//!   statistics — the paper's GPU bottleneck.
//! - **Layer 2** (`python/compile/model.py`): the variational objective in
//!   JAX, AOT-lowered to HLO-text artifacts.
//! - **Layer 3** (this crate): the distributed execution stack.
//!
//! ## Layer map (this crate)
//!
//! | module | role |
//! |---|---|
//! | [`collectives`] | simulated-MPI transport: point-to-point + `bcast`/`reduce_sum`/`gather`, binomial-tree collectives by default (O(log P) critical path), linear reference retained |
//! | [`coordinator::partition`] | datapoints → fixed-shape chunks → contiguous per-rank runs |
//! | [`coordinator::backend`] | pluggable chunk compute behind a `BackendKind` factory: `rust-cpu` (scalar), `parallel-cpu` (intra-rank chunk fan-out over scoped threads, bit-identical), `xla` (PJRT, feature-gated) |
//! | [`coordinator::engine`] | the execution layer: `problem` (model statement + parameter layout), `cycle` (the eight-step SPMD evaluation cycle as a reusable `DistributedEvaluator`), `train` (optimiser loop + stopping), `serve` (sharded posterior serving: broadcast-once state, per-batch row partitioning, rank-order gather), `frontend` (concurrent-client micro-batching scheduler over the streamed serving pipeline, with latency/throughput metrics), re-exported behind a thin facade |
//! | [`math`] | worker statistics + the leader's indistributable M×M core |
//! | [`kern`] | RBF-ARD kernel, psi statistics and analytic VJPs |
//! | [`linalg`] | dense row-major matrices: Cholesky toolkit, cache-blocked `matmul`, symmetric rank-k (`syrk`) updates — inner loops run on the runtime-dispatched SIMD tier in [`linalg::simd`] (AVX2+FMA / portable chunked scalar / bit-identical scalar escape hatch, pinned via `GPPAR_SIMD`, `--simd`, or `EngineConfig::simd`) |
//! | [`optim`] | L-BFGS / SCG / Adam — the central optimiser at rank 0 |
//! | [`models`] | user-facing SGPR / Bayesian GP-LVM / MRD on top of the engine |
//! | [`runtime`] | AOT artifact loading + PJRT execution (behind the off-by-default `xla` feature; pure-Rust stub otherwise) |
//! | [`baselines`], [`data`], [`config`], [`metrics`], [`cli`], [`testutil`] | dense-GP baseline, datasets/RNG, JSON + run config, phase timing, CLI parsing, property/FD test harnesses |
//!
//! Entry points: [`models::SparseGpRegression`], [`models::BayesianGplvm`],
//! [`models::Mrd`], and the lower-level [`coordinator::Engine`].
//!
//! The default build is pure Rust with no external dependencies (the
//! `anyhow` shim is vendored in-tree). The `xla` feature swaps the
//! runtime stub for the real PJRT path, but additionally requires adding
//! the external `xla` crate as a dependency — see the feature notes in
//! `rust/Cargo.toml`.
//!
//! See docs/ARCHITECTURE.md for the end-to-end walkthrough of the
//! execution layer (layer map, the 8-step SPMD cycle, the pipelined
//! schedule and its abort protocol, and the serving fan-out) and
//! docs/BENCHMARKS.md for the bench/trend workflow.

// The public API is documentation-complete and gated in CI
// (`cargo doc --no-deps` with `RUSTDOCFLAGS="-D warnings"`).
#![warn(missing_docs)]
// Clippy runs in CI with `-D warnings` (blocking); this is the curated
// crate-wide allow-list. Every entry is a deliberate house-style call —
// add new ones here with a reason, never inline without one.
//
// Explicit index loops mirror the paper's subscripted formulas (and the
// Python reference implementation) more faithfully than iterator chains;
// rewriting them obscures the maths the code is transcribing.
#![allow(clippy::needless_range_loop)]
// Kernel/statistics entry points take the full parameter set the paper's
// equations take; bundling them into structs at the innermost layer would
// add a copy or a borrow-splitting fight for zero clarity gain.
#![allow(clippy::too_many_arguments)]
// `n`, `m`, `q`, `k`, `a`, `b` are the paper's own symbols; renaming them
// breaks the side-by-side read against the equations.
#![allow(clippy::many_single_char_names)]
// The engine's scratch/wire plumbing passes a few deep tuple types by
// design (no heap indirection on the hot path); aliasing each one would
// scatter single-use type definitions across the crate.
#![allow(clippy::type_complexity)]

pub mod baselines;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kern;
pub mod linalg;
pub mod math;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod runtime;
pub mod testutil;
