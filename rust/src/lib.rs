//! # gpparallel
//!
//! Distributed + accelerated sparse Gaussian process models: a
//! reproduction of *"Gaussian Process Models with Parallelization and GPU
//! acceleration"* (Dai, Damianou, Hensman & Lawrence, 2014) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! - **Layer 1** (`python/compile/kernels/`): Pallas kernels for the psi
//!   statistics — the paper's GPU bottleneck.
//! - **Layer 2** (`python/compile/model.py`): the variational objective in
//!   JAX, AOT-lowered to HLO-text artifacts.
//! - **Layer 3** (this crate): the distributed coordinator — data
//!   partitioning, simulated-MPI collectives, the leader's M×M core, the
//!   central optimiser — plus every substrate (linear algebra, kernels
//!   with analytic gradients, optimisers, data generation, JSON, CLI).
//!
//! Entry points: [`models::SparseGpRegression`], [`models::BayesianGplvm`],
//! [`models::Mrd`], and the lower-level [`coordinator::Engine`].
//!
//! See DESIGN.md for the paper↔module map and EXPERIMENTS.md for the
//! reproduced figures.

pub mod baselines;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kern;
pub mod linalg;
pub mod math;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod runtime;
pub mod testutil;
