//! Pluggable point-to-point transport under [`Comm`](super::Comm).
//!
//! [`Comm`](super::Comm) owns the collective algorithms (tree/linear
//! bcast, reduce, gather) and the per-(src, tag) parking logic; the
//! *wire* underneath — how a tagged payload physically moves from rank
//! to rank — is abstracted behind the [`Transport`] trait so it can be
//! swapped without touching any protocol code:
//!
//! - [`InMemoryTransport`] — the production substrate today: one mpsc
//!   channel per rank, full mesh of senders, shared byte/message
//!   counters. Bit-identical to the pre-trait `Comm` internals.
//! - [`FaultyTransport`] — a decorator over any transport that
//!   deterministically injects exactly one seeded fault at a chosen
//!   send index: payload truncation, NaN/garbage corruption, bounded
//!   delay (FIFO-preserving), or a dead-peer hangup. The chaos harness
//!   (`testutil::chaos`) sweeps it across every message of a protocol.
//!
//! Dead peers are first-class: a transport that shuts down (explicitly,
//! on drop, or because its rank panicked and unwound) notifies every
//! peer with a hangup marker, so a rank blocked in `recv` on a dead
//! peer gets an error instead of hanging forever. This is the trait
//! surface a future TCP transport plugs into (ROADMAP: real
//! multi-process cluster).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::data::rng::Rng64;

// The hangup sentinel lives in the wire-protocol registry
// (`collectives::protocol`) so its value is uniqueness-checked against
// every protocol tag. It never reaches protocol code as a tag:
// [`InMemoryTransport::recv_blocking`] translates it into
// [`Delivery::Hangup`].
use super::protocol::TAG_HANGUP;

/// How many subsequent sends a [`FaultKind::Delay`] fault may hold a
/// message back before it is force-flushed (it also flushes before any
/// later send on the same (dst, tag) stream, before the transport
/// blocks in a receive, and at shutdown — so delivery is always
/// bounded and per-(src, tag) FIFO is preserved).
const DELAY_WINDOW: u32 = 3;

/// Error surfaced by a [`Transport`]: a dead peer or a torn-down
/// cluster. Implements [`std::error::Error`], so it converts into
/// `anyhow::Error` via `?`.
#[derive(Debug, Clone)]
pub struct TransportError {
    what: String,
}

impl TransportError {
    fn new(what: impl Into<String>) -> Self {
        TransportError { what: what.into() }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "transport: {}", self.what)
    }
}

impl std::error::Error for TransportError {}

/// One delivery out of [`Transport::recv_blocking`] / [`Transport::try_recv`].
pub enum Delivery {
    /// A payload message from `src` with `tag`.
    Message {
        /// Sending rank.
        src: usize,
        /// Message tag.
        tag: u64,
        /// Payload.
        data: Vec<f64>,
    },
    /// Peer `src`'s transport shut down; no further messages from it
    /// will ever arrive (its pre-shutdown messages were delivered
    /// before this marker — per-sender FIFO).
    Hangup(usize),
}

/// The point-to-point wire under [`Comm`](super::Comm): tagged sends,
/// blocking/non-blocking receives, and dead-peer notification.
///
/// Implementations must preserve per-sender FIFO order (messages from
/// one rank arrive in send order, regardless of tag) and must notify
/// peers on [`shutdown`](Transport::shutdown) so nobody blocks forever
/// on a dead rank.
pub trait Transport: Send {
    /// This rank's index in the cluster.
    fn rank(&self) -> usize;
    /// Cluster size P.
    fn size(&self) -> usize;
    /// Ship `data` to `dst` under `tag` (non-blocking; buffered).
    /// Errors if `dst` has already shut down or this transport is
    /// closed.
    fn send(&mut self, dst: usize, tag: u64, data: &[f64]) -> Result<(), TransportError>;
    /// Block until the next delivery (a message from any peer, or a
    /// hangup marker). Errors only if the cluster is torn down so
    /// completely that no delivery can ever arrive.
    fn recv_blocking(&mut self) -> Result<Delivery, TransportError>;
    /// Non-blocking receive: the next delivery if one is already
    /// queued, else `None`. Never waits.
    fn try_recv(&mut self) -> Option<Delivery>;
    /// Close this transport and notify every peer (idempotent). Called
    /// automatically on drop.
    fn shutdown(&mut self);
    /// Total payload bytes shipped by the whole cluster (shared
    /// counter; hangup markers are transport control, not payload, and
    /// are not counted).
    fn bytes_sent(&self) -> u64;
    /// Total payload messages shipped by the whole cluster (shared
    /// counter).
    fn messages_sent(&self) -> u64;
    /// Payload messages sent by *this rank* through *this transport*
    /// (protocol-level count: a delayed message counts when the
    /// protocol sent it, not when the fault injector released it).
    fn local_sent(&self) -> u64;
}

/// A tagged message on the in-memory wire.
struct Message {
    src: usize,
    tag: u64,
    data: Vec<f64>,
}

/// The in-process production transport: one mpsc channel per rank, a
/// full mesh of senders, shared cluster-wide traffic counters.
pub struct InMemoryTransport {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    bytes_sent: Arc<AtomicU64>,
    messages_sent: Arc<AtomicU64>,
    local_sent: u64,
    closed: bool,
}

impl InMemoryTransport {
    /// Build a fully-connected mesh of `size` transports (index = rank)
    /// sharing one pair of traffic counters.
    pub fn mesh(size: usize) -> Vec<InMemoryTransport> {
        assert!(size >= 1);
        let bytes = Arc::new(AtomicU64::new(0));
        let msgs = Arc::new(AtomicU64::new(0));
        let mut senders: Vec<Sender<Message>> = Vec::with_capacity(size);
        let mut inboxes: Vec<Receiver<Message>> = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(rx);
        }
        inboxes
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| InMemoryTransport {
                rank,
                size,
                senders: senders.clone(),
                inbox,
                bytes_sent: bytes.clone(),
                messages_sent: msgs.clone(),
                local_sent: 0,
                closed: false,
            })
            .collect()
    }
}

impl Transport for InMemoryTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, tag: u64, data: &[f64]) -> Result<(), TransportError> {
        if self.closed {
            return Err(TransportError::new(format!(
                "rank {} transport is shut down", self.rank
            )));
        }
        self.senders[dst]
            .send(Message { src: self.rank, tag, data: data.to_vec() })
            .map_err(|_| {
                TransportError::new(format!("peer rank {dst} hung up (send failed)"))
            })?;
        // Relaxed: pure statistics counters — monotonic fetch_adds with
        // no other memory ordered by them; message delivery itself is
        // ordered by the mpsc channel, not these counts.
        self.bytes_sent.fetch_add((data.len() * 8) as u64, Ordering::Relaxed);
        self.messages_sent.fetch_add(1, Ordering::Relaxed); // Relaxed: statistics counter (see above)
        self.local_sent += 1;
        Ok(())
    }

    fn recv_blocking(&mut self) -> Result<Delivery, TransportError> {
        match self.inbox.recv() {
            Ok(m) if m.tag == TAG_HANGUP => Ok(Delivery::Hangup(m.src)),
            Ok(m) => Ok(Delivery::Message { src: m.src, tag: m.tag, data: m.data }),
            // Every peer's sender (and our own self-sender) is gone:
            // the cluster is fully torn down around us.
            Err(_) => Err(TransportError::new("cluster torn down mid-recv")),
        }
    }

    fn try_recv(&mut self) -> Option<Delivery> {
        match self.inbox.try_recv() {
            Ok(m) if m.tag == TAG_HANGUP => Some(Delivery::Hangup(m.src)),
            Ok(m) => Some(Delivery::Message { src: m.src, tag: m.tag, data: m.data }),
            Err(_) => None,
        }
    }

    fn shutdown(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        // Wake every peer that might be (or later block) in a recv on
        // us. Best-effort: a peer that is itself already gone has
        // dropped its receiver, and that is fine.
        for dst in 0..self.size {
            if dst != self.rank {
                let _ = self.senders[dst].send(Message {
                    src: self.rank,
                    tag: TAG_HANGUP,
                    data: Vec::new(),
                });
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed) // Relaxed: statistics snapshot, may lag in-flight sends
    }

    fn messages_sent(&self) -> u64 {
        self.messages_sent.load(Ordering::Relaxed) // Relaxed: statistics snapshot, may lag in-flight sends
    }

    fn local_sent(&self) -> u64 {
        self.local_sent
    }
}

impl Drop for InMemoryTransport {
    // A rank that returns normally *or unwinds from a panic* notifies
    // its peers either way — this is what keeps a panicking rank from
    // hanging the survivors' blocking recvs.
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The four deterministic fault kinds [`FaultyTransport`] can inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Deliver a strict prefix of the payload (seeded length).
    Truncate,
    /// Deliver the right length but seeded garbage values (NaN, ±inf,
    /// huge magnitudes) in some positions.
    Corrupt,
    /// Hold the message back, releasing it after at most
    /// [`DELAY_WINDOW`] later sends — and always before a later send
    /// on the same (dst, tag) stream, before blocking in a receive,
    /// and at shutdown. Reorders across streams, never within one, so
    /// results must stay bit-identical to the fault-free run.
    Delay,
    /// Drop the message and kill the transport: peers get hangup
    /// markers, every later operation on this rank errors.
    Hangup,
}

impl FaultKind {
    /// All kinds, in sweep order.
    pub const ALL: [FaultKind; 4] =
        [FaultKind::Truncate, FaultKind::Corrupt, FaultKind::Delay, FaultKind::Hangup];

    /// Short stable name (used in replay seeds and test labels).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Truncate => "truncate",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Delay => "delay",
            FaultKind::Hangup => "hangup",
        }
    }

    /// Inverse of [`name`](FaultKind::name).
    pub fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// A fully deterministic fault: *the `index`-th send() call made by
/// `rank`* suffers `kind`, with value-level randomness (truncation
/// point, garbage values) derived from `seed`. Indexing by the
/// victim's own program-order send count makes the injection point
/// independent of thread interleaving, so a plan replays bit-identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// The rank whose transport misbehaves.
    pub rank: usize,
    /// Zero-based index into that rank's sequence of send() calls.
    pub index: u64,
    /// What goes wrong.
    pub kind: FaultKind,
    /// Seed for the fault's value-level randomness.
    pub seed: u64,
}

/// Decorator injecting exactly one [`FaultPlan`] fault into an inner
/// transport. Wrap the victim rank's transport; all other ranks run
/// clean.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    sent: u64,
    /// A message held back by a Delay fault: (dst, tag, payload).
    held: Option<(usize, u64, Vec<f64>)>,
    hold_left: u32,
    /// Set once a Hangup fault fires; every later op errors.
    dropped: bool,
}

impl FaultyTransport {
    /// Wrap `inner` with the given plan. `plan.rank` must equal
    /// `inner.rank()` (the harness wires this up; debug-asserted).
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> Self {
        debug_assert_eq!(plan.rank, inner.rank(), "fault plan targets a different rank");
        FaultyTransport { inner, plan, sent: 0, held: None, hold_left: 0, dropped: false }
    }

    /// Release the held Delay message, if any (best-effort: if the
    /// destination died in the meantime the message is lost, exactly
    /// like a real wire).
    fn flush_held(&mut self) {
        if let Some((dst, tag, data)) = self.held.take() {
            let _ = self.inner.send(dst, tag, &data);
        }
    }

    /// Seeded RNG for this fault's value-level choices.
    fn fault_rng(&self) -> Rng64 {
        Rng64::new(
            self.plan
                .seed
                .wrapping_mul(0x9E3779B97f4A7C15)
                .wrapping_add(self.plan.index)
                .wrapping_add((self.plan.rank as u64) << 32),
        )
    }
}

impl Transport for FaultyTransport {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&mut self, dst: usize, tag: u64, data: &[f64]) -> Result<(), TransportError> {
        if self.dropped {
            return Err(TransportError::new(format!(
                "rank {} hung up (injected fault)", self.plan.rank
            )));
        }
        let idx = self.sent;
        self.sent += 1;

        if idx == self.plan.index {
            match self.plan.kind {
                FaultKind::Delay => {
                    self.held = Some((dst, tag, data.to_vec()));
                    self.hold_left = DELAY_WINDOW;
                    return Ok(());
                }
                FaultKind::Truncate => {
                    if data.is_empty() {
                        return self.inner.send(dst, tag, data);
                    }
                    let mut rng = self.fault_rng();
                    let new_len = (rng.next_u64() % data.len() as u64) as usize;
                    return self.inner.send(dst, tag, &data[..new_len]);
                }
                FaultKind::Corrupt => {
                    let mut rng = self.fault_rng();
                    const GARBAGE: [f64; 4] = [f64::NAN, f64::INFINITY, -1.0e300, 3.5e9];
                    let mut bad = data.to_vec();
                    if bad.is_empty() {
                        bad.push(f64::NAN);
                    } else {
                        // Corrupt ~1/4 of positions, and always at
                        // least one so the fault is never a no-op.
                        let force = (rng.next_u64() % bad.len() as u64) as usize;
                        for (i, v) in bad.iter_mut().enumerate() {
                            let roll = rng.next_u64();
                            if i == force || roll % 4 == 0 {
                                *v = GARBAGE[(roll >> 32) as usize % GARBAGE.len()];
                            }
                        }
                    }
                    return self.inner.send(dst, tag, &bad);
                }
                FaultKind::Hangup => {
                    self.dropped = true;
                    self.held = None;
                    self.inner.shutdown();
                    return Err(TransportError::new(format!(
                        "rank {} hung up (injected fault)", self.plan.rank
                    )));
                }
            }
        }

        // Normal send, but respect a held Delay message: same-stream
        // sends must flush it first (FIFO), and any send shrinks the
        // hold window.
        if let Some((hd, ht, _)) = self.held {
            if (hd, ht) == (dst, tag) {
                self.flush_held();
                return self.inner.send(dst, tag, data);
            }
        }
        let res = self.inner.send(dst, tag, data);
        if self.held.is_some() {
            self.hold_left = self.hold_left.saturating_sub(1);
            if self.hold_left == 0 {
                self.flush_held();
            }
        }
        res
    }

    fn recv_blocking(&mut self) -> Result<Delivery, TransportError> {
        if self.dropped {
            return Err(TransportError::new(format!(
                "rank {} hung up (injected fault)", self.plan.rank
            )));
        }
        // Never block while holding a message another rank may be
        // waiting on — that would manufacture a deadlock the real
        // protocol doesn't have.
        self.flush_held();
        self.inner.recv_blocking()
    }

    fn try_recv(&mut self) -> Option<Delivery> {
        if self.dropped {
            return None;
        }
        self.flush_held();
        self.inner.try_recv()
    }

    fn shutdown(&mut self) {
        self.flush_held();
        self.inner.shutdown();
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn messages_sent(&self) -> u64 {
        self.inner.messages_sent()
    }

    fn local_sent(&self) -> u64 {
        self.sent
    }
}

impl Drop for FaultyTransport {
    fn drop(&mut self) {
        // Release anything still held before the inner transport's own
        // drop notifies peers; a held message must never outlive the
        // wire (bounded delay even when the rank exits immediately).
        if !self.dropped {
            self.flush_held();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (InMemoryTransport, InMemoryTransport) {
        let mut v = InMemoryTransport::mesh(2).into_iter();
        (v.next().unwrap(), v.next().unwrap())
    }

    #[test]
    fn in_memory_roundtrip_and_counters() {
        let (mut a, mut b) = pair();
        a.send(1, 42, &[1.0, 2.0]).unwrap();
        match b.recv_blocking().unwrap() {
            Delivery::Message { src, tag, data } => {
                assert_eq!((src, tag), (0, 42));
                assert_eq!(data, vec![1.0, 2.0]);
            }
            Delivery::Hangup(_) => panic!("unexpected hangup"),
        }
        assert_eq!(a.local_sent(), 1);
        assert_eq!(b.messages_sent(), 1);
        assert_eq!(b.bytes_sent(), 16);
    }

    #[test]
    fn shutdown_delivers_hangup_marker_not_payload() {
        let (mut a, mut b) = pair();
        a.send(1, 7, &[9.0]).unwrap();
        a.shutdown();
        // FIFO: the payload arrives before the marker.
        assert!(matches!(b.recv_blocking().unwrap(), Delivery::Message { .. }));
        match b.recv_blocking().unwrap() {
            Delivery::Hangup(src) => assert_eq!(src, 0),
            Delivery::Message { .. } => panic!("marker leaked as payload"),
        }
        // Sending on a shut-down transport errors instead of panicking.
        assert!(a.send(1, 7, &[1.0]).is_err());
    }

    #[test]
    fn drop_notifies_peers() {
        let (a, mut b) = pair();
        drop(a);
        assert!(matches!(b.recv_blocking().unwrap(), Delivery::Hangup(0)));
    }

    #[test]
    fn send_to_dropped_peer_errors() {
        let (mut a, b) = pair();
        drop(b);
        assert!(a.send(1, 1, &[0.0]).is_err());
    }

    #[test]
    fn delay_fault_preserves_per_stream_fifo() {
        let (a, mut b) = pair();
        let plan = FaultPlan { rank: 0, index: 0, kind: FaultKind::Delay, seed: 1 };
        let mut f = FaultyTransport::new(Box::new(a), plan);
        f.send(1, 5, &[1.0]).unwrap(); // held
        f.send(1, 9, &[2.0]).unwrap(); // other stream: goes first
        f.send(1, 5, &[3.0]).unwrap(); // same stream: forces flush of [1.0]
        let mut got = Vec::new();
        for _ in 0..3 {
            if let Delivery::Message { tag, data, .. } = b.recv_blocking().unwrap() {
                got.push((tag, data[0]));
            }
        }
        assert_eq!(got, vec![(9, 2.0), (5, 1.0), (5, 3.0)]);
        assert_eq!(f.local_sent(), 3, "protocol-level count, not wire count");
    }

    #[test]
    fn delay_fault_flushes_before_blocking_recv() {
        let (a, mut b) = pair();
        let plan = FaultPlan { rank: 0, index: 0, kind: FaultKind::Delay, seed: 1 };
        let mut f = FaultyTransport::new(Box::new(a), plan);
        f.send(1, 5, &[1.0]).unwrap(); // held
        // The peer replies only after it sees our message — if recv
        // didn't flush, this would deadlock.
        let t = std::thread::spawn(move || {
            assert!(matches!(b.recv_blocking().unwrap(), Delivery::Message { .. }));
            b.send(0, 6, &[2.0]).unwrap();
            b
        });
        assert!(matches!(f.recv_blocking().unwrap(), Delivery::Message { .. }));
        t.join().unwrap();
    }

    #[test]
    fn truncate_fault_shortens_exactly_one_message() {
        let (a, mut b) = pair();
        let plan = FaultPlan { rank: 0, index: 1, kind: FaultKind::Truncate, seed: 3 };
        let mut f = FaultyTransport::new(Box::new(a), plan);
        f.send(1, 5, &[1.0; 4]).unwrap();
        f.send(1, 5, &[2.0; 4]).unwrap(); // victim
        f.send(1, 5, &[3.0; 4]).unwrap();
        let lens: Vec<usize> = (0..3)
            .map(|_| match b.recv_blocking().unwrap() {
                Delivery::Message { data, .. } => data.len(),
                Delivery::Hangup(_) => panic!("unexpected hangup"),
            })
            .collect();
        assert_eq!(lens[0], 4);
        assert!(lens[1] < 4, "victim must be strictly truncated, got {}", lens[1]);
        assert_eq!(lens[2], 4);
    }

    #[test]
    fn corrupt_fault_changes_payload_and_replays_identically() {
        let run = || {
            let (a, mut b) = pair();
            let plan = FaultPlan { rank: 0, index: 0, kind: FaultKind::Corrupt, seed: 7 };
            let mut f = FaultyTransport::new(Box::new(a), plan);
            f.send(1, 5, &[1.0; 8]).unwrap();
            match b.recv_blocking().unwrap() {
                Delivery::Message { data, .. } => data,
                Delivery::Hangup(_) => panic!("unexpected hangup"),
            }
        };
        let x = run();
        let y = run();
        assert_eq!(x.len(), 8, "corruption keeps the length");
        assert!(x.iter().zip(&[1.0; 8]).any(|(a, b)| a.to_bits() != b.to_bits()),
                "at least one element must change");
        let same = x.iter().zip(&y).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "same plan must corrupt identically");
    }

    #[test]
    fn hangup_fault_kills_transport_and_notifies_peer() {
        let (a, mut b) = pair();
        let plan = FaultPlan { rank: 0, index: 1, kind: FaultKind::Hangup, seed: 1 };
        let mut f = FaultyTransport::new(Box::new(a), plan);
        f.send(1, 5, &[1.0]).unwrap();
        assert!(f.send(1, 5, &[2.0]).is_err(), "the fault itself errors");
        assert!(f.send(1, 5, &[3.0]).is_err(), "and stays sticky");
        assert!(f.recv_blocking().is_err());
        // Peer sees the real payload, then the hangup.
        assert!(matches!(b.recv_blocking().unwrap(), Delivery::Message { .. }));
        assert!(matches!(b.recv_blocking().unwrap(), Delivery::Hangup(0)));
    }
}
