//! Point-to-point and collective operations over in-process channels.
//!
//! Collectives come in two interchangeable topologies:
//!
//! - **Linear** — the reference implementation: the root receives (or
//!   sends) `P − 1` messages sequentially. O(P) critical path.
//! - **Tree** (default) — binomial-tree `bcast`/`reduce_sum`: each round
//!   doubles the set of ranks reached (or halves the set still holding
//!   partial sums), so the critical path is O(log P) messages. This is
//!   the textbook MPI algorithm and what makes the leader's per-iteration
//!   collectives scale past a handful of ranks.
//!
//! Both topologies produce the same results (bit-identical for `bcast`,
//! equal up to floating-point reduction order for `reduce_sum`); the
//! equivalence is property-tested below for every cluster size 1–9.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A tagged message between ranks.
struct Message {
    src: usize,
    tag: u64,
    data: Vec<f64>,
}

/// Which algorithm the collectives use. Selectable per-`Comm`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Topology {
    /// Sequential fan-in/fan-out at the root (reference).
    Linear,
    /// Binomial tree: O(log P) critical path.
    #[default]
    Tree,
}

/// Per-rank communicator handle (the MPI_Comm analog).
pub struct Comm {
    rank: usize,
    size: usize,
    topology: Topology,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    /// Out-of-order messages parked until a matching recv. `VecDeque` so
    /// delivery pops are O(1) (a `Vec::remove(0)` here is O(n) per
    /// message — O(n²) under sustained out-of-order traffic).
    parked: HashMap<(usize, u64), VecDeque<Vec<f64>>>,
    bytes_sent: Arc<AtomicU64>,
    messages_sent: Arc<AtomicU64>,
}

const TAG_BCAST: u64 = u64::MAX - 1;
const TAG_REDUCE: u64 = u64::MAX - 2;
const TAG_GATHER: u64 = u64::MAX - 3;

impl Comm {
    /// This rank's index in the cluster.
    pub fn rank(&self) -> usize { self.rank }
    /// Cluster size P.
    pub fn size(&self) -> usize { self.size }
    /// Is this rank 0?
    pub fn is_root(&self) -> bool { self.rank == 0 }

    /// The collective topology in use.
    pub fn topology(&self) -> Topology { self.topology }

    /// Switch collective algorithms. Every rank of a communicator must
    /// agree (SPMD code always does, since they run the same line).
    pub fn set_topology(&mut self, t: Topology) { self.topology = t; }

    /// Total bytes this *cluster* has shipped (shared counter).
    pub fn bytes_sent(&self) -> u64 { self.bytes_sent.load(Ordering::Relaxed) }
    /// Total messages this *cluster* has shipped (shared counter).
    pub fn messages_sent(&self) -> u64 { self.messages_sent.load(Ordering::Relaxed) }

    /// Send `data` to `dst` with a tag (non-blocking; channels buffer).
    pub fn send(&self, dst: usize, tag: u64, data: &[f64]) {
        self.bytes_sent.fetch_add((data.len() * 8) as u64, Ordering::Relaxed);
        self.messages_sent.fetch_add(1, Ordering::Relaxed);
        self.senders[dst]
            .send(Message { src: self.rank, tag, data: data.to_vec() })
            .expect("peer hung up");
    }

    /// Blocking receive of the next message from `src` with `tag`
    /// (out-of-order arrivals are parked, preserving per-(src,tag) order).
    pub fn recv(&mut self, src: usize, tag: u64) -> Vec<f64> {
        if let Some(q) = self.parked.get_mut(&(src, tag)) {
            if let Some(data) = q.pop_front() {
                return data;
            }
        }
        loop {
            let msg = self.inbox.recv().expect("cluster torn down mid-recv");
            if msg.src == src && msg.tag == tag {
                return msg.data;
            }
            self.parked.entry((msg.src, msg.tag)).or_default().push_back(msg.data);
        }
    }

    /// Drain every message already sitting in this rank's inbox into the
    /// parked map (non-blocking; never waits). Per-(src, tag) FIFO order
    /// is preserved, so a later [`recv`](Comm::recv) returns exactly what
    /// it would have returned without the drain — this is a *progress*
    /// primitive, not a semantic one. The serving leader calls it before
    /// computing its own shard so worker gather payloads that are already
    /// in flight get absorbed while the compute runs, instead of queueing
    /// behind it (the in-process analog of posting MPI receives early).
    /// Returns the number of messages parked.
    pub fn drain_pending(&mut self) -> usize {
        let mut n = 0;
        while let Ok(msg) = self.inbox.try_recv() {
            self.parked.entry((msg.src, msg.tag)).or_default().push_back(msg.data);
            n += 1;
        }
        n
    }

    // -----------------------------------------------------------------
    // broadcast
    // -----------------------------------------------------------------

    /// Broadcast from `root`: returns the root's `data` on every rank.
    /// Dispatches on the communicator's [`Topology`].
    pub fn bcast(&mut self, root: usize, data: Vec<f64>) -> Vec<f64> {
        match self.topology {
            Topology::Linear => self.bcast_linear(root, data),
            Topology::Tree => self.bcast_tree(root, data),
        }
    }

    /// Linear broadcast (reference): root sends to each rank in turn.
    pub fn bcast_linear(&mut self, root: usize, data: Vec<f64>) -> Vec<f64> {
        if self.rank == root {
            for dst in 0..self.size {
                if dst != root {
                    self.send(dst, TAG_BCAST, &data);
                }
            }
            data
        } else {
            self.recv(root, TAG_BCAST)
        }
    }

    /// Binomial-tree broadcast: rank v (relative to the root) receives
    /// from `v − lowest_set_bit(v)` and forwards to `v + 2^k` for every
    /// `2^k` below its lowest set bit — ⌈log₂ P⌉ rounds end to end.
    pub fn bcast_tree(&mut self, root: usize, data: Vec<f64>) -> Vec<f64> {
        let size = self.size;
        let vrank = (self.rank + size - root) % size;
        let to_real = |v: usize| (v + root) % size;

        // Receive phase (no-op at the root): scan up to the lowest set
        // bit of vrank — that bit names the parent.
        let mut mask = 1usize;
        let data = if vrank == 0 {
            while mask < size {
                mask <<= 1;
            }
            data
        } else {
            loop {
                if vrank & mask != 0 {
                    let parent = vrank - mask;
                    break self.recv(to_real(parent), TAG_BCAST);
                }
                mask <<= 1;
            }
        };

        // Send phase: peel `mask` back down (always below our lowest set
        // bit), forwarding to each child in range.
        mask >>= 1;
        while mask > 0 {
            let child = vrank + mask;
            if child < size {
                self.send(to_real(child), TAG_BCAST, &data);
            }
            mask >>= 1;
        }
        data
    }

    // -----------------------------------------------------------------
    // reduce
    // -----------------------------------------------------------------

    /// Element-wise sum-reduction to `root`; `Some(total)` on root,
    /// `None` elsewhere. Dispatches on the communicator's [`Topology`].
    pub fn reduce_sum(&mut self, root: usize, data: &[f64]) -> Option<Vec<f64>> {
        let mut buf = data.to_vec();
        self.reduce_sum_into(root, &mut buf).then_some(buf)
    }

    /// Buffer-reusing reduction: accumulates **in place** into `data`
    /// (the caller's reusable wire buffer), so per-cycle reductions stop
    /// allocating a fresh accumulator. Returns `true` on `root`, where
    /// `data` then holds the cluster-wide total; elsewhere returns
    /// `false` and `data` is left holding the partial this rank shipped
    /// up the tree (its own contribution plus any absorbed subtree).
    /// [`reduce_sum`](Comm::reduce_sum) and the topology-pinned variants
    /// below all delegate here, so there is exactly one copy of each
    /// accumulation order and the totals are bit-identical
    /// (property-tested below).
    pub fn reduce_sum_into(&mut self, root: usize, data: &mut Vec<f64>) -> bool {
        match self.topology {
            Topology::Linear => self.reduce_into_linear(root, data),
            Topology::Tree => self.reduce_into_tree(root, data),
        }
    }

    /// Linear reduction (reference): root receives P−1 partials in rank
    /// order and accumulates sequentially.
    pub fn reduce_sum_linear(&mut self, root: usize, data: &[f64]) -> Option<Vec<f64>> {
        let mut buf = data.to_vec();
        self.reduce_into_linear(root, &mut buf).then_some(buf)
    }

    /// Binomial-tree reduction (mirror image of `bcast_tree`): in round
    /// `k`, ranks with bit `2^k` set ship their partial sum to the parent
    /// and drop out; the root absorbs ⌈log₂ P⌉ partials instead of P−1.
    pub fn reduce_sum_tree(&mut self, root: usize, data: &[f64]) -> Option<Vec<f64>> {
        let mut buf = data.to_vec();
        self.reduce_into_tree(root, &mut buf).then_some(buf)
    }

    fn reduce_into_linear(&mut self, root: usize, data: &mut Vec<f64>) -> bool {
        if self.rank == root {
            for src in 0..self.size {
                if src == root {
                    continue;
                }
                let part = self.recv(src, TAG_REDUCE);
                assert_eq!(part.len(), data.len(), "reduce length mismatch");
                for (a, b) in data.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            true
        } else {
            self.send(root, TAG_REDUCE, data.as_slice());
            false
        }
    }

    fn reduce_into_tree(&mut self, root: usize, data: &mut Vec<f64>) -> bool {
        let size = self.size;
        let vrank = (self.rank + size - root) % size;
        let to_real = |v: usize| (v + root) % size;
        let mut mask = 1usize;
        while mask < size {
            if vrank & mask == 0 {
                let child = vrank + mask;
                if child < size {
                    let part = self.recv(to_real(child), TAG_REDUCE);
                    assert_eq!(part.len(), data.len(), "reduce length mismatch");
                    for (a, b) in data.iter_mut().zip(&part) {
                        *a += b;
                    }
                }
            } else {
                let parent = vrank - mask;
                self.send(to_real(parent), TAG_REDUCE, data.as_slice());
                return false;
            }
            mask <<= 1;
        }
        true
    }

    // -----------------------------------------------------------------
    // composites
    // -----------------------------------------------------------------

    /// Reduce-to-root followed by broadcast (the classic two-phase
    /// allreduce; the paper's scheme reduces to one node anyway because
    /// the optimiser is centralised).
    pub fn allreduce_sum(&mut self, data: &[f64]) -> Vec<f64> {
        match self.reduce_sum(0, data) {
            Some(total) => self.bcast(0, total),
            None => self.bcast(0, Vec::new()),
        }
    }

    /// Gather every rank's vector at `root` (indexed by rank). Payloads
    /// are heterogeneous, so this stays a point-to-point fan-in.
    pub fn gather(&mut self, root: usize, data: &[f64]) -> Option<Vec<Vec<f64>>> {
        if self.rank == root {
            let mut out = vec![Vec::new(); self.size];
            out[root] = data.to_vec();
            for src in 0..self.size {
                if src != root {
                    out[src] = self.recv(src, TAG_GATHER);
                }
            }
            Some(out)
        } else {
            self.send(root, TAG_GATHER, data);
            None
        }
    }

    /// Barrier: empty allreduce.
    pub fn barrier(&mut self) {
        let _ = self.allreduce_sum(&[]);
    }
}

/// Cluster launcher: spawns `size` SPMD ranks and joins them.
pub struct Cluster;

impl Cluster {
    /// Run `f` on `size` ranks (each on its own OS thread; rank r gets a
    /// connected `Comm` with the default [`Topology::Tree`] collectives).
    /// Returns the per-rank results, indexed by rank. Panics in any rank
    /// propagate.
    pub fn run<T, F>(size: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Comm) -> T + Sync,
    {
        Cluster::run_with(size, Topology::default(), f)
    }

    /// `run` with an explicit collective topology.
    pub fn run_with<T, F>(size: usize, topology: Topology, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Comm) -> T + Sync,
    {
        assert!(size >= 1);
        let bytes = Arc::new(AtomicU64::new(0));
        let msgs = Arc::new(AtomicU64::new(0));

        // Full mesh: one (sender-set, receiver) pair per rank.
        let mut senders_per_rank: Vec<Sender<Message>> = Vec::with_capacity(size);
        let mut inboxes: Vec<Option<Receiver<Message>>> = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            senders_per_rank.push(tx);
            inboxes.push(Some(rx));
        }

        let comms: Vec<Comm> = (0..size)
            .map(|rank| Comm {
                rank,
                size,
                topology,
                senders: senders_per_rank.clone(),
                inbox: inboxes[rank].take().unwrap(),
                parked: HashMap::new(),
                bytes_sent: bytes.clone(),
                messages_sent: msgs.clone(),
            })
            .collect();
        drop(senders_per_rank);

        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| scope.spawn(move || f(comm)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank panicked"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn allreduce_equals_serial_sum() {
        for size in [1, 2, 3, 5, 8] {
            let results = Cluster::run(size, |mut comm| {
                let local: Vec<f64> = (0..4).map(|i| (comm.rank() * 10 + i) as f64).collect();
                comm.allreduce_sum(&local)
            });
            let expect: Vec<f64> = (0..4)
                .map(|i| (0..size).map(|r| (r * 10 + i) as f64).sum())
                .collect();
            for r in &results {
                assert_eq!(*r, expect, "size {size}");
            }
        }
    }

    #[test]
    fn bcast_delivers_root_value() {
        for topology in [Topology::Linear, Topology::Tree] {
            let results = Cluster::run_with(4, topology, |mut comm| {
                let data = if comm.is_root() { vec![3.5, -1.0] } else { vec![] };
                comm.bcast(0, data)
            });
            for r in results {
                assert_eq!(r, vec![3.5, -1.0], "{topology:?}");
            }
        }
    }

    #[test]
    fn gather_indexes_by_rank() {
        let results = Cluster::run(3, |mut comm| {
            comm.gather(0, &[comm.rank() as f64 * 2.0])
        });
        let at_root = results[0].as_ref().unwrap();
        assert_eq!(at_root.len(), 3);
        for (r, v) in at_root.iter().enumerate() {
            assert_eq!(v[0], r as f64 * 2.0);
        }
        assert!(results[1].is_none() && results[2].is_none());
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        // rank 1 sends tag B then tag A; rank 0 receives A then B.
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                comm.send(0, 7, &[7.0]);
                comm.send(0, 5, &[5.0]);
                vec![]
            } else {
                let a = comm.recv(1, 5);
                let b = comm.recv(1, 7);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[0], vec![5.0, 7.0]);
    }

    #[test]
    fn parked_queue_preserves_fifo_order_per_tag() {
        // Three messages on one (src, tag) arrive while rank 0 waits on a
        // different tag; they must drain in send order afterwards.
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                for v in [1.0, 2.0, 3.0] {
                    comm.send(0, 9, &[v]);
                }
                comm.send(0, 4, &[0.0]);
                vec![]
            } else {
                let _ = comm.recv(1, 4); // parks all three tag-9 messages
                (0..3).map(|_| comm.recv(1, 9)[0]).collect()
            }
        });
        assert_eq!(results[0], vec![1.0, 2.0, 3.0]);
    }

    /// `drain_pending` is a progress primitive only: it moves messages
    /// into the parked map without sending anything, and later `recv`s
    /// see exactly the per-(src, tag) FIFO order they would have seen
    /// without the drain — including messages that arrive *after* it.
    #[test]
    fn drain_pending_preserves_recv_order_and_sends_nothing() {
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 0 {
                // first wave: exactly three messages are in flight
                let mut drained = 0;
                while drained < 3 {
                    drained += comm.drain_pending();
                    std::thread::yield_now();
                }
                let before = comm.messages_sent();
                assert_eq!(comm.drain_pending(), 0, "nothing else is in flight");
                assert_eq!(comm.messages_sent(), before, "drain must not send");
                // parked messages drain through recv in send order
                let mut got = vec![comm.recv(1, 9)[0], comm.recv(1, 9)[0]];
                // second wave (ack-gated, so it arrives after the drain)
                // interleaves with the remaining parked message correctly
                comm.send(1, 8, &[0.0]);
                got.push(comm.recv(1, 9)[0]);
                got.push(comm.recv(1, 9)[0]);
                got
            } else {
                for v in [1.0, 2.0, 3.0] {
                    comm.send(0, 9, &[v]);
                }
                let _ = comm.recv(0, 8); // wait until the drain happened
                comm.send(0, 9, &[4.0]);
                vec![]
            }
        });
        assert_eq!(results[0], vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn byte_counter_counts_payloads() {
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                comm.send(0, 1, &[0.0; 100]);
            } else {
                let _ = comm.recv(1, 1);
            }
            comm.barrier();
            comm.bytes_sent()
        });
        // 100 f64 payload = 800 bytes, plus barrier traffic (empty).
        assert!(results[0] >= 800, "bytes {}", results[0]);
    }

    #[test]
    fn prop_reduce_matches_serial_for_random_sizes() {
        Prop::new("reduce_random").cases(10).run(|rng| {
            let size = 1 + (rng.next_u64() % 6) as usize;
            let len = (rng.next_u64() % 20) as usize;
            let datasets: Vec<Vec<f64>> = (0..size)
                .map(|r| {
                    let mut rr = crate::data::rng::Rng64::new(r as u64 + 99);
                    rr.normal_vec(len)
                })
                .collect();
            let expect: Vec<f64> = (0..len)
                .map(|i| datasets.iter().map(|d| d[i]).sum())
                .collect();
            let ds = &datasets;
            let results = Cluster::run(size, |mut comm| {
                comm.allreduce_sum(&ds[comm.rank()])
            });
            for r in results {
                for (a, b) in r.iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-12);
                }
            }
        });
    }

    /// Tree reduce must agree with the linear reference for every cluster
    /// size 1–9 (covering perfect trees, one-past-a-power, and odd sizes)
    /// and for every root.
    #[test]
    fn prop_tree_reduce_matches_linear() {
        Prop::new("tree_vs_linear_reduce").cases(6).run(|rng| {
            let len = 1 + (rng.next_u64() % 16) as usize;
            for size in 1..=9usize {
                let root = (rng.next_u64() % size as u64) as usize;
                let datasets: Vec<Vec<f64>> = (0..size)
                    .map(|r| {
                        let mut rr = crate::data::rng::Rng64::new(r as u64 * 7 + 1);
                        rr.normal_vec(len)
                    })
                    .collect();
                let ds = &datasets;
                let run = |topology| {
                    Cluster::run_with(size, topology, move |mut comm| {
                        comm.reduce_sum(root, &ds[comm.rank()])
                    })
                };
                let lin = run(Topology::Linear);
                let tree = run(Topology::Tree);
                for r in 0..size {
                    match (&lin[r], &tree[r]) {
                        (Some(a), Some(b)) => {
                            assert_eq!(r, root);
                            for (x, y) in a.iter().zip(b) {
                                assert!((x - y).abs() < 1e-12 * (1.0 + x.abs()),
                                        "size {size} root {root}: {x} vs {y}");
                            }
                        }
                        (None, None) => assert_ne!(r, root),
                        _ => panic!("size {size}: topologies disagree on root-ness"),
                    }
                }
            }
        });
    }

    /// Tree bcast must deliver the root's exact payload on every rank for
    /// sizes 1–9 and every root.
    #[test]
    fn prop_tree_bcast_matches_linear() {
        Prop::new("tree_vs_linear_bcast").cases(6).run(|rng| {
            let payload = rng.normal_vec(1 + (rng.next_u64() % 12) as usize);
            for size in 1..=9usize {
                let root = (rng.next_u64() % size as u64) as usize;
                let pl = &payload;
                let run = |topology| {
                    Cluster::run_with(size, topology, move |mut comm| {
                        let data = if comm.rank() == root { pl.clone() } else { Vec::new() };
                        comm.bcast(root, data)
                    })
                };
                for (a, b) in run(Topology::Linear).iter().zip(&run(Topology::Tree)) {
                    assert_eq!(a, b, "size {size} root {root}");
                    assert_eq!(a, pl, "size {size} root {root}");
                }
            }
        });
    }

    /// Pipelined collectives (several in flight back to back, mixed with
    /// point-to-point traffic) stay in lockstep under the tree topology.
    #[test]
    fn tree_collectives_pipeline_safely() {
        let results = Cluster::run_with(5, Topology::Tree, |mut comm| {
            let mut acc = 0.0;
            for round in 0..4 {
                let x = comm.bcast(0, vec![round as f64]);
                let total = comm.allreduce_sum(&[x[0] + comm.rank() as f64]);
                acc += total[0];
            }
            acc
        });
        // round r: sum over ranks of (r + rank) = 5r + 10
        let expect: f64 = (0..4).map(|r| 5.0 * r as f64 + 10.0).sum();
        for r in results {
            assert!((r - expect).abs() < 1e-12, "{r} vs {expect}");
        }
    }

    /// `reduce_sum_into` must match `reduce_sum` bit-for-bit on the root
    /// for both topologies and every cluster size 1–9, and leave the
    /// buffer reusable (no reallocation needed across rounds).
    #[test]
    fn prop_reduce_into_matches_reduce() {
        Prop::new("reduce_into_vs_reduce").cases(6).run(|rng| {
            let len = 1 + (rng.next_u64() % 16) as usize;
            for topology in [Topology::Linear, Topology::Tree] {
                for size in 1..=9usize {
                    let datasets: Vec<Vec<f64>> = (0..size)
                        .map(|r| {
                            let mut rr = crate::data::rng::Rng64::new(r as u64 * 13 + 5);
                            rr.normal_vec(len)
                        })
                        .collect();
                    let ds = &datasets;
                    let alloc = Cluster::run_with(size, topology, move |mut comm| {
                        comm.reduce_sum(0, &ds[comm.rank()])
                    });
                    let inplace = Cluster::run_with(size, topology, move |mut comm| {
                        // two rounds through one buffer: reuse must not
                        // leak the previous round's partials
                        let mut buf = ds[comm.rank()].clone();
                        let first_root = comm.reduce_sum_into(0, &mut buf);
                        buf.clear();
                        buf.extend_from_slice(&ds[comm.rank()]);
                        let root = comm.reduce_sum_into(0, &mut buf);
                        assert_eq!(first_root, root);
                        root.then_some(buf)
                    });
                    for (a, b) in alloc.iter().zip(&inplace) {
                        match (a, b) {
                            (Some(x), Some(y)) => assert_eq!(x, y,
                                "{topology:?} size {size}: totals differ"),
                            (None, None) => {}
                            _ => panic!("{topology:?} size {size}: root-ness differs"),
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn barrier_synchronises() {
        for topology in [Topology::Linear, Topology::Tree] {
            // No deadlock across repeated barriers with mixed work.
            let results = Cluster::run_with(4, topology, |mut comm| {
                for i in 0..5 {
                    if comm.rank() % 2 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(i));
                    }
                    comm.barrier();
                }
                true
            });
            assert!(results.into_iter().all(|r| r));
        }
    }
}
