//! Point-to-point and collective operations over a pluggable
//! [`Transport`].
//!
//! Collectives come in two interchangeable topologies:
//!
//! - **Linear** — the reference implementation: the root receives (or
//!   sends) `P − 1` messages sequentially. O(P) critical path.
//! - **Tree** (default) — binomial-tree `bcast`/`reduce_sum`: each round
//!   doubles the set of ranks reached (or halves the set still holding
//!   partial sums), so the critical path is O(log P) messages. This is
//!   the textbook MPI algorithm and what makes the leader's per-iteration
//!   collectives scale past a handful of ranks.
//!
//! Both topologies produce the same results (bit-identical for `bcast`,
//! equal up to floating-point reduction order for `reduce_sum`); the
//! equivalence is property-tested below for every cluster size 1–9.
//!
//! Every operation is fallible: a dead peer (rank panicked, transport
//! shut down, injected hangup) surfaces as an `Err` instead of a hang
//! or a panic, so protocol layers tear down cleanly. Transport errors
//! are *terminal* for a rank — distinct from compute errors, which ride
//! the fail-flag machinery in lockstep (see `engine::cycle`).

use std::collections::{HashMap, HashSet, VecDeque};

use anyhow::{anyhow, Result};

use super::protocol::{TAG_BCAST, TAG_GATHER, TAG_REDUCE};
use super::transport::{Delivery, InMemoryTransport, Transport};

/// Which algorithm the collectives use. Selectable per-`Comm`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Topology {
    /// Sequential fan-in/fan-out at the root (reference).
    Linear,
    /// Binomial tree: O(log P) critical path.
    #[default]
    Tree,
}

/// Per-rank communicator handle (the MPI_Comm analog).
pub struct Comm {
    topology: Topology,
    transport: Box<dyn Transport>,
    /// Out-of-order messages parked until a matching recv. `VecDeque` so
    /// delivery pops are O(1) (a `Vec::remove(0)` here is O(n) per
    /// message — O(n²) under sustained out-of-order traffic).
    parked: HashMap<(usize, u64), VecDeque<Vec<f64>>>,
    /// Peers whose hangup marker we have consumed. Because the wire is
    /// per-sender FIFO, everything a peer sent before dying was parked
    /// before its marker — so once a peer is here, a recv on it with no
    /// parked match can *never* succeed and errors immediately.
    dead: HashSet<usize>,
}

impl Comm {
    /// Wrap a transport (in-memory, fault-injecting, or a future
    /// socket implementation) with the collective layer.
    pub fn new(transport: Box<dyn Transport>, topology: Topology) -> Comm {
        Comm { topology, transport, parked: HashMap::new(), dead: HashSet::new() }
    }

    /// This rank's index in the cluster.
    pub fn rank(&self) -> usize { self.transport.rank() }
    /// Cluster size P.
    pub fn size(&self) -> usize { self.transport.size() }
    /// Is this rank 0?
    pub fn is_root(&self) -> bool { self.rank() == 0 }

    /// The collective topology in use.
    pub fn topology(&self) -> Topology { self.topology }

    /// Switch collective algorithms. Every rank of a communicator must
    /// agree (SPMD code always does, since they run the same line).
    pub fn set_topology(&mut self, t: Topology) { self.topology = t; }

    /// Total bytes this *cluster* has shipped (shared counter).
    pub fn bytes_sent(&self) -> u64 { self.transport.bytes_sent() }
    /// Total messages this *cluster* has shipped (shared counter).
    pub fn messages_sent(&self) -> u64 { self.transport.messages_sent() }
    /// Messages *this rank* has sent (its own program-order count; the
    /// chaos harness keys fault-injection points off this).
    pub fn local_messages_sent(&self) -> u64 { self.transport.local_sent() }

    /// Send `data` to `dst` with a tag (non-blocking; the transport
    /// buffers). Errors if the destination is gone.
    pub fn send(&mut self, dst: usize, tag: u64, data: &[f64]) -> Result<()> {
        self.transport.send(dst, tag, data)?;
        Ok(())
    }

    /// Blocking receive of the next message from `src` with `tag`
    /// (out-of-order arrivals are parked, preserving per-(src,tag)
    /// order). Errors if `src` hung up before sending it.
    pub fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f64>> {
        if let Some(q) = self.parked.get_mut(&(src, tag)) {
            if let Some(data) = q.pop_front() {
                return Ok(data);
            }
        }
        if self.dead.contains(&src) {
            return Err(anyhow!("rank {src} hung up before sending (tag {tag})"));
        }
        loop {
            match self.transport.recv_blocking()? {
                Delivery::Message { src: s, tag: t, data } => {
                    if s == src && t == tag {
                        return Ok(data);
                    }
                    self.parked.entry((s, t)).or_default().push_back(data);
                }
                Delivery::Hangup(h) => {
                    self.dead.insert(h);
                    if h == src {
                        return Err(anyhow!(
                            "rank {src} hung up before sending (tag {tag})"
                        ));
                    }
                    // Someone else died; keep waiting for our peer. If
                    // our peer is (transitively) blocked on the dead
                    // rank, its own recv errors, it unwinds, and its
                    // drop delivers the marker that unblocks us —
                    // hangups cascade, so nobody waits forever.
                }
            }
        }
    }

    /// Drain every message already sitting in this rank's inbox into the
    /// parked map (non-blocking; never waits). Per-(src, tag) FIFO order
    /// is preserved, so a later [`recv`](Comm::recv) returns exactly what
    /// it would have returned without the drain — this is a *progress*
    /// primitive, not a semantic one. The serving leader calls it before
    /// computing its own shard so worker gather payloads that are already
    /// in flight get absorbed while the compute runs, instead of queueing
    /// behind it (the in-process analog of posting MPI receives early).
    /// Returns the number of messages parked (hangup markers are latched
    /// into the dead set, not counted).
    pub fn drain_pending(&mut self) -> usize {
        let mut n = 0;
        while let Some(d) = self.transport.try_recv() {
            match d {
                Delivery::Message { src, tag, data } => {
                    self.parked.entry((src, tag)).or_default().push_back(data);
                    n += 1;
                }
                Delivery::Hangup(h) => {
                    self.dead.insert(h);
                }
            }
        }
        n
    }

    /// Blocking variant of [`drain_pending`](Comm::drain_pending): park
    /// the calling thread until at least one delivery arrives, absorb
    /// it, then drain whatever else is already queued. The wait parks
    /// on the transport's blocking receive (condvar/futex under the
    /// hood) — no spin loop, no `yield_now`, no sleep-and-poll. Same
    /// FIFO-preserving semantics as `drain_pending`; returns the number
    /// of messages parked by this call (hangup markers latch into the
    /// dead set and are not counted, so `Ok(0)` is possible). Errors
    /// only if the transport itself is torn down.
    pub fn drain_blocking(&mut self) -> Result<usize> {
        let mut n = 0;
        match self.transport.recv_blocking()? {
            Delivery::Message { src, tag, data } => {
                self.parked.entry((src, tag)).or_default().push_back(data);
                n += 1;
            }
            Delivery::Hangup(h) => {
                self.dead.insert(h);
            }
        }
        Ok(n + self.drain_pending())
    }

    // -----------------------------------------------------------------
    // broadcast
    // -----------------------------------------------------------------

    /// Broadcast from `root`: returns the root's `data` on every rank.
    /// Dispatches on the communicator's [`Topology`].
    pub fn bcast(&mut self, root: usize, data: Vec<f64>) -> Result<Vec<f64>> {
        match self.topology {
            Topology::Linear => self.bcast_linear(root, data),
            Topology::Tree => self.bcast_tree(root, data),
        }
    }

    /// Linear broadcast (reference): root sends to each rank in turn.
    pub fn bcast_linear(&mut self, root: usize, data: Vec<f64>) -> Result<Vec<f64>> {
        if self.rank() == root {
            for dst in 0..self.size() {
                if dst != root {
                    self.send(dst, TAG_BCAST, &data)?;
                }
            }
            Ok(data)
        } else {
            self.recv(root, TAG_BCAST)
        }
    }

    /// Binomial-tree broadcast: rank v (relative to the root) receives
    /// from `v − lowest_set_bit(v)` and forwards to `v + 2^k` for every
    /// `2^k` below its lowest set bit — ⌈log₂ P⌉ rounds end to end.
    pub fn bcast_tree(&mut self, root: usize, data: Vec<f64>) -> Result<Vec<f64>> {
        let size = self.size();
        let vrank = (self.rank() + size - root) % size;
        let to_real = |v: usize| (v + root) % size;

        // Receive phase (no-op at the root): scan up to the lowest set
        // bit of vrank — that bit names the parent.
        let mut mask = 1usize;
        let data = if vrank == 0 {
            while mask < size {
                mask <<= 1;
            }
            data
        } else {
            loop {
                if vrank & mask != 0 {
                    let parent = vrank - mask;
                    break self.recv(to_real(parent), TAG_BCAST)?;
                }
                mask <<= 1;
            }
        };

        // Send phase: peel `mask` back down (always below our lowest set
        // bit), forwarding to each child in range.
        mask >>= 1;
        while mask > 0 {
            let child = vrank + mask;
            if child < size {
                self.send(to_real(child), TAG_BCAST, &data)?;
            }
            mask >>= 1;
        }
        Ok(data)
    }

    // -----------------------------------------------------------------
    // reduce
    // -----------------------------------------------------------------

    /// Element-wise sum-reduction to `root`; `Some(total)` on root,
    /// `None` elsewhere. Dispatches on the communicator's [`Topology`].
    pub fn reduce_sum(&mut self, root: usize, data: &[f64]) -> Result<Option<Vec<f64>>> {
        let mut buf = data.to_vec();
        Ok(self.reduce_sum_into(root, &mut buf)?.then_some(buf))
    }

    /// Buffer-reusing reduction: accumulates **in place** into `data`
    /// (the caller's reusable wire buffer), so per-cycle reductions stop
    /// allocating a fresh accumulator. Returns `Ok(true)` on `root`,
    /// where `data` then holds the cluster-wide total; elsewhere returns
    /// `Ok(false)` and `data` is left holding the partial this rank
    /// shipped up the tree (its own contribution plus any absorbed
    /// subtree). [`reduce_sum`](Comm::reduce_sum) and the
    /// topology-pinned variants below all delegate here, so there is
    /// exactly one copy of each accumulation order and the totals are
    /// bit-identical (property-tested below).
    pub fn reduce_sum_into(&mut self, root: usize, data: &mut Vec<f64>) -> Result<bool> {
        match self.topology {
            Topology::Linear => self.reduce_into_linear(root, data),
            Topology::Tree => self.reduce_into_tree(root, data),
        }
    }

    /// Linear reduction (reference): root receives P−1 partials in rank
    /// order and accumulates sequentially.
    pub fn reduce_sum_linear(&mut self, root: usize, data: &[f64]) -> Result<Option<Vec<f64>>> {
        let mut buf = data.to_vec();
        Ok(self.reduce_into_linear(root, &mut buf)?.then_some(buf))
    }

    /// Binomial-tree reduction (mirror image of `bcast_tree`): in round
    /// `k`, ranks with bit `2^k` set ship their partial sum to the parent
    /// and drop out; the root absorbs ⌈log₂ P⌉ partials instead of P−1.
    pub fn reduce_sum_tree(&mut self, root: usize, data: &[f64]) -> Result<Option<Vec<f64>>> {
        let mut buf = data.to_vec();
        Ok(self.reduce_into_tree(root, &mut buf)?.then_some(buf))
    }

    /// A received reduction partial whose length disagrees with ours is
    /// a protocol breach (truncated or misrouted wire): error out rather
    /// than fold garbage or panic mid-collective.
    fn check_reduce_len(part: &[f64], want: usize, src: usize) -> Result<()> {
        if part.len() != want {
            return Err(anyhow!(
                "reduce length mismatch: rank {src} sent {} elements, expected {want}",
                part.len()
            ));
        }
        Ok(())
    }

    fn reduce_into_linear(&mut self, root: usize, data: &mut Vec<f64>) -> Result<bool> {
        if self.rank() == root {
            for src in 0..self.size() {
                if src == root {
                    continue;
                }
                let part = self.recv(src, TAG_REDUCE)?;
                Self::check_reduce_len(&part, data.len(), src)?;
                for (a, b) in data.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            Ok(true)
        } else {
            self.send(root, TAG_REDUCE, data.as_slice())?;
            Ok(false)
        }
    }

    fn reduce_into_tree(&mut self, root: usize, data: &mut Vec<f64>) -> Result<bool> {
        let size = self.size();
        let vrank = (self.rank() + size - root) % size;
        let to_real = |v: usize| (v + root) % size;
        let mut mask = 1usize;
        while mask < size {
            if vrank & mask == 0 {
                let child = vrank + mask;
                if child < size {
                    let src = to_real(child);
                    let part = self.recv(src, TAG_REDUCE)?;
                    Self::check_reduce_len(&part, data.len(), src)?;
                    for (a, b) in data.iter_mut().zip(&part) {
                        *a += b;
                    }
                }
            } else {
                let parent = vrank - mask;
                self.send(to_real(parent), TAG_REDUCE, data.as_slice())?;
                return Ok(false);
            }
            mask <<= 1;
        }
        Ok(true)
    }

    // -----------------------------------------------------------------
    // composites
    // -----------------------------------------------------------------

    /// Reduce-to-root followed by broadcast (the classic two-phase
    /// allreduce; the paper's scheme reduces to one node anyway because
    /// the optimiser is centralised).
    pub fn allreduce_sum(&mut self, data: &[f64]) -> Result<Vec<f64>> {
        match self.reduce_sum(0, data)? {
            Some(total) => self.bcast(0, total),
            None => self.bcast(0, Vec::new()),
        }
    }

    /// Gather every rank's vector at `root` (indexed by rank). Payloads
    /// are heterogeneous, so this stays a point-to-point fan-in.
    pub fn gather(&mut self, root: usize, data: &[f64]) -> Result<Option<Vec<Vec<f64>>>> {
        if self.rank() == root {
            let mut out = vec![Vec::new(); self.size()];
            out[root] = data.to_vec();
            for src in 0..self.size() {
                if src != root {
                    out[src] = self.recv(src, TAG_GATHER)?;
                }
            }
            Ok(Some(out))
        } else {
            self.send(root, TAG_GATHER, data)?;
            Ok(None)
        }
    }

    /// Barrier: empty allreduce.
    pub fn barrier(&mut self) -> Result<()> {
        let _ = self.allreduce_sum(&[])?;
        Ok(())
    }
}

/// Cluster launcher: spawns `size` SPMD ranks and joins them.
pub struct Cluster;

impl Cluster {
    /// Run `f` on `size` ranks (each on its own OS thread; rank r gets a
    /// connected `Comm` with the default [`Topology::Tree`] collectives).
    /// Returns the per-rank results, indexed by rank. Panics in any rank
    /// propagate.
    pub fn run<T, F>(size: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Comm) -> T + Sync,
    {
        Cluster::run_with(size, Topology::default(), f)
    }

    /// `run` with an explicit collective topology.
    pub fn run_with<T, F>(size: usize, topology: Topology, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Comm) -> T + Sync,
    {
        Cluster::try_run_with(size, topology, f)
            .into_iter()
            // lint: allow(no-unwrap-protocol) — deliberate panic
            // propagation: `run_with` documents that a panicking rank
            // aborts the launcher; callers wanting containment use
            // `try_run_with`.
            .map(|r| r.expect("rank panicked"))
            .collect()
    }

    /// Like [`run_with`](Cluster::run_with), but a panicking rank does
    /// not abort the launcher: each rank's result comes back as a
    /// [`std::thread::Result`] (the `Err` holds the panic payload).
    /// Surviving ranks are *not* hung by the panic — the dying rank's
    /// transport notifies them on unwind, so their blocking receives
    /// error out and they run to completion.
    pub fn try_run_with<T, F>(size: usize, topology: Topology, f: F) -> Vec<std::thread::Result<T>>
    where
        T: Send,
        F: Fn(Comm) -> T + Sync,
    {
        let transports = InMemoryTransport::mesh(size)
            .into_iter()
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .collect();
        Cluster::try_run_on(transports, topology, &f)
    }

    /// The fully general launcher: one caller-supplied transport per
    /// rank (index = rank). This is how the chaos harness slots a
    /// [`FaultyTransport`](super::transport::FaultyTransport) under a
    /// single victim rank while the rest of the mesh runs clean.
    pub fn try_run_on<T>(
        transports: Vec<Box<dyn Transport>>,
        topology: Topology,
        f: &(dyn Fn(Comm) -> T + Sync),
    ) -> Vec<std::thread::Result<T>>
    where
        T: Send,
    {
        assert!(!transports.is_empty());
        let comms: Vec<Comm> =
            transports.into_iter().map(|t| Comm::new(t, topology)).collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| scope.spawn(move || f(comm)))
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn allreduce_equals_serial_sum() {
        for size in [1, 2, 3, 5, 8] {
            let results = Cluster::run(size, |mut comm| {
                let local: Vec<f64> = (0..4).map(|i| (comm.rank() * 10 + i) as f64).collect();
                comm.allreduce_sum(&local).unwrap()
            });
            let expect: Vec<f64> = (0..4)
                .map(|i| (0..size).map(|r| (r * 10 + i) as f64).sum())
                .collect();
            for r in &results {
                assert_eq!(*r, expect, "size {size}");
            }
        }
    }

    #[test]
    fn bcast_delivers_root_value() {
        for topology in [Topology::Linear, Topology::Tree] {
            let results = Cluster::run_with(4, topology, |mut comm| {
                let data = if comm.is_root() { vec![3.5, -1.0] } else { vec![] };
                comm.bcast(0, data).unwrap()
            });
            for r in results {
                assert_eq!(r, vec![3.5, -1.0], "{topology:?}");
            }
        }
    }

    #[test]
    fn gather_indexes_by_rank() {
        let results = Cluster::run(3, |mut comm| {
            comm.gather(0, &[comm.rank() as f64 * 2.0]).unwrap()
        });
        let at_root = results[0].as_ref().unwrap();
        assert_eq!(at_root.len(), 3);
        for (r, v) in at_root.iter().enumerate() {
            assert_eq!(v[0], r as f64 * 2.0);
        }
        assert!(results[1].is_none() && results[2].is_none());
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        // rank 1 sends tag B then tag A; rank 0 receives A then B.
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                comm.send(0, 7, &[7.0]).unwrap();
                comm.send(0, 5, &[5.0]).unwrap();
                vec![]
            } else {
                let a = comm.recv(1, 5).unwrap();
                let b = comm.recv(1, 7).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[0], vec![5.0, 7.0]);
    }

    #[test]
    fn parked_queue_preserves_fifo_order_per_tag() {
        // Three messages on one (src, tag) arrive while rank 0 waits on a
        // different tag; they must drain in send order afterwards.
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                for v in [1.0, 2.0, 3.0] {
                    comm.send(0, 9, &[v]).unwrap();
                }
                comm.send(0, 4, &[0.0]).unwrap();
                vec![]
            } else {
                let _ = comm.recv(1, 4).unwrap(); // parks all three tag-9 messages
                (0..3).map(|_| comm.recv(1, 9).unwrap()[0]).collect()
            }
        });
        assert_eq!(results[0], vec![1.0, 2.0, 3.0]);
    }

    /// `drain_pending` is a progress primitive only: it moves messages
    /// into the parked map without sending anything, and later `recv`s
    /// see exactly the per-(src, tag) FIFO order they would have seen
    /// without the drain — including messages that arrive *after* it.
    #[test]
    fn drain_pending_preserves_recv_order_and_sends_nothing() {
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 0 {
                // first wave: exactly three messages are in flight.
                // `drain_blocking` parks the thread on the transport
                // channel until each arrives — no yield_now spin.
                let mut drained = 0;
                while drained < 3 {
                    drained += comm.drain_blocking().unwrap();
                }
                let before = comm.messages_sent();
                assert_eq!(comm.drain_pending(), 0, "nothing else is in flight");
                assert_eq!(comm.messages_sent(), before, "drain must not send");
                // parked messages drain through recv in send order
                let mut got = vec![comm.recv(1, 9).unwrap()[0], comm.recv(1, 9).unwrap()[0]];
                // second wave (ack-gated, so it arrives after the drain)
                // interleaves with the remaining parked message correctly
                comm.send(1, 8, &[0.0]).unwrap();
                got.push(comm.recv(1, 9).unwrap()[0]);
                got.push(comm.recv(1, 9).unwrap()[0]);
                got
            } else {
                for v in [1.0, 2.0, 3.0] {
                    comm.send(0, 9, &[v]).unwrap();
                }
                let _ = comm.recv(0, 8).unwrap(); // wait until the drain happened
                comm.send(0, 9, &[4.0]).unwrap();
                vec![]
            }
        });
        assert_eq!(results[0], vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn byte_counter_counts_payloads() {
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                comm.send(0, 1, &[0.0; 100]).unwrap();
            } else {
                let _ = comm.recv(1, 1).unwrap();
            }
            comm.barrier().unwrap();
            comm.bytes_sent()
        });
        // 100 f64 payload = 800 bytes, plus barrier traffic (empty).
        assert!(results[0] >= 800, "bytes {}", results[0]);
    }

    #[test]
    fn prop_reduce_matches_serial_for_random_sizes() {
        Prop::new("reduce_random").cases(10).run(|rng| {
            let size = 1 + (rng.next_u64() % 6) as usize;
            let len = (rng.next_u64() % 20) as usize;
            let datasets: Vec<Vec<f64>> = (0..size)
                .map(|r| {
                    let mut rr = crate::data::rng::Rng64::new(r as u64 + 99);
                    rr.normal_vec(len)
                })
                .collect();
            let expect: Vec<f64> = (0..len)
                .map(|i| datasets.iter().map(|d| d[i]).sum())
                .collect();
            let ds = &datasets;
            let results = Cluster::run(size, |mut comm| {
                comm.allreduce_sum(&ds[comm.rank()]).unwrap()
            });
            for r in results {
                for (a, b) in r.iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-12);
                }
            }
        });
    }

    /// Tree reduce must agree with the linear reference for every cluster
    /// size 1–9 (covering perfect trees, one-past-a-power, and odd sizes)
    /// and for every root.
    #[test]
    fn prop_tree_reduce_matches_linear() {
        Prop::new("tree_vs_linear_reduce").cases(6).run(|rng| {
            let len = 1 + (rng.next_u64() % 16) as usize;
            for size in 1..=9usize {
                let root = (rng.next_u64() % size as u64) as usize;
                let datasets: Vec<Vec<f64>> = (0..size)
                    .map(|r| {
                        let mut rr = crate::data::rng::Rng64::new(r as u64 * 7 + 1);
                        rr.normal_vec(len)
                    })
                    .collect();
                let ds = &datasets;
                let run = |topology| {
                    Cluster::run_with(size, topology, move |mut comm| {
                        comm.reduce_sum(root, &ds[comm.rank()]).unwrap()
                    })
                };
                let lin = run(Topology::Linear);
                let tree = run(Topology::Tree);
                for r in 0..size {
                    match (&lin[r], &tree[r]) {
                        (Some(a), Some(b)) => {
                            assert_eq!(r, root);
                            for (x, y) in a.iter().zip(b) {
                                assert!((x - y).abs() < 1e-12 * (1.0 + x.abs()),
                                        "size {size} root {root}: {x} vs {y}");
                            }
                        }
                        (None, None) => assert_ne!(r, root),
                        _ => panic!("size {size}: topologies disagree on root-ness"),
                    }
                }
            }
        });
    }

    /// Tree bcast must deliver the root's exact payload on every rank for
    /// sizes 1–9 and every root.
    #[test]
    fn prop_tree_bcast_matches_linear() {
        Prop::new("tree_vs_linear_bcast").cases(6).run(|rng| {
            let payload = rng.normal_vec(1 + (rng.next_u64() % 12) as usize);
            for size in 1..=9usize {
                let root = (rng.next_u64() % size as u64) as usize;
                let pl = &payload;
                let run = |topology| {
                    Cluster::run_with(size, topology, move |mut comm| {
                        let data = if comm.rank() == root { pl.clone() } else { Vec::new() };
                        comm.bcast(root, data).unwrap()
                    })
                };
                for (a, b) in run(Topology::Linear).iter().zip(&run(Topology::Tree)) {
                    assert_eq!(a, b, "size {size} root {root}");
                    assert_eq!(a, pl, "size {size} root {root}");
                }
            }
        });
    }

    /// Pipelined collectives (several in flight back to back, mixed with
    /// point-to-point traffic) stay in lockstep under the tree topology.
    #[test]
    fn tree_collectives_pipeline_safely() {
        let results = Cluster::run_with(5, Topology::Tree, |mut comm| {
            let mut acc = 0.0;
            for round in 0..4 {
                let x = comm.bcast(0, vec![round as f64]).unwrap();
                let total = comm.allreduce_sum(&[x[0] + comm.rank() as f64]).unwrap();
                acc += total[0];
            }
            acc
        });
        // round r: sum over ranks of (r + rank) = 5r + 10
        let expect: f64 = (0..4).map(|r| 5.0 * r as f64 + 10.0).sum();
        for r in results {
            assert!((r - expect).abs() < 1e-12, "{r} vs {expect}");
        }
    }

    /// `reduce_sum_into` must match `reduce_sum` bit-for-bit on the root
    /// for both topologies and every cluster size 1–9, and leave the
    /// buffer reusable (no reallocation needed across rounds).
    #[test]
    fn prop_reduce_into_matches_reduce() {
        Prop::new("reduce_into_vs_reduce").cases(6).run(|rng| {
            let len = 1 + (rng.next_u64() % 16) as usize;
            for topology in [Topology::Linear, Topology::Tree] {
                for size in 1..=9usize {
                    let datasets: Vec<Vec<f64>> = (0..size)
                        .map(|r| {
                            let mut rr = crate::data::rng::Rng64::new(r as u64 * 13 + 5);
                            rr.normal_vec(len)
                        })
                        .collect();
                    let ds = &datasets;
                    let alloc = Cluster::run_with(size, topology, move |mut comm| {
                        comm.reduce_sum(0, &ds[comm.rank()]).unwrap()
                    });
                    let inplace = Cluster::run_with(size, topology, move |mut comm| {
                        // two rounds through one buffer: reuse must not
                        // leak the previous round's partials
                        let mut buf = ds[comm.rank()].clone();
                        let first_root = comm.reduce_sum_into(0, &mut buf).unwrap();
                        buf.clear();
                        buf.extend_from_slice(&ds[comm.rank()]);
                        let root = comm.reduce_sum_into(0, &mut buf).unwrap();
                        assert_eq!(first_root, root);
                        root.then_some(buf)
                    });
                    for (a, b) in alloc.iter().zip(&inplace) {
                        match (a, b) {
                            (Some(x), Some(y)) => assert_eq!(x, y,
                                "{topology:?} size {size}: totals differ"),
                            (None, None) => {}
                            _ => panic!("{topology:?} size {size}: root-ness differs"),
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn barrier_synchronises() {
        for topology in [Topology::Linear, Topology::Tree] {
            // No deadlock across repeated barriers with mixed skew. The
            // skew is a Condvar turnstile — each round the ranks reach
            // the barrier strictly in rank order, parked (not sleeping)
            // until their turn — so the stagger is deterministic instead
            // of a wall-clock `sleep` race.
            let gate = (std::sync::Mutex::new(0usize), std::sync::Condvar::new());
            let gate = &gate;
            let results = Cluster::run_with(4, topology, move |mut comm| {
                for i in 0..5usize {
                    let (lock, cv) = gate;
                    let mut turn = lock.lock().unwrap();
                    while *turn != i * 4 + comm.rank() {
                        turn = cv.wait(turn).unwrap();
                    }
                    *turn += 1;
                    cv.notify_all();
                    drop(turn);
                    comm.barrier().unwrap();
                }
                true
            });
            assert!(results.into_iter().all(|r| r));
        }
    }

    /// Regression (dead-peer propagation): a rank that panics mid-run
    /// must not hang peers blocked in `recv` on it — its transport
    /// notifies them on unwind and their receives error out.
    #[test]
    fn panicked_rank_unblocks_surviving_receivers() {
        for topology in [Topology::Linear, Topology::Tree] {
            let results = Cluster::try_run_with(3, topology, |mut comm| {
                if comm.rank() == 1 {
                    panic!("injected rank failure");
                }
                // Both survivors block on the doomed rank.
                comm.recv(1, 42)
            });
            assert!(results[1].is_err(), "rank 1 must report its panic");
            for r in [0, 2] {
                let out = results[r].as_ref().expect("survivor must not panic");
                assert!(out.is_err(), "rank {r} recv must error, not hang");
            }
        }
    }

    /// Sends to a rank that already exited error instead of panicking,
    /// and a recv whose peer died before sending errors immediately.
    #[test]
    fn dead_peer_send_and_recv_both_error() {
        let results = Cluster::try_run_with(2, Topology::Tree, |mut comm| {
            if comm.rank() == 1 {
                return Ok(());
            }
            // Wait until rank 1 is certainly gone (its hangup marker
            // arrives), then both directions must fail cleanly.
            let r = comm.recv(1, 5);
            assert!(r.is_err(), "recv from dead peer must error");
            let s = comm.send(1, 5, &[1.0]);
            assert!(s.is_err(), "send to dead peer must error");
            // And collectives built on them surface the error too.
            assert!(comm.barrier().is_err());
            Ok(())
        });
        for r in results {
            let out: anyhow::Result<()> = r.expect("no rank panics");
            assert!(out.is_ok());
        }
    }

    /// A truncated reduction partial is a protocol error, not a panic:
    /// the root reports it and every rank terminates.
    #[test]
    fn short_reduce_partial_errors_at_root() {
        for topology in [Topology::Linear, Topology::Tree] {
            let results = Cluster::try_run_with(2, topology, move |mut comm| {
                if comm.rank() == 1 {
                    // Claims to reduce 2 elements but ships 1.
                    comm.send(0, TAG_REDUCE, &[1.0])?;
                    Ok(false)
                } else {
                    let mut buf = vec![1.0, 2.0];
                    comm.reduce_sum_into(0, &mut buf).map(|_| true)
                }
            });
            let root: &anyhow::Result<bool> = results[0].as_ref().expect("no panic");
            assert!(root.is_err(), "{topology:?}: root must reject the short partial");
            assert!(format!("{:#}", root.as_ref().unwrap_err()).contains("length mismatch"));
        }
    }
}
