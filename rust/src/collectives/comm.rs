//! Point-to-point and collective operations over in-process channels.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A tagged message between ranks.
struct Message {
    src: usize,
    tag: u64,
    data: Vec<f64>,
}

/// Per-rank communicator handle (the MPI_Comm analog).
pub struct Comm {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    /// Out-of-order messages parked until a matching recv.
    parked: HashMap<(usize, u64), Vec<Vec<f64>>>,
    bytes_sent: Arc<AtomicU64>,
    messages_sent: Arc<AtomicU64>,
}

impl Comm {
    pub fn rank(&self) -> usize { self.rank }
    pub fn size(&self) -> usize { self.size }
    pub fn is_root(&self) -> bool { self.rank == 0 }

    /// Total bytes this *cluster* has shipped (shared counter).
    pub fn bytes_sent(&self) -> u64 { self.bytes_sent.load(Ordering::Relaxed) }
    pub fn messages_sent(&self) -> u64 { self.messages_sent.load(Ordering::Relaxed) }

    /// Send `data` to `dst` with a tag (non-blocking; channels buffer).
    pub fn send(&self, dst: usize, tag: u64, data: &[f64]) {
        self.bytes_sent.fetch_add((data.len() * 8) as u64, Ordering::Relaxed);
        self.messages_sent.fetch_add(1, Ordering::Relaxed);
        self.senders[dst]
            .send(Message { src: self.rank, tag, data: data.to_vec() })
            .expect("peer hung up");
    }

    /// Blocking receive of the next message from `src` with `tag`
    /// (out-of-order arrivals are parked, preserving per-(src,tag) order).
    pub fn recv(&mut self, src: usize, tag: u64) -> Vec<f64> {
        if let Some(q) = self.parked.get_mut(&(src, tag)) {
            if !q.is_empty() {
                return q.remove(0);
            }
        }
        loop {
            let msg = self.inbox.recv().expect("cluster torn down mid-recv");
            if msg.src == src && msg.tag == tag {
                return msg.data;
            }
            self.parked.entry((msg.src, msg.tag)).or_default().push(msg.data);
        }
    }

    /// Broadcast from `root`: returns the root's `data` on every rank.
    pub fn bcast(&mut self, root: usize, data: Vec<f64>) -> Vec<f64> {
        const TAG: u64 = u64::MAX - 1;
        if self.rank == root {
            for dst in 0..self.size {
                if dst != root {
                    self.send(dst, TAG, &data);
                }
            }
            data
        } else {
            self.recv(root, TAG)
        }
    }

    /// Element-wise sum-reduction to `root`; `Some(total)` on root,
    /// `None` elsewhere.
    pub fn reduce_sum(&mut self, root: usize, data: &[f64]) -> Option<Vec<f64>> {
        const TAG: u64 = u64::MAX - 2;
        if self.rank == root {
            let mut acc = data.to_vec();
            for src in 0..self.size {
                if src == root {
                    continue;
                }
                let part = self.recv(src, TAG);
                assert_eq!(part.len(), acc.len(), "reduce length mismatch");
                for (a, b) in acc.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            Some(acc)
        } else {
            self.send(root, TAG, data);
            None
        }
    }

    /// Reduce-to-root followed by broadcast (the classic two-phase
    /// allreduce; the paper's scheme reduces to one node anyway because
    /// the optimiser is centralised).
    pub fn allreduce_sum(&mut self, data: &[f64]) -> Vec<f64> {
        match self.reduce_sum(0, data) {
            Some(total) => self.bcast(0, total),
            None => self.bcast(0, Vec::new()),
        }
    }

    /// Gather every rank's vector at `root` (indexed by rank).
    pub fn gather(&mut self, root: usize, data: &[f64]) -> Option<Vec<Vec<f64>>> {
        const TAG: u64 = u64::MAX - 3;
        if self.rank == root {
            let mut out = vec![Vec::new(); self.size];
            out[root] = data.to_vec();
            for src in 0..self.size {
                if src != root {
                    out[src] = self.recv(src, TAG);
                }
            }
            Some(out)
        } else {
            self.send(root, TAG, data);
            None
        }
    }

    /// Barrier: empty allreduce.
    pub fn barrier(&mut self) {
        let _ = self.allreduce_sum(&[]);
    }
}

/// Cluster launcher: spawns `size` SPMD ranks and joins them.
pub struct Cluster;

impl Cluster {
    /// Run `f` on `size` ranks (each on its own OS thread; rank r gets a
    /// connected `Comm`). Returns the per-rank results, indexed by rank.
    /// Panics in any rank propagate.
    pub fn run<T, F>(size: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Comm) -> T + Sync,
    {
        assert!(size >= 1);
        let bytes = Arc::new(AtomicU64::new(0));
        let msgs = Arc::new(AtomicU64::new(0));

        // Full mesh: one (sender-set, receiver) pair per rank.
        let mut senders_per_rank: Vec<Sender<Message>> = Vec::with_capacity(size);
        let mut inboxes: Vec<Option<Receiver<Message>>> = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            senders_per_rank.push(tx);
            inboxes.push(Some(rx));
        }

        let comms: Vec<Comm> = (0..size)
            .map(|rank| Comm {
                rank,
                size,
                senders: senders_per_rank.clone(),
                inbox: inboxes[rank].take().unwrap(),
                parked: HashMap::new(),
                bytes_sent: bytes.clone(),
                messages_sent: msgs.clone(),
            })
            .collect();
        drop(senders_per_rank);

        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| scope.spawn(move || f(comm)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank panicked"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn allreduce_equals_serial_sum() {
        for size in [1, 2, 3, 5, 8] {
            let results = Cluster::run(size, |mut comm| {
                let local: Vec<f64> = (0..4).map(|i| (comm.rank() * 10 + i) as f64).collect();
                comm.allreduce_sum(&local)
            });
            let expect: Vec<f64> = (0..4)
                .map(|i| (0..size).map(|r| (r * 10 + i) as f64).sum())
                .collect();
            for r in &results {
                assert_eq!(*r, expect, "size {size}");
            }
        }
    }

    #[test]
    fn bcast_delivers_root_value() {
        let results = Cluster::run(4, |mut comm| {
            let data = if comm.is_root() { vec![3.5, -1.0] } else { vec![] };
            comm.bcast(0, data)
        });
        for r in results {
            assert_eq!(r, vec![3.5, -1.0]);
        }
    }

    #[test]
    fn gather_indexes_by_rank() {
        let results = Cluster::run(3, |mut comm| {
            comm.gather(0, &[comm.rank() as f64 * 2.0])
        });
        let at_root = results[0].as_ref().unwrap();
        assert_eq!(at_root.len(), 3);
        for (r, v) in at_root.iter().enumerate() {
            assert_eq!(v[0], r as f64 * 2.0);
        }
        assert!(results[1].is_none() && results[2].is_none());
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        // rank 1 sends tag B then tag A; rank 0 receives A then B.
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                comm.send(0, 7, &[7.0]);
                comm.send(0, 5, &[5.0]);
                vec![]
            } else {
                let a = comm.recv(1, 5);
                let b = comm.recv(1, 7);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[0], vec![5.0, 7.0]);
    }

    #[test]
    fn byte_counter_counts_payloads() {
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 1 {
                comm.send(0, 1, &[0.0; 100]);
            } else {
                let _ = comm.recv(1, 1);
            }
            comm.barrier();
            comm.bytes_sent()
        });
        // 100 f64 payload = 800 bytes, plus barrier traffic (empty).
        assert!(results[0] >= 800, "bytes {}", results[0]);
    }

    #[test]
    fn prop_reduce_matches_serial_for_random_sizes() {
        Prop::new("reduce_random").cases(10).run(|rng| {
            let size = 1 + (rng.next_u64() % 6) as usize;
            let len = (rng.next_u64() % 20) as usize;
            let datasets: Vec<Vec<f64>> = (0..size)
                .map(|r| {
                    let mut rr = crate::data::rng::Rng64::new(r as u64 + 99);
                    rr.normal_vec(len)
                })
                .collect();
            let expect: Vec<f64> = (0..len)
                .map(|i| datasets.iter().map(|d| d[i]).sum())
                .collect();
            let ds = &datasets;
            let results = Cluster::run(size, |mut comm| {
                comm.allreduce_sum(&ds[comm.rank()])
            });
            for r in results {
                for (a, b) in r.iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-12);
                }
            }
        });
    }

    #[test]
    fn barrier_synchronises() {
        // No deadlock across repeated barriers with mixed work.
        let results = Cluster::run(4, |mut comm| {
            for i in 0..5 {
                if comm.rank() % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(i));
                }
                comm.barrier();
            }
            true
        });
        assert!(results.into_iter().all(|r| r));
    }
}
