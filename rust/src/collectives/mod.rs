//! Simulated MPI: SPMD message passing over OS threads + channels.
//!
//! The paper distributes datapoints across MPI ranks; this module gives
//! the coordinator the same collective primitives (`bcast`, `reduce_sum`,
//! `allreduce_sum`, `gather`, `barrier`) with the same semantics, with the
//! wire swapped from a network to in-process channels. Per-rank byte
//! counters report exactly the traffic an MPI run would ship, so the
//! "communication overhead is negligible" claim (paper §4) is measurable.
//!
//! The point-to-point layer is pluggable: [`Comm`] runs its collectives
//! over any [`Transport`] ([`InMemoryTransport`] in production today; a
//! socket transport is the planned next implementation), and the
//! [`FaultyTransport`] decorator deterministically injects wire faults
//! for the chaos harness (`testutil::chaos`). Every operation returns a
//! `Result`: a dead peer surfaces as an error, never a hang or a panic.
//!
//! `bcast`/`reduce_sum` run over a binomial tree by default (O(log P)
//! critical path); the linear reference algorithms are retained and
//! selectable per-communicator via [`Topology`].
//!
//! Usage is SPMD, like MPI:
//! ```no_run
//! use gpparallel::collectives::Cluster;
//! let results = Cluster::run(4, |mut comm| {
//!     let local = vec![comm.rank() as f64];
//!     comm.allreduce_sum(&local).unwrap()[0] // == 0+1+2+3 on every rank
//! });
//! assert!(results.iter().all(|&r| r == 6.0));
//! ```

mod comm;
pub mod protocol;
pub mod transport;

pub use comm::{Cluster, Comm, Topology};
pub use transport::{
    Delivery, FaultKind, FaultPlan, FaultyTransport, InMemoryTransport, Transport,
    TransportError,
};
