//! Simulated MPI: SPMD message passing over OS threads + channels.
//!
//! The paper distributes datapoints across MPI ranks; this module gives
//! the coordinator the same collective primitives (`bcast`, `reduce_sum`,
//! `allreduce_sum`, `gather`, `barrier`) with the same semantics, with the
//! transport swapped from a network to in-process channels. Per-rank byte
//! counters report exactly the traffic an MPI run would ship, so the
//! "communication overhead is negligible" claim (paper §4) is measurable.
//!
//! `bcast`/`reduce_sum` run over a binomial tree by default (O(log P)
//! critical path); the linear reference algorithms are retained and
//! selectable per-communicator via [`Topology`].
//!
//! Usage is SPMD, like MPI:
//! ```no_run
//! use gpparallel::collectives::Cluster;
//! let results = Cluster::run(4, |mut comm| {
//!     let local = vec![comm.rank() as f64];
//!     comm.allreduce_sum(&local)[0] // == 0+1+2+3 on every rank
//! });
//! assert!(results.iter().all(|&r| r == 6.0));
//! ```

mod comm;

pub use comm::{Cluster, Comm, Topology};
