//! The wire-protocol registry: every point-to-point tag and broadcast
//! verb spoken anywhere in the cluster, declared exactly once.
//!
//! Why a registry
//! --------------
//! The protocols layered on [`Comm`](super::Comm) — the training
//! cycle's command loop, the STATS round, the streamed serve session —
//! multiplex one transport by `(src, tag)`. A tag collision between
//! two protocols silently cross-wires their streams: the receiver
//! parks a message from the wrong conversation and both sides block or
//! mis-parse. The failure is a deadlock or a junk matrix, never a type
//! error, so the defence has to be organisational: **every tag and
//! verb lives here**, `gpp-lint`'s `wire-registry` rule rejects raw
//! numeric tags at `send`/`recv` call sites, and the uniqueness test
//! at the bottom of this module rejects collisions at `cargo test`
//! time.
//!
//! Layout of the space
//! -------------------
//! * Protocol tags are small numbers (`100`, `300`, …), grouped by
//!   subsystem with room between groups.
//! * Collective-internal tags ([`TAG_BCAST`], [`TAG_REDUCE`],
//!   [`TAG_GATHER`]) sit at the very top of the `u64` range so user
//!   protocols can never collide with them by growing upward.
//! * [`TAG_HANGUP`] is `u64::MAX` — it never crosses the wire as a
//!   message tag; the transport layer uses it as the sentinel for a
//!   peer's hangup marker.
//!
//! Verbs (`CMD_*`, `SRV_*`) are `f64` because command headers ride the
//! same `Vec<f64>` wire as payload data; each verb family must be
//! internally collision-free (also asserted below).

// ---------------------------------------------------------------------
// Point-to-point tags (u64)
// ---------------------------------------------------------------------

/// Training cycle: workers upload their per-span local statistics and
/// gradients to rank 0 under this tag (`gather_locals` / the pipelined
/// evaluator).
pub const TAG_LOCALS: u64 = 100;

/// Serve session: rank 0 ships each worker its shard of the query
/// block `X*` under this tag, one message per worker per batch.
pub const TAG_XSTAR: u64 = 300;

/// Micro-benchmark ping-pong tag (`benches/micro.rs`). Registered so
/// even throwaway harness traffic cannot collide with a protocol
/// stream when benches and protocols share a cluster.
pub const TAG_BENCH_PINGPONG: u64 = 700;

/// Collective-internal: broadcast hops of the binomial tree.
pub const TAG_BCAST: u64 = u64::MAX - 1;

/// Collective-internal: reduction partials (tree and linear).
pub const TAG_REDUCE: u64 = u64::MAX - 2;

/// Collective-internal: gather payloads sent to the root.
pub const TAG_GATHER: u64 = u64::MAX - 3;

/// Transport-internal sentinel: the tag value reserved for hangup
/// markers propagated when a peer's transport drops. Never sent as a
/// message tag by any protocol; reserved here so nothing else can
/// claim it.
pub const TAG_HANGUP: u64 = u64::MAX;

// ---------------------------------------------------------------------
// Training-cycle command verbs (f64, slot 0 of a command broadcast)
// ---------------------------------------------------------------------

/// Cluster command: tear the worker loop down cleanly.
pub const CMD_STOP: f64 = 0.0;

/// Cluster command: run one distributed bound + gradient evaluation.
pub const CMD_EVAL: f64 = 1.0;

/// Cluster command: enter a sharded serving session.
pub const CMD_SERVE: f64 = 2.0;

/// Cluster command: run one distributed statistics pass.
pub const CMD_STATS: f64 = 3.0;

// ---------------------------------------------------------------------
// Serve-session verbs (f64, slot 0 of a serve sub-command broadcast)
// ---------------------------------------------------------------------

/// Serve sub-command: close the serving session.
pub const SRV_DONE: f64 = 0.0;

/// Serve sub-command: predict one batch (header carries row count and
/// stream flag).
pub const SRV_PREDICT: f64 = 1.0;

/// Serve sub-command: hot-swap the posterior core on every rank.
pub const SRV_SWAP: f64 = 2.0;

/// Serve sub-command: refit hyperparameters mid-session.
pub const SRV_REFIT: f64 = 3.0;

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_unique(group: &str, vals: &[(&str, u64)]) {
        for (i, (na, va)) in vals.iter().enumerate() {
            for (nb, vb) in &vals[i + 1..] {
                assert_ne!(va, vb, "{group}: {na} and {nb} collide on {va}");
            }
        }
    }

    #[test]
    fn tags_are_unique() {
        assert_unique(
            "tags",
            &[
                ("TAG_LOCALS", TAG_LOCALS),
                ("TAG_XSTAR", TAG_XSTAR),
                ("TAG_BENCH_PINGPONG", TAG_BENCH_PINGPONG),
                ("TAG_BCAST", TAG_BCAST),
                ("TAG_REDUCE", TAG_REDUCE),
                ("TAG_GATHER", TAG_GATHER),
                ("TAG_HANGUP", TAG_HANGUP),
            ],
        );
    }

    #[test]
    fn protocol_tags_stay_below_the_collective_range() {
        // User protocols grow upward from small numbers; the
        // collective/transport sentinels own the top of the range.
        for t in [TAG_LOCALS, TAG_XSTAR, TAG_BENCH_PINGPONG] {
            assert!(t < TAG_GATHER, "protocol tag {t} invades the reserved top range");
        }
    }

    #[test]
    fn verb_families_are_unique() {
        let cmds = [
            ("CMD_STOP", CMD_STOP),
            ("CMD_EVAL", CMD_EVAL),
            ("CMD_SERVE", CMD_SERVE),
            ("CMD_STATS", CMD_STATS),
        ];
        let srvs = [
            ("SRV_DONE", SRV_DONE),
            ("SRV_PREDICT", SRV_PREDICT),
            ("SRV_SWAP", SRV_SWAP),
            ("SRV_REFIT", SRV_REFIT),
        ];
        for fam in [&cmds, &srvs] {
            for (i, (na, va)) in fam.iter().enumerate() {
                for (nb, vb) in &fam[i + 1..] {
                    assert_ne!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{na} and {nb} collide on {va}"
                    );
                }
            }
        }
    }

    #[test]
    fn verbs_survive_the_f64_wire_exactly() {
        // Verbs are compared with == after a broadcast; they must be
        // exactly representable and round-trip through to_bits.
        for v in [
            CMD_STOP, CMD_EVAL, CMD_SERVE, CMD_STATS, SRV_DONE, SRV_PREDICT,
            SRV_SWAP, SRV_REFIT,
        ] {
            assert_eq!(v, v.trunc(), "verb {v} is not an integer-valued f64");
            assert_eq!(f64::from_bits(v.to_bits()), v);
        }
    }
}
