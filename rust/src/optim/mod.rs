//! Optimisation substrate: the central optimiser of the paper's scheme
//! (it collects gathered gradients at the leader, steps the packed
//! parameter vector, and the coordinator broadcasts the result).
//!
//! - `lbfgs` — L-BFGS with strong-Wolfe line search (the scipy
//!   `L-BFGS-B` stand-in the paper uses; bounds are handled upstream by
//!   `transforms`, which is also how GPy avoids the "-B").
//! - `scg`   — scaled conjugate gradients (GPy's historical default).
//! - `adam`  — first-order baseline for the ablation benches.
//! - `transforms` — positivity transforms so all parameters live in an
//!   unconstrained vector.
//!
//! All optimisers *maximise nothing*: they minimise. The models hand them
//! the negative bound.

pub mod adam;
pub mod lbfgs;
pub mod scg;
pub mod transforms;

pub use adam::Adam;
pub use lbfgs::Lbfgs;
pub use scg::Scg;
pub use transforms::Transform;

/// Objective: x -> (f(x), ∇f(x)). Mutable because evaluation drives the
/// whole distributed machine (workers, reductions, …).
///
/// A **NaN objective value is the abort sentinel**: it means the
/// objective can no longer be evaluated at all (e.g. the distributed
/// evaluator is poisoned after a hard rank failure), not merely that the
/// current point is bad. Every optimiser stops immediately with
/// [`StopReason::Aborted`] when it sees one, so a dead evaluator is not
/// asked for further doomed cluster rounds.
pub type Objective<'a> = dyn FnMut(&[f64]) -> (f64, Vec<f64>) + 'a;

/// Why an optimisation run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Gradient norm fell below the tolerance.
    GradTol,
    /// Relative objective improvement fell below the tolerance.
    FtolReached,
    /// Iteration budget exhausted.
    MaxIters,
    /// Line search could not find an acceptable step.
    LineSearchFailed,
    /// The objective signalled a hard failure (NaN sentinel) — e.g. the
    /// distributed evaluator errored and cannot be driven further.
    Aborted,
}

/// Result of an optimisation run.
#[derive(Clone, Debug)]
pub struct OptResult {
    /// Final parameter vector.
    pub x: Vec<f64>,
    /// Final objective value.
    pub f: f64,
    /// Accepted iterations.
    pub iterations: usize,
    /// Objective evaluations (including line-search probes).
    pub evaluations: usize,
    /// Why the run stopped.
    pub stop: StopReason,
    /// f after every accepted iteration (the loss curve).
    pub trace: Vec<f64>,
}

/// Common optimiser interface.
pub trait Optimizer {
    /// Minimise `obj` from `x0` until a stopping criterion fires.
    fn minimize(&self, obj: &mut Objective, x0: Vec<f64>) -> OptResult;
}

#[cfg(test)]
pub(crate) mod test_objectives {
    /// Rosenbrock function and gradient — the classic line-search torture
    /// test shared by the optimiser unit tests.
    pub fn rosenbrock(x: &[f64]) -> (f64, Vec<f64>) {
        let n = x.len();
        let mut f = 0.0;
        let mut g = vec![0.0; n];
        for i in 0..n - 1 {
            let a = x[i + 1] - x[i] * x[i];
            let b = 1.0 - x[i];
            f += 100.0 * a * a + b * b;
            g[i] += -400.0 * x[i] * a - 2.0 * b;
            g[i + 1] += 200.0 * a;
        }
        (f, g)
    }

    /// Convex quadratic with condition number ~100.
    pub fn quadratic(x: &[f64]) -> (f64, Vec<f64>) {
        let mut f = 0.0;
        let mut g = vec![0.0; x.len()];
        for (i, &xi) in x.iter().enumerate() {
            let c = 1.0 + (i as f64) * 9.9;
            f += 0.5 * c * xi * xi;
            g[i] = c * xi;
        }
        (f, g)
    }
}
