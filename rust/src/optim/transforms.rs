//! Parameter transforms: all model parameters are optimised as one
//! unconstrained vector; positives (variances, lengthscales, S, β) travel
//! through `Exp`. This is exactly how GPy sidesteps L-BFGS-**B**: the
//! bound constraint becomes a smooth reparameterisation.

/// A scalar reparameterisation between constrained model space and the
/// unconstrained optimiser space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transform {
    /// Identity: parameter is already unconstrained.
    Linear,
    /// Positive via `value = exp(raw)`.
    Exp,
}

impl Transform {
    /// Constrained value from unconstrained raw.
    #[inline]
    pub fn forward(self, raw: f64) -> f64 {
        match self {
            Transform::Linear => raw,
            Transform::Exp => raw.exp(),
        }
    }

    /// Unconstrained raw from constrained value.
    #[inline]
    pub fn inverse(self, value: f64) -> f64 {
        match self {
            Transform::Linear => value,
            Transform::Exp => {
                assert!(value > 0.0, "Exp transform needs positive value, got {value}");
                value.ln()
            }
        }
    }

    /// Chain rule factor: d value / d raw, given the *value*.
    #[inline]
    pub fn dvalue_draw(self, value: f64) -> f64 {
        match self {
            Transform::Linear => 1.0,
            Transform::Exp => value,
        }
    }
}

/// Converts a gradient w.r.t. constrained values into a gradient w.r.t.
/// the raw vector, in place.
pub fn chain_gradient(transforms: &[Transform], values: &[f64], grad: &mut [f64]) {
    assert_eq!(transforms.len(), values.len());
    assert_eq!(transforms.len(), grad.len());
    for i in 0..grad.len() {
        grad[i] *= transforms[i].dvalue_draw(values[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fd::{assert_grad_close, grad_fd};

    #[test]
    fn roundtrip() {
        for t in [Transform::Linear, Transform::Exp] {
            for v in [0.1, 1.0, 7.5] {
                assert!((t.forward(t.inverse(v)) - v).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn chain_rule_matches_fd() {
        // g(raw) = f(forward(raw)) with f = sum of squares.
        let transforms = [Transform::Exp, Transform::Linear, Transform::Exp];
        let raw = [0.3, -1.2, -0.5];
        let g = |r: &[f64]| {
            let v: Vec<f64> = r.iter().zip(&transforms).map(|(x, t)| t.forward(*x)).collect();
            v.iter().map(|x| x * x).sum::<f64>()
        };
        let values: Vec<f64> = raw.iter().zip(&transforms).map(|(x, t)| t.forward(*x)).collect();
        let mut grad: Vec<f64> = values.iter().map(|v| 2.0 * v).collect();
        chain_gradient(&transforms, &values, &mut grad);
        assert_grad_close(&grad, &grad_fd(g, &raw, 1e-7), 1e-6, 1e-9, "chain");
    }

    #[test]
    #[should_panic]
    fn exp_inverse_rejects_nonpositive() {
        Transform::Exp.inverse(-1.0);
    }
}
