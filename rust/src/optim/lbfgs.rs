//! L-BFGS (two-loop recursion) with a strong-Wolfe line search — the
//! in-repo stand-in for the scipy L-BFGS-B optimiser the paper calls.

use super::{Objective, OptResult, Optimizer, StopReason};
use crate::linalg::{norm2, vdot};

/// L-BFGS configuration.
#[derive(Clone, Debug)]
pub struct Lbfgs {
    /// History length (number of (s, y) pairs).
    pub history: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// Stop when the max-abs gradient entry falls below this.
    pub grad_tol: f64,
    /// Stop when the relative improvement falls below this.
    pub f_tol: f64,
    /// Wolfe sufficient-decrease constant c1.
    pub c1: f64,
    /// Wolfe curvature constant c2.
    pub c2: f64,
    /// Line-search probe budget per iteration.
    pub max_line_search: usize,
}

impl Default for Lbfgs {
    fn default() -> Self {
        Lbfgs {
            history: 10,
            max_iters: 200,
            grad_tol: 1e-5,
            f_tol: 1e-10,
            c1: 1e-4,
            c2: 0.9,
            max_line_search: 25,
        }
    }
}

/// Strong-Wolfe line search (Nocedal & Wright alg. 3.5/3.6, simplified
/// bracketing + bisection-with-interpolation zoom).
fn wolfe_line_search(
    obj: &mut Objective,
    x: &[f64],
    f0: f64,
    g0: &[f64],
    dir: &[f64],
    c1: f64,
    c2: f64,
    max_evals: usize,
    evals: &mut usize,
) -> Option<(f64, f64, Vec<f64>, Vec<f64>)> {
    let dg0 = vdot(g0, dir);
    if dg0 >= 0.0 {
        return None; // not a descent direction
    }
    let eval = |t: f64, obj: &mut Objective, evals: &mut usize| {
        let xt: Vec<f64> = x.iter().zip(dir).map(|(xi, di)| xi + t * di).collect();
        let (f, g) = obj(&xt);
        *evals += 1;
        (f, g, xt)
    };

    let mut t_prev = 0.0;
    let mut f_prev = f0;
    let mut t = 1.0;
    let mut bracket: Option<(f64, f64, f64, f64)> = None; // (lo, f_lo, hi, f_hi)
    let mut best = None;

    for i in 0..max_evals {
        let (f, g, xt) = eval(t, obj, evals);
        let dg = vdot(&g, dir);
        if f > f0 + c1 * t * dg0 || (i > 0 && f >= f_prev) {
            bracket = Some((t_prev, f_prev, t, f));
            break;
        }
        if dg.abs() <= -c2 * dg0 {
            return Some((t, f, g, xt)); // strong Wolfe satisfied
        }
        if dg >= 0.0 {
            bracket = Some((t, f, t_prev, f_prev));
            break;
        }
        best = Some((t, f, g, xt));
        t_prev = t;
        f_prev = f;
        t *= 2.0;
    }

    let (mut lo, mut f_lo, mut hi, mut _f_hi) = bracket?;
    // zoom
    for _ in 0..max_evals {
        let t_mid = 0.5 * (lo + hi);
        let (f, g, xt) = eval(t_mid, obj, evals);
        let dg = vdot(&g, dir);
        if f > f0 + c1 * t_mid * dg0 || f >= f_lo {
            hi = t_mid;
            _f_hi = f;
        } else {
            if dg.abs() <= -c2 * dg0 {
                return Some((t_mid, f, g, xt));
            }
            if dg * (hi - lo) >= 0.0 {
                hi = lo;
            }
            lo = t_mid;
            f_lo = f;
            best = Some((t_mid, f, g, xt));
        }
        if (hi - lo).abs() < 1e-14 {
            break;
        }
    }
    // Fall back to the best sufficient-decrease point seen, if any.
    best.filter(|(_, f, _, _)| *f < f0)
}

impl Optimizer for Lbfgs {
    fn minimize(&self, obj: &mut Objective, x0: Vec<f64>) -> OptResult {
        let n = x0.len();
        let mut x = x0;
        let (mut f, mut g) = obj(&x);
        let mut evals = 1;
        let mut trace = vec![f];
        if f.is_nan() {
            return OptResult { x, f, iterations: 0, evaluations: evals,
                               stop: StopReason::Aborted, trace };
        }

        let mut s_hist: Vec<Vec<f64>> = Vec::new();
        let mut y_hist: Vec<Vec<f64>> = Vec::new();
        let mut rho: Vec<f64> = Vec::new();

        let mut stop = StopReason::MaxIters;
        let mut iter = 0;
        while iter < self.max_iters {
            let ginf = g.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            if ginf < self.grad_tol {
                stop = StopReason::GradTol;
                break;
            }

            // two-loop recursion
            let mut dir: Vec<f64> = g.iter().map(|v| -v).collect();
            let k = s_hist.len();
            let mut alpha = vec![0.0; k];
            for i in (0..k).rev() {
                alpha[i] = rho[i] * vdot(&s_hist[i], &dir);
                for j in 0..n {
                    dir[j] -= alpha[i] * y_hist[i][j];
                }
            }
            if k > 0 {
                let last = k - 1;
                let gamma = vdot(&s_hist[last], &y_hist[last])
                    / vdot(&y_hist[last], &y_hist[last]).max(1e-300);
                for d in dir.iter_mut() {
                    *d *= gamma;
                }
            } else {
                // first step: scale to unit-ish step
                let gn = norm2(&g).max(1.0);
                for d in dir.iter_mut() {
                    *d /= gn;
                }
            }
            for i in 0..k {
                let beta = rho[i] * vdot(&y_hist[i], &dir);
                for j in 0..n {
                    dir[j] += (alpha[i] - beta) * s_hist[i][j];
                }
            }

            // The abort latch: a NaN value anywhere inside the line
            // search means the objective is gone for good (the sentinel
            // is sticky by contract), so the search outcome is unusable
            // and the run stops with `Aborted`.
            let aborted = std::cell::Cell::new(false);
            let searched = {
                let mut latched = |xv: &[f64]| {
                    let (fv, gv) = obj(xv);
                    if fv.is_nan() {
                        aborted.set(true);
                    }
                    (fv, gv)
                };
                wolfe_line_search(&mut latched, &x, f, &g, &dir, self.c1, self.c2,
                                  self.max_line_search, &mut evals)
            };
            if aborted.get() {
                stop = StopReason::Aborted;
                break;
            }
            match searched {
                Some((t, f_new, g_new, x_new)) => {
                    let s: Vec<f64> = dir.iter().map(|d| t * d).collect();
                    let y: Vec<f64> = g_new.iter().zip(&g).map(|(a, b)| a - b).collect();
                    let sy = vdot(&s, &y);
                    if sy > 1e-12 * norm2(&s) * norm2(&y) {
                        s_hist.push(s);
                        y_hist.push(y);
                        rho.push(1.0 / sy);
                        if s_hist.len() > self.history {
                            s_hist.remove(0);
                            y_hist.remove(0);
                            rho.remove(0);
                        }
                    }
                    let rel = (f - f_new).abs() / f.abs().max(f_new.abs()).max(1.0);
                    x = x_new;
                    g = g_new;
                    f = f_new;
                    trace.push(f);
                    iter += 1;
                    if rel < self.f_tol {
                        stop = StopReason::FtolReached;
                        break;
                    }
                }
                None => {
                    // Restart once from steepest descent; give up if the
                    // memory is already empty.
                    if s_hist.is_empty() {
                        stop = StopReason::LineSearchFailed;
                        break;
                    }
                    s_hist.clear();
                    y_hist.clear();
                    rho.clear();
                }
            }
        }

        OptResult { x, f, iterations: iter, evaluations: evals, stop, trace }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_objectives::{quadratic, rosenbrock};
    use super::*;

    #[test]
    fn solves_quadratic_fast() {
        let opt = Lbfgs::default();
        let r = opt.minimize(&mut |x: &[f64]| quadratic(x), vec![1.0; 10]);
        assert!(r.f < 1e-10, "f = {}", r.f);
        assert!(r.iterations < 60);
    }

    #[test]
    fn solves_rosenbrock_10d() {
        let opt = Lbfgs { max_iters: 600, ..Default::default() };
        let r = opt.minimize(&mut |x: &[f64]| rosenbrock(x), vec![-1.2, 1.0, -0.5, 0.8, 0.0, 0.3, -1.0, 1.5, 2.0, -0.2]);
        assert!(r.f < 1e-8, "f = {} after {} iters ({:?})", r.f, r.iterations, r.stop);
        for xi in &r.x {
            assert!((xi - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn trace_is_monotone_nonincreasing() {
        let opt = Lbfgs::default();
        let r = opt.minimize(&mut |x: &[f64]| rosenbrock(x), vec![-1.2, 1.0]);
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trace increased: {w:?}");
        }
    }

    /// A NaN objective (the abort sentinel) must stop the run with
    /// `Aborted` after a bounded number of further evaluations, both
    /// when it appears immediately and mid-run.
    #[test]
    fn nan_objective_aborts() {
        let r = Lbfgs::default()
            .minimize(&mut |x: &[f64]| (f64::NAN, vec![0.0; x.len()]), vec![1.0; 4]);
        assert_eq!(r.stop, StopReason::Aborted);
        assert_eq!(r.evaluations, 1);

        let mut calls = 0usize;
        let r = Lbfgs::default().minimize(&mut |x: &[f64]| {
            calls += 1;
            if calls > 3 {
                (f64::NAN, vec![0.0; x.len()])
            } else {
                quadratic(x)
            }
        }, vec![1.0; 4]);
        assert_eq!(r.stop, StopReason::Aborted);
        assert!(r.evaluations <= 5, "kept evaluating: {}", r.evaluations);
    }

    #[test]
    fn already_converged_exits_immediately() {
        let opt = Lbfgs::default();
        let r = opt.minimize(&mut |x: &[f64]| quadratic(x), vec![0.0; 4]);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.stop, StopReason::GradTol);
    }
}
