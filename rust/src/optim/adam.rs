//! Adam — first-order baseline used by the optimiser-ablation bench
//! (and handy when the bound is evaluated on minibatches, where L-BFGS's
//! line search is invalid).

use super::{Objective, OptResult, Optimizer, StopReason};

/// Adam configuration (Kingma & Ba 2015 defaults).
#[derive(Clone, Debug)]
pub struct Adam {
    /// Step size.
    pub lr: f64,
    /// First-moment decay rate.
    pub beta1: f64,
    /// Second-moment decay rate.
    pub beta2: f64,
    /// Denominator fuzz.
    pub eps: f64,
    /// Iteration budget.
    pub max_iters: usize,
    /// Stop when the max-abs gradient entry falls below this.
    pub grad_tol: f64,
}

impl Default for Adam {
    fn default() -> Self {
        Adam { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, max_iters: 1000, grad_tol: 1e-6 }
    }
}

impl Optimizer for Adam {
    fn minimize(&self, obj: &mut Objective, x0: Vec<f64>) -> OptResult {
        let n = x0.len();
        let mut x = x0;
        let mut x_prev = vec![0.0; n];
        let mut m = vec![0.0; n];
        let mut v = vec![0.0; n];
        let (mut f, mut g) = obj(&x);
        let mut evals = 1;
        let mut trace = vec![f];
        let mut stop = StopReason::MaxIters;
        let mut iter = 0;
        if f.is_nan() {
            return OptResult { x, f, iterations: 0, evaluations: evals,
                               stop: StopReason::Aborted, trace };
        }

        while iter < self.max_iters {
            let ginf = g.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            if ginf < self.grad_tol {
                stop = StopReason::GradTol;
                break;
            }
            iter += 1;
            x_prev.copy_from_slice(&x);
            let b1t = 1.0 - self.beta1.powi(iter as i32);
            let b2t = 1.0 - self.beta2.powi(iter as i32);
            for i in 0..n {
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
                let mh = m[i] / b1t;
                let vh = v[i] / b2t;
                x[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
            let (fi, gi) = obj(&x);
            evals += 1;
            if fi.is_nan() {
                // abort with the last vetted iterate (and its f), the
                // same contract L-BFGS and SCG keep on the sentinel
                x.copy_from_slice(&x_prev);
                stop = StopReason::Aborted;
                break;
            }
            f = fi;
            g = gi;
            trace.push(f);
        }
        OptResult { x, f, iterations: iter, evaluations: evals, stop, trace }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_objectives::quadratic;
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let r = Adam { lr: 0.2, max_iters: 3000, ..Default::default() }
            .minimize(&mut |x: &[f64]| quadratic(x), vec![1.0; 6]);
        assert!(r.f < 1e-6, "f = {}", r.f);
    }

    /// The NaN abort sentinel stops the run after one further step.
    #[test]
    fn nan_objective_aborts() {
        let mut calls = 0usize;
        let r = Adam::default().minimize(&mut |x: &[f64]| {
            calls += 1;
            if calls > 2 {
                (f64::NAN, vec![0.0; x.len()])
            } else {
                quadratic(x)
            }
        }, vec![1.0; 3]);
        assert_eq!(r.stop, StopReason::Aborted);
        assert!(r.evaluations <= 3, "kept evaluating: {}", r.evaluations);
    }

    #[test]
    fn respects_max_iters() {
        let r = Adam { max_iters: 7, ..Default::default() }
            .minimize(&mut |x: &[f64]| quadratic(x), vec![1.0; 3]);
        assert_eq!(r.iterations, 7);
        assert_eq!(r.stop, StopReason::MaxIters);
    }
}
