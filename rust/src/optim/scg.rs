//! Scaled Conjugate Gradients (Møller 1993) — GPy's historical default
//! optimiser, included so the examples can reproduce GPy-flavoured runs
//! and the benches can ablate the optimiser choice.

use super::{Objective, OptResult, Optimizer, StopReason};
use crate::linalg::{norm2, vdot};

/// SCG configuration (names follow Møller's paper / GPy's scg.py).
#[derive(Clone, Debug)]
pub struct Scg {
    /// Iteration budget.
    pub max_iters: usize,
    /// Stop when the max-abs gradient entry falls below this.
    pub grad_tol: f64,
    /// Stop when the relative improvement falls below this.
    pub f_tol: f64,
}

impl Default for Scg {
    fn default() -> Self {
        Scg { max_iters: 500, grad_tol: 1e-5, f_tol: 1e-10 }
    }
}

impl Optimizer for Scg {
    fn minimize(&self, obj: &mut Objective, x0: Vec<f64>) -> OptResult {
        let n = x0.len();
        let mut x = x0;
        let (mut f_now, mut grad) = obj(&x);
        let mut evals = 1;
        let mut trace = vec![f_now];
        if f_now.is_nan() {
            return OptResult { x, f: f_now, iterations: 0, evaluations: evals,
                               stop: StopReason::Aborted, trace };
        }

        let mut d: Vec<f64> = grad.iter().map(|g| -g).collect(); // search dir
        let mut lambda = 1e-6; // scale parameter
        let mut lambda_bar = 0.0;
        let mut success = true;
        let mut delta = 0.0;
        let mut mu = 0.0;
        let mut kappa = 0.0;

        let mut stop = StopReason::MaxIters;
        let mut iter = 0;
        let mut n_success = 0;

        while iter < self.max_iters {
            if success {
                mu = vdot(&d, &grad);
                if mu >= 0.0 {
                    d = grad.iter().map(|g| -g).collect();
                    mu = vdot(&d, &grad);
                }
                kappa = vdot(&d, &d);
                if kappa < 1e-300 {
                    stop = StopReason::GradTol;
                    break;
                }
                // second-order information via finite difference along d
                let sigma = 1e-8 / kappa.sqrt();
                let x_plus: Vec<f64> = x.iter().zip(&d).map(|(xi, di)| xi + sigma * di).collect();
                let (f_plus, g_plus) = obj(&x_plus);
                evals += 1;
                if f_plus.is_nan() {
                    stop = StopReason::Aborted;
                    break;
                }
                delta = g_plus
                    .iter()
                    .zip(&grad)
                    .zip(&d)
                    .map(|((gp, g), di)| (gp - g) * di)
                    .sum::<f64>()
                    / sigma;
            }

            // scale the Hessian estimate
            delta += (lambda - lambda_bar) * kappa;
            if delta <= 0.0 {
                lambda_bar = 2.0 * (lambda - delta / kappa);
                delta = -delta + lambda * kappa;
                lambda = lambda_bar;
            }

            let alpha = -mu / delta;
            let x_new: Vec<f64> = x.iter().zip(&d).map(|(xi, di)| xi + alpha * di).collect();
            // one evaluation serves both the accept test and, on
            // acceptance, the next gradient — with the distributed
            // objective every call here is a full cluster round, so a
            // second obj(&x_new) in the accept branch would double the
            // SPMD work of every accepted iteration
            let (f_new, g_new) = obj(&x_new);
            evals += 1;
            // NaN is the abort sentinel: without this check the NaN
            // comparison below rejects forever *and* never grows lambda,
            // so the loop would spin without incrementing `iter`.
            if f_new.is_nan() {
                stop = StopReason::Aborted;
                break;
            }

            let comparison = 2.0 * delta * (f_now - f_new) / (mu * mu);
            if comparison >= 0.0 {
                // accept
                x = x_new;
                let g_old = std::mem::replace(&mut grad, g_new);
                let rel = (f_now - f_new).abs() / f_now.abs().max(f_new.abs()).max(1.0);
                f_now = f_new;
                trace.push(f_now);
                lambda_bar = 0.0;
                success = true;
                n_success += 1;
                iter += 1;

                if grad.iter().fold(0.0f64, |a, &b| a.max(b.abs())) < self.grad_tol {
                    stop = StopReason::GradTol;
                    break;
                }
                if rel < self.f_tol {
                    stop = StopReason::FtolReached;
                    break;
                }

                // restart or Polak–Ribiere-style update
                if n_success % n == 0 {
                    d = grad.iter().map(|g| -g).collect();
                } else {
                    let gg = vdot(&grad, &grad);
                    let gg_old_new = vdot(&g_old, &grad);
                    let beta = (gg - gg_old_new) / mu.abs().max(1e-300);
                    d = grad
                        .iter()
                        .zip(&d)
                        .map(|(g, di)| -g + beta * di)
                        .collect();
                }
                if comparison >= 0.75 {
                    lambda *= 0.25;
                }
            } else {
                lambda_bar = lambda;
                success = false;
            }
            if comparison < 0.25 {
                lambda += delta * (1.0 - comparison) / kappa;
            }
            if lambda > 1e40 {
                stop = StopReason::LineSearchFailed;
                break;
            }
        }

        let _ = norm2(&grad);
        OptResult { x, f: f_now, iterations: iter, evaluations: evals, stop, trace }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_objectives::{quadratic, rosenbrock};
    use super::*;

    #[test]
    fn solves_quadratic() {
        let r = Scg::default().minimize(&mut |x: &[f64]| quadratic(x), vec![1.0; 8]);
        assert!(r.f < 1e-8, "f = {} ({:?})", r.f, r.stop);
    }

    #[test]
    fn makes_progress_on_rosenbrock() {
        let r = Scg { max_iters: 2000, ..Default::default() }
            .minimize(&mut |x: &[f64]| rosenbrock(x), vec![-1.2, 1.0]);
        assert!(r.f < 1e-4, "f = {} after {} iters", r.f, r.iterations);
    }

    #[test]
    fn trace_nonincreasing() {
        let r = Scg::default().minimize(&mut |x: &[f64]| quadratic(x), vec![2.0; 5]);
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    /// A NaN objective must terminate promptly with `Aborted` — without
    /// the explicit checks the NaN comparison rejects forever without
    /// growing lambda, and the loop never advances.
    #[test]
    fn nan_objective_aborts() {
        let r = Scg::default()
            .minimize(&mut |x: &[f64]| (f64::NAN, vec![0.0; x.len()]), vec![1.0; 4]);
        assert_eq!(r.stop, StopReason::Aborted);
        assert_eq!(r.evaluations, 1);

        let mut calls = 0usize;
        let r = Scg::default().minimize(&mut |x: &[f64]| {
            calls += 1;
            if calls > 2 {
                (f64::NAN, vec![0.0; x.len()])
            } else {
                quadratic(x)
            }
        }, vec![1.0; 4]);
        assert_eq!(r.stop, StopReason::Aborted);
        assert!(r.evaluations <= 4, "kept evaluating: {}", r.evaluations);
    }
}
