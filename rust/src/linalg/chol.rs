//! Cholesky factorisation and solves — the numerical heart of the
//! leader-side M×M core: `A = K_uu + β Φ` is factored once per iteration
//! and reused for `A⁻¹P`, `logdet A` and the bound-gradient terms.

use super::matrix::Mat;

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix. Returns `Err` with the failing pivot index if the matrix is
/// not (numerically) positive definite.
#[derive(Clone, Debug)]
pub struct Chol {
    l: Mat,
}

/// Error type for a failed factorisation.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Index of the first failing pivot.
    pub pivot: usize,
    /// The non-positive value encountered there.
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite at pivot {} (value {:.3e})",
               self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Chol {
    /// Factor `a` (reads only the lower triangle).
    pub fn new(a: &Mat) -> Result<Chol, NotPositiveDefinite> {
        assert!(a.is_square(), "cholesky of non-square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        return Err(NotPositiveDefinite { pivot: i, value: sum });
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Chol { l })
    }

    /// Factor with escalating diagonal jitter (the GPy `jitchol` pattern):
    /// tries `a`, then `a + 10^k * eps * mean(diag) * I` for growing k.
    pub fn new_with_jitter(a: &Mat, max_tries: usize) -> Result<(Chol, f64), NotPositiveDefinite> {
        match Chol::new(a) {
            Ok(c) => return Ok((c, 0.0)),
            Err(e) => {
                let n = a.rows();
                let mean_diag = (0..n).map(|i| a[(i, i)]).sum::<f64>() / n as f64;
                let mut jitter = mean_diag.abs().max(1e-300) * 1e-10;
                for _ in 0..max_tries {
                    let mut aj = a.clone();
                    aj.add_diag(jitter);
                    if let Ok(c) = Chol::new(&aj) {
                        return Ok((c, jitter));
                    }
                    jitter *= 10.0;
                }
                Err(e)
            }
        }
    }

    /// The lower-triangular factor L.
    pub fn l(&self) -> &Mat { &self.l }
    /// Matrix dimension N.
    pub fn dim(&self) -> usize { self.l.rows() }

    /// `log det A = 2 Σ log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve `L x = b` in place (forward substitution), column-wise over a
    /// matrix right-hand side.
    pub fn solve_l(&self, b: &Mat) -> Mat {
        let n = self.dim();
        assert_eq!(b.rows(), n);
        let mut x = b.clone();
        for col in 0..b.cols() {
            for i in 0..n {
                let mut sum = x[(i, col)];
                for k in 0..i {
                    sum -= self.l[(i, k)] * x[(k, col)];
                }
                x[(i, col)] = sum / self.l[(i, i)];
            }
        }
        x
    }

    /// Solve `Lᵀ x = b` (backward substitution).
    pub fn solve_lt(&self, b: &Mat) -> Mat {
        let n = self.dim();
        assert_eq!(b.rows(), n);
        let mut x = b.clone();
        for col in 0..b.cols() {
            for i in (0..n).rev() {
                let mut sum = x[(i, col)];
                for k in (i + 1)..n {
                    sum -= self.l[(k, i)] * x[(k, col)];
                }
                x[(i, col)] = sum / self.l[(i, i)];
            }
        }
        x
    }

    /// Solve `A x = b` via the factorisation (`cho_solve`).
    pub fn solve(&self, b: &Mat) -> Mat {
        self.solve_lt(&self.solve_l(b))
    }

    /// Explicit `A⁻¹` (used for gradient assembly where the full inverse
    /// genuinely appears, e.g. ∂F/∂Φ = … − βD/2 A⁻¹ …).
    pub fn inverse(&self) -> Mat {
        let mut inv = self.solve(&Mat::eye(self.dim()));
        inv.symmetrize();
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::{Prop, Rng64};

    fn random_spd(rng: &mut Rng64, n: usize) -> Mat {
        // B Bᵀ + n·I is SPD for any B.
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(n as f64 * 0.1 + 0.1);
        a
    }

    #[test]
    fn prop_reconstruct() {
        // L Lᵀ == A over random SPD matrices (property test).
        Prop::new("chol_reconstruct").cases(40).run(|rng| {
            let n = 1 + (rng.next_u64() % 12) as usize;
            let a = random_spd(rng, n);
            let c = Chol::new(&a).expect("spd");
            let rec = c.l().matmul_t(c.l());
            assert!(rec.max_abs_diff(&a) < 1e-9 * (n as f64),
                    "reconstruction error too large (n={n})");
        });
    }

    #[test]
    fn prop_solve_identity() {
        // A * solve(A, B) == B.
        Prop::new("chol_solve").cases(40).run(|rng| {
            let n = 1 + (rng.next_u64() % 10) as usize;
            let k = 1 + (rng.next_u64() % 4) as usize;
            let a = random_spd(rng, n);
            let b = Mat::from_fn(n, k, |_, _| rng.normal());
            let c = Chol::new(&a).unwrap();
            let x = c.solve(&b);
            assert!(a.matmul(&x).max_abs_diff(&b) < 1e-8);
        });
    }

    #[test]
    fn logdet_matches_diagonal_matrix() {
        let d = Mat::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let c = Chol::new(&d).unwrap();
        let expect: f64 = (1..=4).map(|v| (v as f64).ln()).sum();
        assert!((c.logdet() - expect).abs() < 1e-12);
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Rng64::new(7);
        let a = random_spd(&mut rng, 6);
        let inv = Chol::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).max_abs_diff(&Mat::eye(6)) < 1e-9);
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(Chol::new(&a).is_err());
    }

    #[test]
    fn jitter_recovers_semidefinite() {
        // Rank-deficient PSD matrix: plain cholesky may fail, jitchol must not.
        let b = Mat::from_fn(4, 2, |i, j| (i + j) as f64);
        let a = b.matmul_t(&b); // rank <= 2
        let (c, jit) = Chol::new_with_jitter(&a, 10).expect("jitter should fix");
        assert!(jit >= 0.0);
        assert!(c.logdet().is_finite());
    }
}
