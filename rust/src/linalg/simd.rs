//! Runtime-dispatched SIMD lane primitives for the innermost f64 loops.
//!
//! Every hot inner loop in the codebase — the blocked matmul / syrk
//! kernels, the RBF-ARD Ψ-statistics and their VJPs, and the stats-layer
//! accumulators — reduces to four shapes:
//!
//! * [`dot`]   — `Σ aᵢ·bᵢ` (syrk row-dots, `matmul_t`, trace terms)
//! * [`axpy`]  — `yᵢ += c·xᵢ` (the fmadd row kernel inside blocked matmul)
//! * [`wsq_diff`] — `Σ wᵢ·(aᵢ−bᵢ)²` (the RBF exponent, fused)
//! * [`wsq_mid_diff`] — `Σ wᵢ·(mᵢ−½(aᵢ+bᵢ))²` (the Ψ2 exponent's midpoint term)
//!
//! Each primitive is implemented at three [`SimdLevel`]s:
//!
//! * `Off` — the exact pre-SIMD sequential scalar loop, preserved
//!   bit-for-bit as the escape hatch and the property-test reference.
//! * `Scalar` — portable 4-lane-chunked scalar code (four independent
//!   accumulators, combined in the same tree order as the AVX2 horizontal
//!   sum, sequential tail). Compiles everywhere; autovectorizes well.
//! * `Native` — AVX2+FMA intrinsics on `x86_64`, selected once at startup
//!   via `is_x86_feature_detected!`. Falls back to the `Scalar` body when
//!   the features are absent (checked inside the dispatch arm, so an
//!   explicit `Native` request is always sound).
//!
//! Numerical contract: `Off` and `Scalar` agree bit-for-bit on the
//! elementwise `axpy` and on any reduction of ≤ 3 elements (the chunked
//! path degenerates to the sequential tail); longer reductions reorder the
//! summation and `Native` fuses multiply-adds, so cross-level agreement is
//! tight-ulp, property-tested in `testutil::ulp` terms over ragged sizes.
//!
//! The active level is a process-global resolved lazily from the
//! `GPPAR_SIMD` environment variable (`off|scalar|native`, anything else —
//! including unset — means auto-detect), overridable via [`set_active`]
//! (the engine applies `EngineConfig::simd` there, before any compute
//! threads spawn). Tests never mutate the global: they exercise explicit
//! levels through the `*_at` variants.

use std::sync::atomic::{AtomicU8, Ordering};

/// SIMD dispatch tier. See the module docs for the numerical contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Exact pre-SIMD sequential scalar loops (bit-identical escape hatch).
    Off,
    /// Portable 4-lane-chunked scalar fallback.
    Scalar,
    /// AVX2+FMA intrinsics where detected; `Scalar` body otherwise.
    Native,
}

impl SimdLevel {
    /// All levels, lowest to highest — test sweeps iterate this.
    pub const ALL: [SimdLevel; 3] = [SimdLevel::Off, SimdLevel::Scalar, SimdLevel::Native];

    /// Parse `off|scalar|native` (case-insensitive). `None` on anything
    /// else — callers decide whether that means "auto" (env) or an error
    /// (CLI).
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" => Some(SimdLevel::Off),
            "scalar" => Some(SimdLevel::Scalar),
            "native" => Some(SimdLevel::Native),
            _ => None,
        }
    }

    /// Canonical lowercase name (round-trips through [`SimdLevel::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Off => "off",
            SimdLevel::Scalar => "scalar",
            SimdLevel::Native => "native",
        }
    }
}

// Level encoding in the global: 0 = unresolved, 1..=3 = Off/Scalar/Native.
const UNINIT: u8 = 0;

fn to_u8(level: SimdLevel) -> u8 {
    match level {
        SimdLevel::Off => 1,
        SimdLevel::Scalar => 2,
        SimdLevel::Native => 3,
    }
}

fn from_u8(v: u8) -> SimdLevel {
    match v {
        1 => SimdLevel::Off,
        2 => SimdLevel::Scalar,
        _ => SimdLevel::Native,
    }
}

static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

/// The process-global active level. Resolved on first call from
/// `GPPAR_SIMD` (`off|scalar|native`; unset or unrecognized → `Native` if
/// AVX2+FMA are detected, else `Scalar`), then cached.
pub fn active() -> SimdLevel {
    // Relaxed: single-cell lazy cache — no other memory is published
    // through this flag, and a racing first call resolves the same
    // value, so the store is idempotent.
    match ACTIVE.load(Ordering::Relaxed) {
        UNINIT => {
            let level = resolve(std::env::var("GPPAR_SIMD").ok().as_deref());
            ACTIVE.store(to_u8(level), Ordering::Relaxed); // Relaxed: idempotent cache fill (see above)
            level
        }
        v => from_u8(v),
    }
}

/// Override the process-global level. Call before spawning compute threads
/// (the engine does this once, from `Engine::new`); concurrent kernels
/// observe the switch at an arbitrary point, which would break any
/// bit-identity assumption mid-computation.
pub fn set_active(level: SimdLevel) {
    // Relaxed: a plain mode flag; the documented contract is that this
    // runs before compute threads spawn, and thread spawn/join already
    // provides the necessary ordering.
    ACTIVE.store(to_u8(level), Ordering::Relaxed);
}

/// `GPPAR_SIMD` → level: recognized names win, anything else auto-detects.
fn resolve(env: Option<&str>) -> SimdLevel {
    if let Some(level) = env.and_then(SimdLevel::parse) {
        return level;
    }
    if native_available() { SimdLevel::Native } else { SimdLevel::Scalar }
}

// Detection result cache: 0 = unknown, 1 = available, 2 = absent.
static NATIVE: AtomicU8 = AtomicU8::new(0);

/// Whether the `Native` tier's AVX2+FMA code paths can run on this CPU
/// (always `false` off x86_64). Cached after the first query.
pub fn native_available() -> bool {
    // Relaxed: single-cell detection cache; cpuid gives every thread
    // the same answer, so a racing fill stores the same value and no
    // other memory depends on the flag's ordering.
    match NATIVE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = detect_native();
            NATIVE.store(if ok { 1 } else { 2 }, Ordering::Relaxed); // Relaxed: idempotent cache fill (see above)
            ok
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_native() -> bool {
    // Miri interprets MIR and has no cpuid or vector intrinsics: report
    // the native tier as absent so every dispatch falls back to the
    // portable chunked-scalar bodies under `cargo miri test`.
    if cfg!(miri) {
        return false;
    }
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_native() -> bool {
    false
}

// ---------------------------------------------------------------------
// dot: Σ aᵢ·bᵢ
// ---------------------------------------------------------------------

/// `Σ aᵢ·bᵢ` at the process-global level. Panics on length mismatch.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_at(active(), a, b)
}

/// [`dot`] at an explicit level (test sweeps; level-pinned callers).
pub fn dot_at(level: SimdLevel, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    match level {
        SimdLevel::Off => dot_off(a, b),
        SimdLevel::Scalar => dot_chunks(a, b),
        SimdLevel::Native => dot_native(a, b),
    }
}

fn dot_off(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

fn dot_chunks(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let mut acc = [0.0f64; 4];
    let mut i = 0;
    while i + 4 <= n {
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
        i += 4;
    }
    // Same tree as the AVX2 horizontal sum: (lane0+lane2)+(lane1+lane3).
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

fn dot_native(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if native_available() {
        // SAFETY: AVX2+FMA presence verified by native_available().
        return unsafe { avx::dot(a, b) };
    }
    dot_chunks(a, b)
}

// ---------------------------------------------------------------------
// axpy: yᵢ += c·xᵢ
// ---------------------------------------------------------------------

/// `yᵢ += c·xᵢ` in place at the process-global level. Elementwise, so
/// `Off` and `Scalar` are bit-identical; `Native` fuses the multiply-add.
/// Panics on length mismatch.
pub fn axpy(y: &mut [f64], c: f64, x: &[f64]) {
    axpy_at(active(), y, c, x)
}

/// [`axpy`] at an explicit level.
pub fn axpy_at(level: SimdLevel, y: &mut [f64], c: f64, x: &[f64]) {
    assert_eq!(y.len(), x.len(), "axpy length mismatch");
    match level {
        SimdLevel::Off | SimdLevel::Scalar => axpy_off(y, c, x),
        SimdLevel::Native => axpy_native(y, c, x),
    }
}

fn axpy_off(y: &mut [f64], c: f64, x: &[f64]) {
    for i in 0..y.len() {
        y[i] += c * x[i];
    }
}

fn axpy_native(y: &mut [f64], c: f64, x: &[f64]) {
    #[cfg(target_arch = "x86_64")]
    if native_available() {
        // SAFETY: AVX2+FMA presence verified by native_available().
        unsafe { avx::axpy(y, c, x) };
        return;
    }
    axpy_off(y, c, x)
}

// ---------------------------------------------------------------------
// wsq_diff: Σ wᵢ·(aᵢ−bᵢ)²
// ---------------------------------------------------------------------

/// `Σ wᵢ·(aᵢ−bᵢ)²` at the process-global level — the fused RBF-ARD
/// exponent (weights = inverse-squared lengthscales). Terms are
/// nonnegative, so the reduction never cancels and cross-level agreement
/// stays within a few ulps per element. Panics on length mismatch.
pub fn wsq_diff(w: &[f64], a: &[f64], b: &[f64]) -> f64 {
    wsq_diff_at(active(), w, a, b)
}

/// [`wsq_diff`] at an explicit level.
pub fn wsq_diff_at(level: SimdLevel, w: &[f64], a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(w.len(), a.len(), "wsq_diff length mismatch");
    assert_eq!(w.len(), b.len(), "wsq_diff length mismatch");
    match level {
        SimdLevel::Off => wsq_diff_off(w, a, b),
        SimdLevel::Scalar => wsq_diff_chunks(w, a, b),
        SimdLevel::Native => wsq_diff_native(w, a, b),
    }
}

fn wsq_diff_off(w: &[f64], a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..w.len() {
        let d = a[i] - b[i];
        acc += w[i] * d * d;
    }
    acc
}

fn wsq_diff_chunks(w: &[f64], a: &[f64], b: &[f64]) -> f64 {
    let n = w.len();
    let mut acc = [0.0f64; 4];
    let mut i = 0;
    while i + 4 <= n {
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        acc[0] += w[i] * d0 * d0;
        acc[1] += w[i + 1] * d1 * d1;
        acc[2] += w[i + 2] * d2 * d2;
        acc[3] += w[i + 3] * d3 * d3;
        i += 4;
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    while i < n {
        let d = a[i] - b[i];
        s += w[i] * d * d;
        i += 1;
    }
    s
}

fn wsq_diff_native(w: &[f64], a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if native_available() {
        // SAFETY: AVX2+FMA presence verified by native_available().
        return unsafe { avx::wsq_diff(w, a, b) };
    }
    wsq_diff_chunks(w, a, b)
}

// ---------------------------------------------------------------------
// wsq_mid_diff: Σ wᵢ·(mᵢ − ½(aᵢ+bᵢ))²
// ---------------------------------------------------------------------

/// `Σ wᵢ·(mᵢ − ½(aᵢ+bᵢ))²` at the process-global level — the Ψ2
/// exponent's inducing-midpoint term. Panics on length mismatch.
pub fn wsq_mid_diff(w: &[f64], m: &[f64], a: &[f64], b: &[f64]) -> f64 {
    wsq_mid_diff_at(active(), w, m, a, b)
}

/// [`wsq_mid_diff`] at an explicit level.
pub fn wsq_mid_diff_at(level: SimdLevel, w: &[f64], m: &[f64], a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(w.len(), m.len(), "wsq_mid_diff length mismatch");
    assert_eq!(w.len(), a.len(), "wsq_mid_diff length mismatch");
    assert_eq!(w.len(), b.len(), "wsq_mid_diff length mismatch");
    match level {
        SimdLevel::Off => wsq_mid_diff_off(w, m, a, b),
        SimdLevel::Scalar => wsq_mid_diff_chunks(w, m, a, b),
        SimdLevel::Native => wsq_mid_diff_native(w, m, a, b),
    }
}

fn wsq_mid_diff_off(w: &[f64], m: &[f64], a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..w.len() {
        let g = m[i] - 0.5 * (a[i] + b[i]);
        acc += w[i] * g * g;
    }
    acc
}

fn wsq_mid_diff_chunks(w: &[f64], m: &[f64], a: &[f64], b: &[f64]) -> f64 {
    let n = w.len();
    let mut acc = [0.0f64; 4];
    let mut i = 0;
    while i + 4 <= n {
        let g0 = m[i] - 0.5 * (a[i] + b[i]);
        let g1 = m[i + 1] - 0.5 * (a[i + 1] + b[i + 1]);
        let g2 = m[i + 2] - 0.5 * (a[i + 2] + b[i + 2]);
        let g3 = m[i + 3] - 0.5 * (a[i + 3] + b[i + 3]);
        acc[0] += w[i] * g0 * g0;
        acc[1] += w[i + 1] * g1 * g1;
        acc[2] += w[i + 2] * g2 * g2;
        acc[3] += w[i + 3] * g3 * g3;
        i += 4;
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    while i < n {
        let g = m[i] - 0.5 * (a[i] + b[i]);
        s += w[i] * g * g;
        i += 1;
    }
    s
}

fn wsq_mid_diff_native(w: &[f64], m: &[f64], a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if native_available() {
        // SAFETY: AVX2+FMA presence verified by native_available().
        return unsafe { avx::wsq_mid_diff(w, m, a, b) };
    }
    wsq_mid_diff_chunks(w, m, a, b)
}

// ---------------------------------------------------------------------
// AVX2+FMA bodies (x86_64 only; callers gate on native_available()).
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx {
    use std::arch::x86_64::*;

    /// Horizontal sum in the fixed tree order (lane0+lane2)+(lane1+lane3),
    /// mirrored exactly by the chunked-scalar combine.
    // SAFETY: `unsafe` solely because of `#[target_feature]` — the body
    // touches no raw pointers, only register-to-register AVX/SSE2
    // intrinsics. Callers must ensure AVX2 is available; every caller
    // in this module carries that same precondition and is gated behind
    // `native_available()`.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v); // [lane0, lane1]
        let hi = _mm256_extractf128_pd::<1>(v); // [lane2, lane3]
        let pair = _mm_add_pd(lo, hi); // [l0+l2, l1+l3]
        let swapped = _mm_unpackhi_pd(pair, pair);
        _mm_cvtsd_f64(_mm_add_sd(pair, swapped))
    }

    // SAFETY preconditions (caller): AVX2+FMA must be present — every
    // call site dispatches through `native_available()`. Pointer
    // validity is internal: `_mm256_loadu_pd(a.as_ptr().add(i))` reads
    // `[i, i+4)` only while `i + 4 <= n` with `n == a.len() == b.len()`
    // (asserted in `dot_at`), so every load is in-bounds; `loadu` has
    // no alignment requirement.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let va = _mm256_loadu_pd(a.as_ptr().add(i));
            let vb = _mm256_loadu_pd(b.as_ptr().add(i));
            acc = _mm256_fmadd_pd(va, vb, acc);
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    // SAFETY preconditions (caller): AVX2+FMA must be present — every
    // call site dispatches through `native_available()`. Pointer
    // validity is internal: loads/stores touch `[i, i+4)` only while
    // `i + 4 <= n` with `n == y.len() == x.len()` (asserted in
    // `axpy_at`); the store goes through `y.as_mut_ptr()`, the one
    // exclusive borrow, and `loadu`/`storeu` are alignment-free.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(y: &mut [f64], c: f64, x: &[f64]) {
        let n = y.len();
        let vc = _mm256_set1_pd(c);
        let mut i = 0;
        while i + 4 <= n {
            let vx = _mm256_loadu_pd(x.as_ptr().add(i));
            let vy = _mm256_loadu_pd(y.as_ptr().add(i));
            _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_fmadd_pd(vc, vx, vy));
            i += 4;
        }
        while i < n {
            y[i] += c * x[i];
            i += 1;
        }
    }

    // SAFETY preconditions (caller): AVX2+FMA must be present — every
    // call site dispatches through `native_available()`. Pointer
    // validity is internal: all three slices are length-checked equal
    // in `wsq_diff_at` and each unaligned load reads `[i, i+4)` only
    // while `i + 4 <= n`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn wsq_diff(w: &[f64], a: &[f64], b: &[f64]) -> f64 {
        let n = w.len();
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let vd = _mm256_sub_pd(_mm256_loadu_pd(a.as_ptr().add(i)),
                                   _mm256_loadu_pd(b.as_ptr().add(i)));
            let t = _mm256_mul_pd(_mm256_loadu_pd(w.as_ptr().add(i)), vd);
            acc = _mm256_fmadd_pd(t, vd, acc);
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = a[i] - b[i];
            s += w[i] * d * d;
            i += 1;
        }
        s
    }

    // SAFETY preconditions (caller): AVX2+FMA must be present — every
    // call site dispatches through `native_available()`. Pointer
    // validity is internal: all four slices are length-checked equal in
    // `wsq_mid_diff_at` and each unaligned load reads `[i, i+4)` only
    // while `i + 4 <= n`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn wsq_mid_diff(w: &[f64], m: &[f64], a: &[f64], b: &[f64]) -> f64 {
        let n = w.len();
        let half = _mm256_set1_pd(0.5);
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let mid = _mm256_mul_pd(half, _mm256_add_pd(_mm256_loadu_pd(a.as_ptr().add(i)),
                                                        _mm256_loadu_pd(b.as_ptr().add(i))));
            let g = _mm256_sub_pd(_mm256_loadu_pd(m.as_ptr().add(i)), mid);
            let t = _mm256_mul_pd(_mm256_loadu_pd(w.as_ptr().add(i)), g);
            acc = _mm256_fmadd_pd(t, g, acc);
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            let g = m[i] - 0.5 * (a[i] + b[i]);
            s += w[i] * g * g;
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;
    use crate::testutil::ulp::assert_close_ulps;

    #[test]
    fn parse_round_trips() {
        for level in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(level.name()), Some(level));
        }
        assert_eq!(SimdLevel::parse("OFF"), Some(SimdLevel::Off));
        assert_eq!(SimdLevel::parse(" native "), Some(SimdLevel::Native));
        assert_eq!(SimdLevel::parse("auto"), None);
        assert_eq!(SimdLevel::parse(""), None);
    }

    #[test]
    fn resolve_env_values() {
        assert_eq!(resolve(Some("off")), SimdLevel::Off);
        assert_eq!(resolve(Some("scalar")), SimdLevel::Scalar);
        assert_eq!(resolve(Some("native")), SimdLevel::Native);
        // Unset / unrecognized auto-detect — never Off.
        for env in [None, Some("auto"), Some("bogus")] {
            let level = resolve(env);
            assert!(level == SimdLevel::Scalar || level == SimdLevel::Native);
            if level == SimdLevel::Native {
                assert!(native_available());
            }
        }
    }

    #[test]
    fn active_is_resolved_and_stable() {
        // Never mutate the global here (other tests run concurrently);
        // just check lazy resolution yields a stable non-sentinel level.
        assert_eq!(active(), active());
    }

    #[test]
    fn tails_are_bit_identical_across_levels() {
        // Reductions of ≤ 3 elements take the sequential tail at every
        // level, so Q-sized (1–3) kernel loops agree bit-for-bit.
        let mut rng = crate::testutil::prop::Rng64::new(7);
        for n in 0..=3usize {
            let w: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 2.0)).collect();
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let m: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for level in SimdLevel::ALL {
                assert_eq!(dot_at(level, &a, &b).to_bits(),
                           dot_at(SimdLevel::Off, &a, &b).to_bits(), "dot n={n}");
                assert_eq!(wsq_diff_at(level, &w, &a, &b).to_bits(),
                           wsq_diff_at(SimdLevel::Off, &w, &a, &b).to_bits(),
                           "wsq_diff n={n}");
                assert_eq!(wsq_mid_diff_at(level, &w, &m, &a, &b).to_bits(),
                           wsq_mid_diff_at(SimdLevel::Off, &w, &m, &a, &b).to_bits(),
                           "wsq_mid_diff n={n}");
            }
        }
    }

    #[test]
    fn axpy_off_and_scalar_bit_identical() {
        let mut rng = crate::testutil::prop::Rng64::new(11);
        for n in 0..=33usize {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut y0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut y1 = y0.clone();
            axpy_at(SimdLevel::Off, &mut y0, 0.37, &x);
            axpy_at(SimdLevel::Scalar, &mut y1, 0.37, &x);
            for i in 0..n {
                assert_eq!(y0[i].to_bits(), y1[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn prop_primitives_ulp_close_across_levels_and_ragged_sizes() {
        // Every primitive × every level × sizes 1..=33 (straddling the
        // 4-wide lane boundary with ragged tails) vs the Off reference.
        Prop::new("simd_primitives_vs_off").cases(40).run(|rng| {
            let n = 1 + (rng.next_u64() % 33) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.05, 3.0)).collect();
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let m: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let c = rng.normal();
            for level in SimdLevel::ALL {
                assert_close_ulps(dot_at(level, &a, &b), dot_at(SimdLevel::Off, &a, &b),
                                  64, 1e-12, &format!("dot n={n} {}", level.name()));
                assert_close_ulps(wsq_diff_at(level, &w, &a, &b),
                                  wsq_diff_at(SimdLevel::Off, &w, &a, &b),
                                  16, 0.0, &format!("wsq_diff n={n} {}", level.name()));
                assert_close_ulps(wsq_mid_diff_at(level, &w, &m, &a, &b),
                                  wsq_mid_diff_at(SimdLevel::Off, &w, &m, &a, &b),
                                  16, 0.0, &format!("wsq_mid_diff n={n} {}", level.name()));
                let mut y0: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
                let mut y1 = y0.clone();
                axpy_at(SimdLevel::Off, &mut y0, c, &a);
                axpy_at(level, &mut y1, c, &a);
                for i in 0..n {
                    assert_close_ulps(y1[i], y0[i], 1, 0.0,
                                      &format!("axpy n={n} i={i} {}", level.name()));
                }
            }
        });
    }

    #[test]
    fn dot_matches_naive_values() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 0.5, -1.0, 3.0, 0.0];
        for level in SimdLevel::ALL {
            assert!((dot_at(level, &a, &b) - 12.0).abs() < 1e-12);
        }
        for level in SimdLevel::ALL {
            assert_eq!(dot_at(level, &[], &[]), 0.0);
        }
    }
}
