//! Dense row-major `f64` matrix — the workhorse of the M×M "indistributable
//! core" (bound assembly, predictions, the dense-GP baseline).
//!
//! Deliberately minimal: owned storage, explicit dimensions, no
//! broadcasting magic. Everything here is O(M²)/O(M³) leader-side work;
//! the O(N) data-parallel work lives in `math::stats` / the XLA artifacts.

use crate::linalg::simd::{self, SimdLevel};
use std::fmt;
use std::ops::{Index, IndexMut};

/// Tile edge for the cache-blocked matmul: 64×64 f64 tiles are 32 KiB —
/// one operand tile per L1 slice, three per typical L2 way-set.
const MM_BLOCK: usize = 64;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero-filled `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a row-major data vector; panics on length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length {} != {}x{}",
                   data.len(), rows, cols);
        Mat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Column vector (n × 1) from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat::from_vec(v.len(), 1, v.to_vec())
    }

    /// Row count.
    pub fn rows(&self) -> usize { self.rows }
    /// Column count.
    pub fn cols(&self) -> usize { self.cols }
    /// Is this matrix square?
    pub fn is_square(&self) -> bool { self.rows == self.cols }

    /// Underlying row-major storage.
    pub fn as_slice(&self) -> &[f64] { &self.data }
    /// Mutable view of the row-major storage.
    pub fn as_mut_slice(&mut self) -> &mut [f64] { &mut self.data }
    /// Unwrap into the row-major data vector.
    pub fn into_vec(self) -> Vec<f64> { self.data }

    /// Overwrite the whole matrix from a row-major slice without
    /// reallocating (the wire-unpack hot path reuses one `Mat` per cycle
    /// instead of a fresh `from_vec`). Panics on length mismatch.
    pub fn set_from(&mut self, data: &[f64]) {
        assert_eq!(data.len(), self.rows * self.cols, "set_from length {} != {}x{}",
                   data.len(), self.rows, self.cols);
        self.data.copy_from_slice(data);
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// `self * other`. Dispatches to the cache-blocked (and
    /// SIMD-accelerated) kernel once the combined working set outgrows the
    /// cache-friendly sizes; both kernels accumulate each output element
    /// in ascending-k order, so at the `off`/`scalar` SIMD tiers the
    /// results are bit-identical and the dispatch is invisible. At the
    /// `native` tier the blocked kernel fuses multiply-adds, so blocked
    /// and naive agree to tight ulps rather than bitwise — the dispatch
    /// is still deterministic per shape.
    pub fn matmul(&self, other: &Mat) -> Mat {
        if Self::use_blocked(self.rows, self.cols, other.cols) {
            self.matmul_blocked(other)
        } else {
            self.matmul_naive(other)
        }
    }

    /// Blocked-kernel dispatch predicate for an n×k · k×m product: take
    /// the blocked path once the three operands' combined footprint
    /// reaches three `MM_BLOCK²` tiles. Unlike the old all-dims ≥
    /// `MM_BLOCK` rule, this catches the tall-skinny N×Q·Q×M and N×M·M×M
    /// products that dominate the Ψ1 path (huge `n`, tiny `k`), which
    /// previously always fell through to the naive loop.
    fn use_blocked(n: usize, k: usize, m: usize) -> bool {
        n * k + k * m + n * m >= 3 * MM_BLOCK * MM_BLOCK
    }

    /// `self * other` — naive triple loop with row-major-friendly order
    /// (the reference the blocked kernel is property-tested against).
    pub fn matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} * {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 { continue; }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `self * other`, cache-blocked: the k-blocks are the outer loop so
    /// each `MM_BLOCK × MM_BLOCK` tile of `other` stays L1/L2-resident
    /// while a block of output rows sweeps it. Per output element the
    /// accumulation order is still ascending k; the inner row update runs
    /// on the SIMD `axpy` primitive at the active dispatch level, which is
    /// bit-identical to [`Mat::matmul_naive`] at the `off`/`scalar` tiers
    /// and tight-ulp (fused multiply-add) at `native`.
    pub fn matmul_blocked(&self, other: &Mat) -> Mat {
        self.matmul_blocked_at(simd::active(), other)
    }

    fn matmul_blocked_at(&self, level: SimdLevel, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} * {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let (n, kk, m) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(n, m);
        for kb in (0..kk).step_by(MM_BLOCK) {
            let ke = (kb + MM_BLOCK).min(kk);
            for ib in (0..n).step_by(MM_BLOCK) {
                let ie = (ib + MM_BLOCK).min(n);
                for jb in (0..m).step_by(MM_BLOCK) {
                    let je = (jb + MM_BLOCK).min(m);
                    for i in ib..ie {
                        for k in kb..ke {
                            let a = self.data[i * kk + k];
                            if a == 0.0 { continue; }
                            let orow = &other.data[k * m + jb..k * m + je];
                            let out_row = &mut out.data[i * m + jb..i * m + je];
                            simd::axpy_at(level, out_row, a, orow);
                        }
                    }
                }
            }
        }
        out
    }

    /// `selfᵀ * other` without materialising the transpose. The inner row
    /// update runs on the SIMD `axpy` primitive at the active level.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        self.t_matmul_at(simd::active(), other)
    }

    fn t_matmul_at(&self, level: SimdLevel, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let srow = self.row(k);
            let orow = other.row(k);
            for i in 0..self.cols {
                let a = srow[i];
                if a == 0.0 { continue; }
                simd::axpy_at(level, out.row_mut(i), a, orow);
            }
        }
        out
    }

    /// Symmetric rank-k update `self * selfᵀ` (n×n from n×k): computes
    /// only the lower triangle and mirrors — half the flops of
    /// `matmul_t(self)`, bit-identical on the computed entries (both run
    /// the same SIMD row-dot at the same dispatch level). This is the
    /// Ψ2-shaped product at the heart of the leader's M×M core
    /// (`A⁻¹P (A⁻¹P)ᵀ`).
    pub fn syrk(&self) -> Mat {
        self.syrk_at(simd::active())
    }

    fn syrk_at(&self, level: SimdLevel) -> Mat {
        let n = self.rows;
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            let ri = self.row(i);
            for j in 0..=i {
                let acc = simd::dot_at(level, ri, self.row(j));
                out[(i, j)] = acc;
                out[(j, i)] = acc;
            }
        }
        out
    }

    /// Weighted Gram update `selfᵀ · diag(w) · self` (k×k from n×k):
    /// a row-wise symmetric rank-1 accumulation (upper triangle, then
    /// mirrored) — the syrk-style form of the SGPR Ψ2 statistic
    /// `Σ_n w_n k_n k_nᵀ`. Rows with `w == 0` are skipped entirely.
    pub fn syrk_t_weighted(&self, w: &[f64]) -> Mat {
        self.syrk_t_weighted_at(simd::active(), w)
    }

    fn syrk_t_weighted_at(&self, level: SimdLevel, w: &[f64]) -> Mat {
        assert_eq!(w.len(), self.rows);
        let k = self.cols;
        let mut out = Mat::zeros(k, k);
        for row in 0..self.rows {
            if w[row] == 0.0 { continue; }
            let r = self.row(row);
            for i in 0..k {
                let a = w[row] * r[i];
                if a == 0.0 { continue; }
                simd::axpy_at(level, &mut out.row_mut(i)[i..], a, &r[i..]);
            }
        }
        for i in 0..k {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
        out
    }

    /// `self * otherᵀ` — row dots on the SIMD `dot` primitive.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        self.matmul_t_at(simd::active(), other)
    }

    fn matmul_t_at(&self, level: SimdLevel, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let srow = self.row(i);
            for j in 0..other.rows {
                out[(i, j)] = simd::dot_at(level, srow, other.row(j));
            }
        }
        out
    }

    /// Element-wise in-place `self += c * other`.
    pub fn axpy(&mut self, c: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        simd::axpy(&mut self.data, c, &other.data);
    }

    /// `self * c` (copy).
    pub fn scale(&self, c: f64) -> Mat {
        Mat::from_vec(self.rows, self.cols,
                      self.data.iter().map(|v| v * c).collect())
    }

    /// In-place scale.
    pub fn scale_mut(&mut self, c: f64) {
        for v in &mut self.data { *v *= c; }
    }

    /// Add `c` to the diagonal in place.
    pub fn add_diag(&mut self, c: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            self.data[i * self.cols + i] += c;
        }
    }

    /// Sum of diagonal entries.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Frobenius inner product `sum_ij self_ij * other_ij`.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        simd::dot(&self.data, &other.data)
    }

    /// `tr(self * other)` for square same-size matrices, without the product.
    pub fn trace_product(&self, other: &Mat) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut acc = 0.0;
        for i in 0..self.rows {
            for k in 0..self.cols {
                acc += self[(i, k)] * other[(k, i)];
            }
        }
        acc
    }

    /// Force exact symmetry: `(A + Aᵀ)/2`.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Max |a_ij - b_ij| — used all over the tests.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.iter().zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Elementwise map (copy).
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat::from_vec(self.rows, self.cols,
                      self.data.iter().map(|&v| f(v)).collect())
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 { writeln!(f, "  ...")?; }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        assert_eq!(Mat::eye(3).matmul(&a), a);
        assert_eq!(a.matmul(&Mat::eye(4)), a);
    }

    #[test]
    fn t_matmul_equals_explicit() {
        let a = Mat::from_fn(5, 3, |i, j| (i as f64 - j as f64) * 0.7);
        let b = Mat::from_fn(5, 2, |i, j| (i + 2 * j) as f64 * 0.3);
        assert!(a.t().matmul(&b).max_abs_diff(&a.t_matmul(&b)) < 1e-13);
    }

    #[test]
    fn matmul_t_equals_explicit() {
        let a = Mat::from_fn(4, 3, |i, j| (i * j) as f64 + 1.0);
        let b = Mat::from_fn(5, 3, |i, j| i as f64 - 0.5 * j as f64);
        assert!(a.matmul(&b.t()).max_abs_diff(&a.matmul_t(&b)) < 1e-13);
    }

    #[test]
    fn trace_and_trace_product() {
        let a = Mat::from_fn(3, 3, |i, j| (i + j) as f64);
        let b = Mat::from_fn(3, 3, |i, j| (i as f64) * 0.5 - j as f64);
        let ab = a.matmul(&b);
        assert!((a.trace_product(&b) - ab.trace()).abs() < 1e-13);
    }

    #[test]
    fn symmetrize_is_symmetric() {
        let mut a = Mat::from_fn(4, 4, |i, j| (3 * i + j) as f64);
        a.symmetrize();
        assert!(a.max_abs_diff(&a.t()) == 0.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn prop_blocked_matmul_matches_naive_per_level() {
        // Sizes straddle the 64-wide tile edge (including ragged tails and
        // degenerate dims). At off/scalar the ascending-k axpy makes the
        // two kernels agree exactly; at native the fused multiply-add
        // perturbs each element by at most one rounding per k-term, so
        // the contract is tight-ulp against the untouched naive loop.
        use crate::linalg::simd::SimdLevel;
        use crate::testutil::prop::Prop;
        use crate::testutil::ulp::assert_mat_close_ulps;
        Prop::new("matmul_blocked_vs_naive").cases(12).run(|rng| {
            let n = 1 + (rng.next_u64() % 150) as usize;
            let k = 1 + (rng.next_u64() % 150) as usize;
            let m = 1 + (rng.next_u64() % 150) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, m, |_, _| rng.normal());
            let want = a.matmul_naive(&b);
            for level in SimdLevel::ALL {
                let got = a.matmul_blocked_at(level, &b);
                match level {
                    SimdLevel::Off | SimdLevel::Scalar => {
                        let diff = want.max_abs_diff(&got);
                        assert!(diff == 0.0, "{n}x{k}x{m} {}: diff {diff}", level.name());
                    }
                    SimdLevel::Native => {
                        assert_mat_close_ulps(&got, &want, 128, 1e-10,
                                              &format!("{n}x{k}x{m} native"));
                    }
                }
            }
        });
    }

    #[test]
    fn matmul_dispatch_matches_naive() {
        // Above the dispatch threshold matmul() takes the blocked path;
        // verify against the naive reference on a 130³ product (bitwise
        // only when the active tier keeps the scalar accumulation order).
        use crate::linalg::simd::{self, SimdLevel};
        use crate::testutil::ulp::assert_mat_close_ulps;
        let mut rng = crate::testutil::prop::Rng64::new(91);
        let a = Mat::from_fn(130, 130, |_, _| rng.normal());
        let b = Mat::from_fn(130, 130, |_, _| rng.normal());
        let (got, want) = (a.matmul(&b), a.matmul_naive(&b));
        match simd::active() {
            SimdLevel::Off | SimdLevel::Scalar => {
                assert!(got.max_abs_diff(&want) == 0.0);
            }
            SimdLevel::Native => {
                assert_mat_close_ulps(&got, &want, 128, 1e-10, "matmul 130^3");
            }
        }
    }

    #[test]
    fn tall_skinny_products_take_blocked_path() {
        // The Ψ1-path shapes: N×Q·Q×M (huge n, tiny k) and N×M·M×M must
        // hit the blocked kernel under the working-set dispatch even
        // though some dims are far below MM_BLOCK.
        assert!(Mat::use_blocked(2048, 2, 100), "N×Q · Q×M");
        assert!(Mat::use_blocked(2048, 100, 100), "N×M · M×M");
        assert!(Mat::use_blocked(64, 64, 64), "old threshold boundary");
        assert!(Mat::use_blocked(130, 130, 130));
        assert!(!Mat::use_blocked(8, 8, 8), "small products stay naive");
        assert!(!Mat::use_blocked(32, 32, 32));
        // And the blocked result on a tall-skinny product matches naive.
        use crate::linalg::simd::SimdLevel;
        use crate::testutil::ulp::assert_mat_close_ulps;
        let mut rng = crate::testutil::prop::Rng64::new(17);
        let a = Mat::from_fn(300, 2, |_, _| rng.normal());
        let b = Mat::from_fn(2, 90, |_, _| rng.normal());
        let want = a.matmul_naive(&b);
        for level in SimdLevel::ALL {
            assert_mat_close_ulps(&a.matmul_blocked_at(level, &b), &want, 4, 0.0,
                                  &format!("tall-skinny {}", level.name()));
        }
    }

    #[test]
    fn prop_simd_kernels_match_off_reference() {
        // syrk / syrk_t_weighted / t_matmul / matmul_t at every SIMD level
        // vs the Off (pre-SIMD scalar) tier, over ragged non-lane-multiple
        // sizes.
        use crate::linalg::simd::SimdLevel;
        use crate::testutil::prop::Prop;
        use crate::testutil::ulp::assert_mat_close_ulps;
        Prop::new("matrix_kernels_vs_off").cases(20).run(|rng| {
            let n = 1 + (rng.next_u64() % 33) as usize;
            let k = 1 + (rng.next_u64() % 33) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let b = Mat::from_fn(n, k, |_, _| rng.normal());
            let w: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.0, 2.0)).collect();
            for level in SimdLevel::ALL {
                assert_mat_close_ulps(&a.syrk_at(level), &a.syrk_at(SimdLevel::Off),
                                      64, 1e-12, &format!("syrk {}", level.name()));
                assert_mat_close_ulps(&a.syrk_t_weighted_at(level, &w),
                                      &a.syrk_t_weighted_at(SimdLevel::Off, &w),
                                      64, 1e-12,
                                      &format!("syrk_t_weighted {}", level.name()));
                assert_mat_close_ulps(&a.t_matmul_at(level, &b),
                                      &a.t_matmul_at(SimdLevel::Off, &b),
                                      64, 1e-12, &format!("t_matmul {}", level.name()));
                assert_mat_close_ulps(&a.matmul_t_at(level, &b),
                                      &a.matmul_t_at(SimdLevel::Off, &b),
                                      64, 1e-12, &format!("matmul_t {}", level.name()));
            }
        });
    }

    #[test]
    fn prop_syrk_matches_matmul_t() {
        use crate::testutil::prop::Prop;
        Prop::new("syrk_vs_matmul_t").cases(15).run(|rng| {
            let n = 1 + (rng.next_u64() % 40) as usize;
            let k = 1 + (rng.next_u64() % 20) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let s = a.syrk();
            assert!(s.max_abs_diff(&a.matmul_t(&a)) < 1e-12);
            assert!(s.max_abs_diff(&s.t()) == 0.0, "syrk must be exactly symmetric");
        });
    }

    #[test]
    fn prop_syrk_t_weighted_matches_dense_reference() {
        use crate::testutil::prop::Prop;
        Prop::new("syrk_t_weighted").cases(15).run(|rng| {
            let n = 1 + (rng.next_u64() % 30) as usize;
            let k = 1 + (rng.next_u64() % 12) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let w: Vec<f64> = (0..n)
                .map(|_| if rng.uniform() < 0.75 { rng.uniform_range(0.1, 2.0) } else { 0.0 })
                .collect();
            // reference: (diag(w)·A)ᵀ · A
            let mut wa = a.clone();
            for i in 0..n {
                for j in 0..k {
                    wa[(i, j)] *= w[i];
                }
            }
            let want = wa.t_matmul(&a);
            let got = a.syrk_t_weighted(&w);
            assert!(got.max_abs_diff(&want) < 1e-12, "{n}x{k}");
            assert!(got.max_abs_diff(&got.t()) == 0.0);
        });
    }
}
