//! Dense row-major `f64` matrix — the workhorse of the M×M "indistributable
//! core" (bound assembly, predictions, the dense-GP baseline).
//!
//! Deliberately minimal: owned storage, explicit dimensions, no
//! broadcasting magic. Everything here is O(M²)/O(M³) leader-side work;
//! the O(N) data-parallel work lives in `math::stats` / the XLA artifacts.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Tile edge for the cache-blocked matmul: 64×64 f64 tiles are 32 KiB —
/// one operand tile per L1 slice, three per typical L2 way-set.
const MM_BLOCK: usize = 64;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero-filled `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a row-major data vector; panics on length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length {} != {}x{}",
                   data.len(), rows, cols);
        Mat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Column vector (n × 1) from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat::from_vec(v.len(), 1, v.to_vec())
    }

    /// Row count.
    pub fn rows(&self) -> usize { self.rows }
    /// Column count.
    pub fn cols(&self) -> usize { self.cols }
    /// Is this matrix square?
    pub fn is_square(&self) -> bool { self.rows == self.cols }

    /// Underlying row-major storage.
    pub fn as_slice(&self) -> &[f64] { &self.data }
    /// Mutable view of the row-major storage.
    pub fn as_mut_slice(&mut self) -> &mut [f64] { &mut self.data }
    /// Unwrap into the row-major data vector.
    pub fn into_vec(self) -> Vec<f64> { self.data }

    /// Overwrite the whole matrix from a row-major slice without
    /// reallocating (the wire-unpack hot path reuses one `Mat` per cycle
    /// instead of a fresh `from_vec`). Panics on length mismatch.
    pub fn set_from(&mut self, data: &[f64]) {
        assert_eq!(data.len(), self.rows * self.cols, "set_from length {} != {}x{}",
                   data.len(), self.rows, self.cols);
        self.data.copy_from_slice(data);
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// `self * other`. Dispatches to the cache-blocked kernel once the
    /// problem outgrows the last-level-friendly sizes; both kernels
    /// accumulate each output element in ascending-k order, so the
    /// results are bit-identical and the dispatch is invisible.
    pub fn matmul(&self, other: &Mat) -> Mat {
        if self.rows >= MM_BLOCK && self.cols >= MM_BLOCK && other.cols >= MM_BLOCK {
            self.matmul_blocked(other)
        } else {
            self.matmul_naive(other)
        }
    }

    /// `self * other` — naive triple loop with row-major-friendly order
    /// (the reference the blocked kernel is property-tested against).
    pub fn matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} * {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 { continue; }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `self * other`, cache-blocked: the k-blocks are the outer loop so
    /// each `MM_BLOCK × MM_BLOCK` tile of `other` stays L1/L2-resident
    /// while a block of output rows sweeps it. Per output element the
    /// accumulation order is still ascending k, so the result is
    /// bit-identical to [`Mat::matmul_naive`].
    pub fn matmul_blocked(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} * {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let (n, kk, m) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(n, m);
        for kb in (0..kk).step_by(MM_BLOCK) {
            let ke = (kb + MM_BLOCK).min(kk);
            for ib in (0..n).step_by(MM_BLOCK) {
                let ie = (ib + MM_BLOCK).min(n);
                for jb in (0..m).step_by(MM_BLOCK) {
                    let je = (jb + MM_BLOCK).min(m);
                    for i in ib..ie {
                        for k in kb..ke {
                            let a = self.data[i * kk + k];
                            if a == 0.0 { continue; }
                            let orow = &other.data[k * m + jb..k * m + je];
                            let out_row = &mut out.data[i * m + jb..i * m + je];
                            for (o, &b) in out_row.iter_mut().zip(orow) {
                                *o += a * b;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// `selfᵀ * other` without materialising the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let srow = self.row(k);
            let orow = other.row(k);
            for i in 0..self.cols {
                let a = srow[i];
                if a == 0.0 { continue; }
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Symmetric rank-k update `self * selfᵀ` (n×n from n×k): computes
    /// only the lower triangle and mirrors — half the flops of
    /// `matmul_t(self)`, bit-identical on the computed entries (same
    /// row-dot, ascending k). This is the Ψ2-shaped product at the heart
    /// of the leader's M×M core (`A⁻¹P (A⁻¹P)ᵀ`).
    pub fn syrk(&self) -> Mat {
        let n = self.rows;
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            let ri = self.row(i);
            for j in 0..=i {
                let rj = self.row(j);
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += ri[k] * rj[k];
                }
                out[(i, j)] = acc;
                out[(j, i)] = acc;
            }
        }
        out
    }

    /// Weighted Gram update `selfᵀ · diag(w) · self` (k×k from n×k):
    /// a row-wise symmetric rank-1 accumulation (upper triangle, then
    /// mirrored) — the syrk-style form of the SGPR Ψ2 statistic
    /// `Σ_n w_n k_n k_nᵀ`. Rows with `w == 0` are skipped entirely.
    pub fn syrk_t_weighted(&self, w: &[f64]) -> Mat {
        assert_eq!(w.len(), self.rows);
        let k = self.cols;
        let mut out = Mat::zeros(k, k);
        for row in 0..self.rows {
            if w[row] == 0.0 { continue; }
            let r = self.row(row);
            for i in 0..k {
                let a = w[row] * r[i];
                if a == 0.0 { continue; }
                let out_row = out.row_mut(i);
                for (j, &rv) in r.iter().enumerate().skip(i) {
                    out_row[j] += a * rv;
                }
            }
        }
        for i in 0..k {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
        out
    }

    /// `self * otherᵀ`.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let srow = self.row(i);
            for j in 0..other.rows {
                let orow = other.row(j);
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += srow[k] * orow[k];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    /// Element-wise in-place `self += c * other`.
    pub fn axpy(&mut self, c: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += c * b;
        }
    }

    /// `self * c` (copy).
    pub fn scale(&self, c: f64) -> Mat {
        Mat::from_vec(self.rows, self.cols,
                      self.data.iter().map(|v| v * c).collect())
    }

    /// In-place scale.
    pub fn scale_mut(&mut self, c: f64) {
        for v in &mut self.data { *v *= c; }
    }

    /// Add `c` to the diagonal in place.
    pub fn add_diag(&mut self, c: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            self.data[i * self.cols + i] += c;
        }
    }

    /// Sum of diagonal entries.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Frobenius inner product `sum_ij self_ij * other_ij`.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// `tr(self * other)` for square same-size matrices, without the product.
    pub fn trace_product(&self, other: &Mat) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut acc = 0.0;
        for i in 0..self.rows {
            for k in 0..self.cols {
                acc += self[(i, k)] * other[(k, i)];
            }
        }
        acc
    }

    /// Force exact symmetry: `(A + Aᵀ)/2`.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Max |a_ij - b_ij| — used all over the tests.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.iter().zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Elementwise map (copy).
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat::from_vec(self.rows, self.cols,
                      self.data.iter().map(|&v| f(v)).collect())
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 { writeln!(f, "  ...")?; }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        assert_eq!(Mat::eye(3).matmul(&a), a);
        assert_eq!(a.matmul(&Mat::eye(4)), a);
    }

    #[test]
    fn t_matmul_equals_explicit() {
        let a = Mat::from_fn(5, 3, |i, j| (i as f64 - j as f64) * 0.7);
        let b = Mat::from_fn(5, 2, |i, j| (i + 2 * j) as f64 * 0.3);
        assert!(a.t().matmul(&b).max_abs_diff(&a.t_matmul(&b)) < 1e-14);
    }

    #[test]
    fn matmul_t_equals_explicit() {
        let a = Mat::from_fn(4, 3, |i, j| (i * j) as f64 + 1.0);
        let b = Mat::from_fn(5, 3, |i, j| i as f64 - 0.5 * j as f64);
        assert!(a.matmul(&b.t()).max_abs_diff(&a.matmul_t(&b)) < 1e-14);
    }

    #[test]
    fn trace_and_trace_product() {
        let a = Mat::from_fn(3, 3, |i, j| (i + j) as f64);
        let b = Mat::from_fn(3, 3, |i, j| (i as f64) * 0.5 - j as f64);
        let ab = a.matmul(&b);
        assert!((a.trace_product(&b) - ab.trace()).abs() < 1e-13);
    }

    #[test]
    fn symmetrize_is_symmetric() {
        let mut a = Mat::from_fn(4, 4, |i, j| (3 * i + j) as f64);
        a.symmetrize();
        assert!(a.max_abs_diff(&a.t()) == 0.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn prop_blocked_matmul_bit_identical_to_naive() {
        // Sizes straddle the 64-wide tile edge (including ragged tails and
        // degenerate dims); ascending-k accumulation makes the two kernels
        // agree exactly, not just within tolerance.
        use crate::testutil::prop::Prop;
        Prop::new("matmul_blocked_vs_naive").cases(12).run(|rng| {
            let n = 1 + (rng.next_u64() % 150) as usize;
            let k = 1 + (rng.next_u64() % 150) as usize;
            let m = 1 + (rng.next_u64() % 150) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, m, |_, _| rng.normal());
            let diff = a.matmul_naive(&b).max_abs_diff(&a.matmul_blocked(&b));
            assert!(diff == 0.0, "{n}x{k}x{m}: diff {diff}");
        });
    }

    #[test]
    fn matmul_dispatch_is_invisible() {
        // Above the dispatch threshold matmul() takes the blocked path;
        // verify against the naive reference on a 130³ product.
        let mut rng = crate::testutil::prop::Rng64::new(91);
        let a = Mat::from_fn(130, 130, |_, _| rng.normal());
        let b = Mat::from_fn(130, 130, |_, _| rng.normal());
        assert!(a.matmul(&b).max_abs_diff(&a.matmul_naive(&b)) == 0.0);
    }

    #[test]
    fn prop_syrk_matches_matmul_t() {
        use crate::testutil::prop::Prop;
        Prop::new("syrk_vs_matmul_t").cases(15).run(|rng| {
            let n = 1 + (rng.next_u64() % 40) as usize;
            let k = 1 + (rng.next_u64() % 20) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let s = a.syrk();
            assert!(s.max_abs_diff(&a.matmul_t(&a)) < 1e-12);
            assert!(s.max_abs_diff(&s.t()) == 0.0, "syrk must be exactly symmetric");
        });
    }

    #[test]
    fn prop_syrk_t_weighted_matches_dense_reference() {
        use crate::testutil::prop::Prop;
        Prop::new("syrk_t_weighted").cases(15).run(|rng| {
            let n = 1 + (rng.next_u64() % 30) as usize;
            let k = 1 + (rng.next_u64() % 12) as usize;
            let a = Mat::from_fn(n, k, |_, _| rng.normal());
            let w: Vec<f64> = (0..n)
                .map(|_| if rng.uniform() < 0.75 { rng.uniform_range(0.1, 2.0) } else { 0.0 })
                .collect();
            // reference: (diag(w)·A)ᵀ · A
            let mut wa = a.clone();
            for i in 0..n {
                for j in 0..k {
                    wa[(i, j)] *= w[i];
                }
            }
            let want = wa.t_matmul(&a);
            let got = a.syrk_t_weighted(&w);
            assert!(got.max_abs_diff(&want) < 1e-12, "{n}x{k}");
            assert!(got.max_abs_diff(&got.t()) == 0.0);
        });
    }
}
