//! Dense linear algebra substrate (row-major `f64`).
//!
//! Implemented in-repo because the paper's "indistributable core"
//! (`(βΦ + K_uu)⁻¹`, log-determinants, the predictive equations) needs a
//! Cholesky + triangular-solve toolkit and nothing heavier; matrices here
//! are M×M with M ≈ 100, so clarity beats BLAS.

mod chol;
mod matrix;
pub mod simd;

pub use chol::{Chol, NotPositiveDefinite};
pub use matrix::Mat;
pub use simd::SimdLevel;

/// Mean of a slice (helper shared by metrics/benches).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return 0.0; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 { return 0.0; }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Euclidean norm of a vector.
pub fn norm2(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Dot product of two equal-length slices.
pub fn vdot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_helpers() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(vdot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
