//! Configuration substrate: JSON (in-repo, offline stand-in for
//! serde_json) and the run-configuration structs shared by the CLI,
//! examples and benches.

pub mod json;

pub use json::Json;

use crate::optim::lbfgs::Lbfgs;

/// Which backend computes the per-worker statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Scalar Rust loops — the per-core "CPU node" analog.
    RustCpu,
    /// Scalar Rust loops fanned across scoped threads *within* a rank —
    /// the paper's "multicore node". `threads == 0` means one thread per
    /// available core. Produces bit-identical statistics to `RustCpu`.
    ParallelCpu {
        threads: usize,
    },
    /// AOT-compiled XLA executable on PJRT — the "GPU card" analog.
    Xla,
}

impl BackendKind {
    /// Intra-rank chunk parallelism with auto-detected thread count.
    pub const fn parallel_auto() -> BackendKind {
        BackendKind::ParallelCpu { threads: 0 }
    }

    /// Parse a CLI spelling: `cpu`, `parallel`, `parallel:N`, `xla`
    /// (plus aliases); `None` when unrecognised.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "cpu" | "rust" | "rust-cpu" => Some(BackendKind::RustCpu),
            "parallel" | "parallel-cpu" | "multicore" => Some(BackendKind::parallel_auto()),
            "xla" | "gpu" | "device" => Some(BackendKind::Xla),
            _ => {
                // "parallel:N" pins the intra-rank thread count.
                let n = s.strip_prefix("parallel:")?.parse().ok()?;
                Some(BackendKind::ParallelCpu { threads: n })
            }
        }
    }

    /// Canonical display name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::RustCpu => "rust-cpu",
            BackendKind::ParallelCpu { .. } => "parallel-cpu",
            BackendKind::Xla => "xla",
        }
    }
}

/// A full training-run configuration (the launcher's input).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Worker count (simulated MPI ranks).
    pub workers: usize,
    /// Datapoints per fixed-shape chunk (must match an AOT config for
    /// the Xla backend).
    pub chunk: usize,
    /// Which backend computes the per-worker statistics.
    pub backend: BackendKind,
    /// Inducing point count M.
    pub m: usize,
    /// Latent dimensionality Q.
    pub q: usize,
    /// Optimiser iteration budget.
    pub max_iters: usize,
    /// Artifact directory (manifest + *.hlo.txt).
    pub artifacts_dir: std::path::PathBuf,
    /// AOT config name (e.g. "paper") for the Xla backend.
    pub aot_config: String,
    /// RNG seed (datasets, initialisation, partitions).
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workers: 1,
            chunk: 1024,
            backend: BackendKind::RustCpu,
            m: 100,
            q: 1,
            max_iters: 100,
            artifacts_dir: "artifacts".into(),
            aot_config: "paper".into(),
            seed: 0,
        }
    }
}

impl RunConfig {
    /// The optimiser this configuration implies.
    pub fn optimizer(&self) -> Lbfgs {
        Lbfgs { max_iters: self.max_iters, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(BackendKind::parse("cpu"), Some(BackendKind::RustCpu));
        assert_eq!(BackendKind::parse("gpu"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("parallel"),
                   Some(BackendKind::ParallelCpu { threads: 0 }));
        assert_eq!(BackendKind::parse("parallel:4"),
                   Some(BackendKind::ParallelCpu { threads: 4 }));
        assert_eq!(BackendKind::parse("parallel:x"), None);
        assert_eq!(BackendKind::parse("tpu"), None);
    }
}
