//! Minimal JSON parser/serialiser (offline stand-in for serde_json) —
//! used for the artifact manifest, run configs and bench result files.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers are f64 (which is all the manifest needs).

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64, like the manifest's dtype).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic serialisation).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors (ergonomics for manifest reading) ----

    /// Object member lookup (`None` on non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value as a usize, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).map(|n| n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialise (keys sorted — BTreeMap — so output is deterministic).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {} (found {:?})", c as char, self.i,
                  self.peek().map(|b| b as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).unwrap_or(b""))?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected , or ] (found {other:?})"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} (found {other:?})"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"version": 1, "modules": [{"file": "a.hlo.txt",
            "dims": {"c": 64, "m": 16}, "inputs": [{"name": "mu",
            "shape": [64, 2]}]}], "ok": true, "x": null}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let m = &j.get("modules").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(m.get("dims").unwrap().get("c").unwrap().as_usize(), Some(64));
        let shape = m.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape.len(), 2);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("x"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": {"d": false}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café \t \"q\"""#).unwrap();
        assert_eq!(j.as_str(), Some("café \t \"q\""));
    }

    #[test]
    fn numbers() {
        for (t, v) in [("0", 0.0), ("-1.5", -1.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(v), "{t}");
        }
    }
}
