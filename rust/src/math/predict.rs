//! The precomputed sparse-GP posterior and its per-row predictive
//! equations — the compute core shared by the single-node
//! [`Posterior`](crate::models::Posterior) and the sharded serving path
//! ([`DistributedPosterior`](crate::coordinator::engine::serve::DistributedPosterior)).
//!
//! With `A = K_uu + βΦ` and `P = ΨᵀY`:
//!
//! ```text
//!   mean(x*) = β k*uᵀ A⁻¹ P
//!   var(x*)  = k** − k*uᵀ (K_uu⁻¹ − A⁻¹) k*u + β⁻¹
//! ```
//!
//! (the standard variational-sparse posterior, e.g. Titsias 2009 eq. 6).
//! Every prediction row is independent of every other row, which is what
//! makes the posterior *embarrassingly shardable*: the serving layer
//! broadcasts one [`PosteriorCore`] and partitions the test rows, and
//! because [`PosteriorCore::predict_rows_into`] is the single per-row
//! implementation used everywhere, sharded output is **bit-identical**
//! to single-node output by construction (no cross-row reductions
//! exist to reorder).

use crate::kern::RbfArd;
use crate::linalg::{Chol, Mat};
use crate::math::stats::Stats;
use anyhow::{Context, Result};

/// Sanity cap on any single wire-header dimension (Q, M, D). Far above
/// any model this system can hold in memory, far below anything whose
/// products could lose integer precision in f64 (2^24 squared is 2^48 <
/// 2^53) — a header outside it is wire corruption, not a big model.
const MAX_WIRE_DIM: f64 = 16_777_216.0; // 2^24

/// Parse one wire-header dimension. The header travels as f64, and a
/// corrupt swap wire can carry literally any bit pattern here — `as
/// usize` on a NaN or negative saturates to 0 and on 1e300 to
/// `usize::MAX`, either of which would drive the downstream slice
/// arithmetic out of bounds and panic the worker thread (tearing down
/// the whole cluster). So: finite, integral, in `[0, MAX_WIRE_DIM]`, or
/// a clean `Err` the poison path already knows how to absorb.
fn header_dim(v: f64, name: &str) -> Result<usize> {
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > MAX_WIRE_DIM {
        anyhow::bail!("posterior wire header: {name} = {v} is not a valid dimension");
    }
    Ok(v as usize)
}

/// Floor applied to every predictive variance. The exact expression
/// `k** − k*uᵀ(K_uu⁻¹ − A⁻¹)k*u + β⁻¹` is positive in exact arithmetic,
/// but cancellation between the two quadratic-form terms can drive it a
/// few ulps negative for test points deep inside dense training data;
/// clamping at a tiny positive value keeps downstream `sqrt`/`ln` calls
/// (log-likelihoods, confidence intervals) well defined.
pub const MIN_PREDICTIVE_VARIANCE: f64 = 1e-12;

/// Precomputed posterior state for fast repeated prediction: everything
/// the predictive equations need, with the two M×M solves already done.
///
/// The struct is plain data (kernel + matrices), so it can be packed
/// onto a collective wire ([`PosteriorCore::pack_into`]) and broadcast to
/// serving ranks once, then applied to any number of prediction batches.
#[derive(Clone, Debug)]
pub struct PosteriorCore {
    /// Fitted kernel (supplies `k*u` rows and the `k**` diagonal).
    pub kern: RbfArd,
    /// Inducing inputs, M × Q.
    pub z: Mat,
    /// Noise precision β.
    pub beta: f64,
    /// `A⁻¹ P` (M × D).
    pub ainv_p: Mat,
    /// `K_uu⁻¹ − A⁻¹` (M × M) — the Woodbury variance correction.
    pub woodbury: Mat,
}

impl PosteriorCore {
    /// Build from fitted parameters and reduced statistics: factor
    /// `K_uu` and `A = K_uu + βΦ` once, precompute `A⁻¹P` and the
    /// Woodbury matrix.
    pub fn new(kern: RbfArd, z: Mat, beta: f64, stats: &Stats) -> Result<PosteriorCore> {
        let kuu = kern.kuu(&z);
        let mut a = stats.psi2.scale(beta);
        a.axpy(1.0, &kuu);
        let (lk, _) = Chol::new_with_jitter(&kuu, 6).context("K_uu")?;
        let (la, _) = Chol::new_with_jitter(&a, 6).context("A")?;
        let ainv_p = la.solve(&stats.p);
        let mut woodbury = lk.inverse();
        woodbury.axpy(-1.0, &la.inverse());
        Ok(PosteriorCore { kern, z, beta, ainv_p, woodbury })
    }

    /// Latent dimensionality Q.
    pub fn q(&self) -> usize {
        self.z.cols()
    }

    /// Inducing-point count M.
    pub fn m(&self) -> usize {
        self.z.rows()
    }

    /// Output dimensionality D.
    pub fn d(&self) -> usize {
        self.ainv_p.cols()
    }

    /// Predictive mean and variance for rows `[row0, row0 + rows)` of
    /// `xstar`, written into `mean_out` (`rows × D`, row-major) and
    /// `var_out` (`rows`; includes the β⁻¹ noise term, floored at
    /// [`MIN_PREDICTIVE_VARIANCE`]).
    ///
    /// This is the one per-row implementation of the predictive
    /// equations; the single-node `Posterior`, both CPU backends and the
    /// sharded serving loop all call it, so their outputs agree bit for
    /// bit. `k**` is routed through [`RbfArd::kdiag_at`] rather than
    /// reading the variance field directly, so a future non-stationary
    /// kernel cannot silently miscompute the variance. The only per-call
    /// allocation is one M-length `k*u` scratch row.
    pub fn predict_rows_into(&self, xstar: &Mat, row0: usize, rows: usize,
                             mean_out: &mut [f64], var_out: &mut [f64]) {
        let m = self.m();
        let d = self.d();
        assert_eq!(xstar.cols(), self.q(), "xstar Q mismatch");
        assert!(row0 + rows <= xstar.rows(), "row range out of bounds");
        assert_eq!(mean_out.len(), rows * d, "mean_out length");
        assert_eq!(var_out.len(), rows, "var_out length");

        let mut ks = vec![0.0; m];
        for i in 0..rows {
            let x = xstar.row(row0 + i);
            self.kern.k_row_into(x, &self.z, &mut ks);

            // mean row: β · k*uᵀ (A⁻¹P), accumulated in ascending-j order
            let mrow = &mut mean_out[i * d..(i + 1) * d];
            mrow.fill(0.0);
            for (j, &k) in ks.iter().enumerate() {
                let prow = self.ainv_p.row(j);
                for (mv, &pv) in mrow.iter_mut().zip(prow) {
                    *mv += k * pv;
                }
            }
            for mv in mrow.iter_mut() {
                *mv *= self.beta;
            }

            // variance: k** − Σ_j (Σ_l k_l W_lj) k_j + β⁻¹
            let mut reduction = 0.0;
            for j in 0..m {
                let mut wk = 0.0;
                for l in 0..m {
                    wk += ks[l] * self.woodbury[(l, j)];
                }
                reduction += wk * ks[j];
            }
            let kss = self.kern.kdiag_at(x);
            var_out[i] = (kss - reduction + 1.0 / self.beta).max(MIN_PREDICTIVE_VARIANCE);
        }
    }

    // -----------------------------------------------------------------
    // wire form (for the serving broadcast: once at session open, and
    // again on every mid-session posterior hot-swap)
    // -----------------------------------------------------------------

    /// Wire length of a core with the given dimensions:
    /// `[q, m, d, β, σ²] ++ ℓ (Q) ++ Z (M·Q) ++ A⁻¹P (M·D) ++ W (M·M)`.
    pub fn wire_len(q: usize, m: usize, d: usize) -> usize {
        5 + q + m * q + m * d + m * m
    }

    /// [`wire_len`](PosteriorCore::wire_len) with overflow-checked
    /// arithmetic, for header values that are not yet trusted: `None`
    /// when any product or sum would wrap (which, in a release build,
    /// would otherwise alias a huge header onto a small wire length and
    /// send the unpack slices out of bounds).
    pub fn checked_wire_len(q: usize, m: usize, d: usize) -> Option<usize> {
        let mq = m.checked_mul(q)?;
        let md = m.checked_mul(d)?;
        let mm = m.checked_mul(m)?;
        5usize
            .checked_add(q)?
            .checked_add(mq)?
            .checked_add(md)?
            .checked_add(mm)
    }

    /// Append the wire form to `out`. Hyperparameters travel as raw
    /// values (not logs) so the unpacked kernel is bit-identical to the
    /// packed one — `exp(ln(x))` round-trips are not exact in f64.
    pub fn pack_into(&self, out: &mut Vec<f64>) {
        out.extend_from_slice(&[self.q() as f64, self.m() as f64, self.d() as f64,
                                self.beta, self.kern.variance]);
        out.extend_from_slice(&self.kern.lengthscales);
        out.extend_from_slice(self.z.as_slice());
        out.extend_from_slice(self.ainv_p.as_slice());
        out.extend_from_slice(self.woodbury.as_slice());
    }

    /// Parse a wire vector produced by [`PosteriorCore::pack_into`].
    ///
    /// The `(Q, M, D)` header is validated before any length arithmetic:
    /// a corrupt wire (NaN, negative, fractional or absurdly large
    /// header values) is an `Err` — which the serving poison path
    /// already handles — never an out-of-bounds slice panic on the
    /// worker thread.
    pub fn unpack(v: &[f64]) -> Result<PosteriorCore> {
        if v.len() < 5 {
            anyhow::bail!("posterior wire too short ({} elements)", v.len());
        }
        let q = header_dim(v[0], "Q")?;
        let m = header_dim(v[1], "M")?;
        let d = header_dim(v[2], "D")?;
        let want = Self::checked_wire_len(q, m, d).ok_or_else(|| {
            anyhow::anyhow!("posterior wire header (Q={q}, M={m}, D={d}) \
                             overflows the wire length")
        })?;
        if v.len() != want {
            anyhow::bail!("posterior wire length {} != {want} for (Q={q}, M={m}, D={d})",
                          v.len());
        }
        let beta = v[3];
        let variance = v[4];
        let mut off = 5;
        let lengthscales = v[off..off + q].to_vec();
        off += q;
        let z = Mat::from_vec(m, q, v[off..off + m * q].to_vec());
        off += m * q;
        let ainv_p = Mat::from_vec(m, d, v[off..off + m * d].to_vec());
        off += m * d;
        let woodbury = Mat::from_vec(m, m, v[off..].to_vec());
        Ok(PosteriorCore {
            kern: RbfArd::new(variance, lengthscales),
            z,
            beta,
            ainv_p,
            woodbury,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::sgpr_stats_fwd;
    use crate::testutil::prop::Rng64;

    fn toy_core(seed: u64, n: usize, m: usize, q: usize, d: usize) -> PosteriorCore {
        let mut rng = Rng64::new(seed);
        let x = Mat::from_fn(n, q, |_, _| rng.normal());
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        let kern = RbfArd::new(1.3, (0..q).map(|_| rng.uniform_range(0.6, 1.4)).collect());
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
        PosteriorCore::new(kern, z, 25.0, &st).unwrap()
    }

    /// The wire round-trip must reproduce the core bit for bit — raw
    /// hyperparameters, not logs, travel on the wire.
    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let core = toy_core(3, 30, 7, 2, 3);
        let mut wire = Vec::new();
        core.pack_into(&mut wire);
        assert_eq!(wire.len(), PosteriorCore::wire_len(2, 7, 3));
        let back = PosteriorCore::unpack(&wire).unwrap();
        assert_eq!(back.kern.variance, core.kern.variance);
        assert_eq!(back.kern.lengthscales, core.kern.lengthscales);
        assert_eq!(back.beta, core.beta);
        assert!(back.z.max_abs_diff(&core.z) == 0.0);
        assert!(back.ainv_p.max_abs_diff(&core.ainv_p) == 0.0);
        assert!(back.woodbury.max_abs_diff(&core.woodbury) == 0.0);

        let mut rng = Rng64::new(17);
        let xstar = Mat::from_fn(9, 2, |_, _| rng.normal());
        let (mut m1, mut v1) = (vec![0.0; 9 * 3], vec![0.0; 9]);
        let (mut m2, mut v2) = (vec![0.0; 9 * 3], vec![0.0; 9]);
        core.predict_rows_into(&xstar, 0, 9, &mut m1, &mut v1);
        back.predict_rows_into(&xstar, 0, 9, &mut m2, &mut v2);
        assert_eq!(m1, m2);
        assert_eq!(v1, v2);
    }

    /// Predicting a sub-range of rows must equal the matching slice of a
    /// whole-batch prediction (the sharding invariant).
    #[test]
    fn row_ranges_compose() {
        let core = toy_core(5, 40, 8, 1, 2);
        let mut rng = Rng64::new(23);
        let nt = 13;
        let xstar = Mat::from_fn(nt, 1, |_, _| rng.normal());
        let (mut mean_all, mut var_all) = (vec![0.0; nt * 2], vec![0.0; nt]);
        core.predict_rows_into(&xstar, 0, nt, &mut mean_all, &mut var_all);
        for (lo, hi) in [(0usize, 5usize), (5, 13), (12, 13)] {
            let rows = hi - lo;
            let (mut mn, mut vr) = (vec![0.0; rows * 2], vec![0.0; rows]);
            core.predict_rows_into(&xstar, lo, rows, &mut mn, &mut vr);
            assert_eq!(mn, mean_all[lo * 2..hi * 2], "mean rows {lo}..{hi}");
            assert_eq!(vr, var_all[lo..hi], "var rows {lo}..{hi}");
        }
    }

    #[test]
    fn malformed_wire_is_rejected() {
        assert!(PosteriorCore::unpack(&[1.0, 2.0]).is_err());
        let core = toy_core(7, 10, 3, 1, 1);
        let mut wire = Vec::new();
        core.pack_into(&mut wire);
        wire.pop();
        assert!(PosteriorCore::unpack(&wire).is_err());
    }

    /// Regression: the `(Q, M, D)` header floats come straight off a
    /// collective wire and used to be trusted — `as usize` on a NaN or
    /// negative saturates to 0, on 1e300 to `usize::MAX`, and the
    /// follow-on length arithmetic could wrap in release builds, driving
    /// the unpack slices out of bounds (a worker-thread panic tears the
    /// whole cluster down). Every corrupt header shape must be a clean
    /// `Err` instead.
    #[test]
    fn corrupt_headers_are_errors_not_panics() {
        let core = toy_core(11, 10, 3, 2, 1);
        let mut wire = Vec::new();
        core.pack_into(&mut wire);

        for (slot, bad) in [
            (0usize, f64::NAN),       // Q = NaN ("as usize" would give 0)
            (1, -3.0),                // M negative (would give 0)
            (2, 1e300),               // D huge (would give usize::MAX)
            (0, f64::INFINITY),       // Q infinite
            (1, 2.5),                 // M fractional (silent truncation)
            (2, 1e308),               // D huge again, different slot
        ] {
            let mut v = wire.clone();
            v[slot] = bad;
            let err = PosteriorCore::unpack(&v)
                .expect_err(&format!("header slot {slot} = {bad} must be rejected"));
            assert!(format!("{err:#}").contains("posterior wire header"),
                    "unhelpful error for slot {slot} = {bad}: {err:#}");
        }

        // in-bounds but mutually inconsistent header: the checked length
        // simply fails the exact-length comparison
        let mut v = wire.clone();
        v[1] = 1000.0; // M claims 1000 on a tiny wire
        assert!(PosteriorCore::unpack(&v).is_err());

        // the bound itself: one past MAX_WIRE_DIM is rejected up front
        let mut v = wire;
        v[0] = MAX_WIRE_DIM + 1.0;
        assert!(PosteriorCore::unpack(&v).is_err());
    }

    #[test]
    fn checked_wire_len_matches_trusted_and_catches_overflow() {
        assert_eq!(PosteriorCore::checked_wire_len(2, 7, 3),
                   Some(PosteriorCore::wire_len(2, 7, 3)));
        assert_eq!(PosteriorCore::checked_wire_len(0, 0, 0), Some(5));
        // usize::MAX² wraps; the checked path reports it instead
        assert_eq!(PosteriorCore::checked_wire_len(1, usize::MAX, 1), None);
    }

    #[test]
    fn variance_respects_floor() {
        let core = toy_core(9, 20, 5, 1, 1);
        let mut rng = Rng64::new(31);
        let xstar = Mat::from_fn(4, 1, |_, _| rng.normal());
        let (mut mean, mut var) = (vec![0.0; 4], vec![0.0; 4]);
        core.predict_rows_into(&xstar, 0, 4, &mut mean, &mut var);
        for v in var {
            assert!(v >= MIN_PREDICTIVE_VARIANCE);
        }
    }
}
