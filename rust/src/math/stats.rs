//! Per-chunk sufficient statistics and their VJP — the worker-side
//! (distributable) computation, in pure Rust.
//!
//! Everything reduced across workers is packed into flat `Vec<f64>`
//! wire vectors so the collectives can sum them element-wise; the pack /
//! unpack round-trip is unit-tested.

use crate::kern::RbfArd;
use crate::linalg::simd;
use crate::linalg::Mat;

/// The paper's global statistics: ψ0 (φ), P = Ψ1ᵀ(w∘Y) (the paper's Ψ),
/// Φ = Ψ2, plus tr(YᵀY) and the q(X) KL — everything the leader needs.
#[derive(Clone, Debug)]
pub struct Stats {
    /// ψ0 = Σ_n w_n ⟨k(x_n, x_n)⟩.
    pub psi0: f64,
    /// M × D.
    pub p: Mat,
    /// M × M.
    pub psi2: Mat,
    /// tr(Yᵀ diag(w) Y).
    pub tryy: f64,
    /// KL[q(X) ‖ p(X)] contribution (variational problems; view 0 only).
    pub kl: f64,
    /// Effective datapoint count Σw (reduced alongside the rest).
    pub n_eff: f64,
}

impl Stats {
    /// All-zero statistics of the given shape (the reducer identity).
    pub fn zeros(m: usize, d: usize) -> Self {
        Stats { psi0: 0.0, p: Mat::zeros(m, d), psi2: Mat::zeros(m, m),
                tryy: 0.0, kl: 0.0, n_eff: 0.0 }
    }

    /// Element-wise accumulate (the chunk-order reduction step).
    pub fn add_assign(&mut self, other: &Stats) {
        self.psi0 += other.psi0;
        self.p.axpy(1.0, &other.p);
        self.psi2.axpy(1.0, &other.psi2);
        self.tryy += other.tryy;
        self.kl += other.kl;
        self.n_eff += other.n_eff;
    }

    /// Flatten for `allreduce_sum` (order: scalars, P, Ψ2).
    pub fn pack(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(4 + self.p.as_slice().len() + self.psi2.as_slice().len());
        self.pack_into(&mut v);
        v
    }

    /// Append the wire form to `out` — the buffer-reusing pack the cycle
    /// calls every evaluation (same layout as [`pack`](Stats::pack)).
    pub fn pack_into(&self, out: &mut Vec<f64>) {
        out.extend_from_slice(&[self.psi0, self.tryy, self.kl, self.n_eff]);
        out.extend_from_slice(self.p.as_slice());
        out.extend_from_slice(self.psi2.as_slice());
    }

    /// Parse a wire vector produced by [`pack`](Stats::pack).
    pub fn unpack(m: usize, d: usize, v: &[f64]) -> Self {
        let mut st = Stats::zeros(m, d);
        st.unpack_from(v);
        st
    }

    /// Overwrite `self` from a wire slice without reallocating; shapes
    /// must match the wire length.
    pub fn unpack_from(&mut self, v: &[f64]) {
        let (m, d) = (self.p.rows(), self.p.cols());
        assert_eq!(v.len(), 4 + m * d + m * m, "stats wire length");
        self.psi0 = v[0];
        self.tryy = v[1];
        self.kl = v[2];
        self.n_eff = v[3];
        self.p.set_from(&v[4..4 + m * d]);
        self.psi2.set_from(&v[4 + m * d..]);
    }
}

/// Cotangents of the statistics — what the leader broadcasts back.
#[derive(Clone, Debug)]
pub struct StatsCts {
    /// ∂F/∂ψ0.
    pub c_psi0: f64,
    /// ∂F/∂P (M × D).
    pub c_p: Mat,
    /// ∂F/∂Ψ2 (M × M).
    pub c_psi2: Mat,
    /// ∂F/∂ tr(YᵀY).
    pub c_tryy: f64,
    /// ∂F/∂KL (−1 when the KL term is active).
    pub c_kl: f64,
}

impl StatsCts {
    /// All-zero cotangents of the given shape.
    pub fn zeros(m: usize, d: usize) -> Self {
        StatsCts { c_psi0: 0.0, c_p: Mat::zeros(m, d), c_psi2: Mat::zeros(m, m),
                   c_tryy: 0.0, c_kl: 0.0 }
    }

    /// Flatten to the broadcast wire (order: scalars, c_P, c_Ψ2).
    pub fn pack(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(3 + self.c_p.as_slice().len() + self.c_psi2.as_slice().len());
        self.pack_into(&mut v);
        v
    }

    /// Append the wire form to `out` (buffer-reusing pack).
    pub fn pack_into(&self, out: &mut Vec<f64>) {
        out.extend_from_slice(&[self.c_psi0, self.c_tryy, self.c_kl]);
        out.extend_from_slice(self.c_p.as_slice());
        out.extend_from_slice(self.c_psi2.as_slice());
    }

    /// Parse a wire vector produced by [`pack`](StatsCts::pack).
    pub fn unpack(m: usize, d: usize, v: &[f64]) -> Self {
        let mut cts = StatsCts::zeros(m, d);
        cts.unpack_from(v);
        cts
    }

    /// Overwrite `self` from a wire slice without reallocating.
    pub fn unpack_from(&mut self, v: &[f64]) {
        let (m, d) = (self.c_p.rows(), self.c_p.cols());
        assert_eq!(v.len(), 3 + m * d + m * m, "cts wire length");
        self.c_psi0 = v[0];
        self.c_tryy = v[1];
        self.c_kl = v[2];
        self.c_p.set_from(&v[3..3 + m * d]);
        self.c_psi2.set_from(&v[3 + m * d..]);
    }
}

/// Gradients a worker produces for its chunk: local (μ, S) plus its
/// partial contribution to the global (Z, hyp) gradients.
#[derive(Clone, Debug)]
pub struct ChunkGrads {
    /// C × Q (zero rows where the chunk mask is 0). Empty for SGPR.
    pub dmu: Mat,
    /// C × Q. Empty for SGPR.
    pub ds: Mat,
    /// M × Q partial.
    pub dz: Mat,
    /// Q+1 partial (w.r.t. log_hyp).
    pub dhyp: Vec<f64>,
}

// ---------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------

/// BGP-LVM chunk statistics (Rust backend). Shapes: mu,s `C×Q`; w `C`;
/// y `C×D`; z `M×Q`.
pub fn bgplvm_stats_fwd(kern: &RbfArd, mu: &Mat, s: &Mat, w: &[f64], y: &Mat,
                        z: &Mat) -> Stats {
    bgplvm_stats_fwd_cached(kern, mu, s, w, y, z).0
}

/// [`bgplvm_stats_fwd`] returning the Ψ1 matrix it already computed, so
/// the matching VJP can skip recomputing it (the fwd→vjp cache).
pub fn bgplvm_stats_fwd_cached(kern: &RbfArd, mu: &Mat, s: &Mat, w: &[f64], y: &Mat,
                               z: &Mat) -> (Stats, Mat) {
    let (m, d) = (z.rows(), y.cols());
    let c = mu.rows();
    let psi1 = kern.psi1(mu, s, z);

    // P = Ψ1ᵀ (w ∘ Y)
    let mut p = Mat::zeros(m, d);
    for n in 0..c {
        if w[n] == 0.0 {
            continue;
        }
        let prow = psi1.row(n);
        let yrow = y.row(n);
        for mm in 0..m {
            let pv = prow[mm] * w[n];
            simd::axpy(p.row_mut(mm), pv, yrow);
        }
    }

    let psi2 = kern.psi2(mu, s, w, z);
    let psi0 = kern.psi0(w);

    let mut tryy = 0.0;
    let mut kl = 0.0;
    let mut n_eff = 0.0;
    for n in 0..c {
        if w[n] == 0.0 {
            continue;
        }
        n_eff += w[n];
        let yrow = y.row(n);
        tryy += w[n] * simd::dot(yrow, yrow);
        for qq in 0..mu.cols() {
            let (mv, sv) = (mu[(n, qq)], s[(n, qq)]);
            kl += 0.5 * w[n] * (sv + mv * mv - 1.0 - sv.ln());
        }
    }
    (Stats { psi0, p, psi2, tryy, kl, n_eff }, psi1)
}

/// Supervised chunk statistics: S ≡ 0, no KL. At S = 0 the psi
/// statistics collapse to the exact kernel — Ψ1 = K_fu and
/// Ψ2 = K_ufᵀ diag(w) K_fu — so the forward pass uses one kernel
/// cross-covariance plus a syrk-style weighted Gram update instead of the
/// general exp-pair loop (O(C·M²) mults vs O(C·M²·Q) exps).
pub fn sgpr_stats_fwd(kern: &RbfArd, x: &Mat, w: &[f64], y: &Mat, z: &Mat) -> Stats {
    sgpr_stats_fwd_cached(kern, x, w, y, z).0
}

/// [`sgpr_stats_fwd`] returning the K_fu matrix it already computed —
/// mathematically Ψ1 at S = 0, reusable by the matching VJP. (K_fu and
/// the general Ψ1 loop at S = 0 agree to rounding error, not bitwise, so
/// the cached and cache-less supervised VJPs may differ in the last ulp.)
pub fn sgpr_stats_fwd_cached(kern: &RbfArd, x: &Mat, w: &[f64], y: &Mat,
                             z: &Mat) -> (Stats, Mat) {
    let d = y.cols();
    let c = x.rows();
    let kfu = kern.k(x, z);

    // P = K_ufᵀ (w ∘ Y)
    let mut wy = Mat::zeros(c, d);
    for n in 0..c {
        if w[n] == 0.0 {
            continue;
        }
        for (dst, &src) in wy.row_mut(n).iter_mut().zip(y.row(n)) {
            *dst = w[n] * src;
        }
    }
    let p = kfu.t_matmul(&wy);

    let psi2 = kfu.syrk_t_weighted(w);
    let psi0 = kern.psi0(w);

    let mut tryy = 0.0;
    let mut n_eff = 0.0;
    for n in 0..c {
        if w[n] == 0.0 {
            continue;
        }
        n_eff += w[n];
        tryy += w[n] * simd::dot(y.row(n), y.row(n));
    }
    // kl = 0: log S is −∞ at S=0; supervised bound has no KL term
    (Stats { psi0, p, psi2, tryy, kl: 0.0, n_eff }, kfu)
}

/// The **serial reference for the distributed stats-only pass**: the
/// full-data supervised statistics accumulated per fixed-shape chunk of
/// `chunk` rows, **in chunk order**, each chunk padded with zero rows
/// masked by w = 0 — exactly how the execution layer builds its
/// rank-resident chunks.
///
/// This is the summation-order discipline the engine's STATS verb
/// reproduces at every cluster size (each chunk's statistics occupy
/// their own slot of the reduction wire, and the leader folds the slots
/// in global chunk order), so the distributed pass is **bit-identical**
/// to this construction for any rank count and either CPU backend
/// (asserted in `rust/tests/serve_test.rs`). Note it is *not* bitwise
/// equal to the monolithic [`sgpr_stats_fwd`] over the full data —
/// floating-point addition is non-associative, so the chunk grouping
/// matters; this function pins the grouping once for everyone.
pub fn sgpr_stats_fwd_chunked(kern: &RbfArd, x: &Mat, w: &[f64], y: &Mat, z: &Mat,
                              chunk: usize) -> Stats {
    assert!(chunk > 0, "chunk must be positive");
    let (n, q, d, m) = (x.rows(), x.cols(), y.cols(), z.rows());
    assert_eq!(w.len(), n, "weight length");
    assert_eq!(y.rows(), n, "y rows");
    let mut acc = Stats::zeros(m, d);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let live = hi - lo;
        // pad to the fixed chunk shape, exactly like the engine's
        // resident chunks (zero rows, w = 0 mask)
        let mut xc = Mat::zeros(chunk, q);
        let mut yc = Mat::zeros(chunk, d);
        let mut wc = vec![0.0; chunk];
        for i in 0..live {
            xc.row_mut(i).copy_from_slice(x.row(lo + i));
            yc.row_mut(i).copy_from_slice(y.row(lo + i));
            wc[i] = w[lo + i];
        }
        acc.add_assign(&sgpr_stats_fwd(kern, &xc, &wc, &yc, z));
        lo = hi;
    }
    acc
}

// ---------------------------------------------------------------------
// VJP
// ---------------------------------------------------------------------

/// Pull the leader's cotangents back to the chunk's parameters (BGP-LVM).
pub fn bgplvm_stats_vjp(kern: &RbfArd, mu: &Mat, s: &Mat, w: &[f64], y: &Mat,
                        z: &Mat, cts: &StatsCts) -> ChunkGrads {
    stats_vjp_impl(kern, mu, s, w, y, z, cts, cts.c_kl, None)
}

/// [`bgplvm_stats_vjp`] reusing the forward pass's Ψ1 (`psi1` from
/// [`bgplvm_stats_fwd_cached`]) — bit-identical to recomputing, since the
/// forward and VJP Ψ1 loops are the same pure function of the inputs.
pub fn bgplvm_stats_vjp_cached(kern: &RbfArd, mu: &Mat, s: &Mat, w: &[f64], y: &Mat,
                               z: &Mat, cts: &StatsCts, psi1: Option<&Mat>)
                               -> ChunkGrads {
    stats_vjp_impl(kern, mu, s, w, y, z, cts, cts.c_kl, psi1)
}

/// Shared VJP body. `c_kl` is passed separately so the supervised path
/// can zero it without cloning the whole cotangent struct (the M×D and
/// M×M matrices stay borrowed). `psi1` is the optional fwd→vjp cache.
fn stats_vjp_impl(kern: &RbfArd, mu: &Mat, s: &Mat, w: &[f64], y: &Mat,
                  z: &Mat, cts: &StatsCts, c_kl: f64, psi1: Option<&Mat>)
                  -> ChunkGrads {
    let (c, q) = (mu.rows(), mu.cols());
    let m = z.rows();

    // c_P -> c_Ψ1: c_Ψ1[n, m] = w_n Σ_d c_P[m, d] y[n, d] — the Ψ1-VJP
    // cotangent build, an O(C·M·D) row-dot on the SIMD primitive.
    let mut c_psi1 = Mat::zeros(c, m);
    for n in 0..c {
        if w[n] == 0.0 {
            continue;
        }
        let yrow = y.row(n);
        for mm in 0..m {
            let acc = simd::dot(cts.c_p.row(mm), yrow);
            c_psi1[(n, mm)] = w[n] * acc;
        }
    }

    let (mut dmu, mut ds, mut dz, mut dhyp) = match psi1 {
        Some(p1) => kern.psi1_vjp_with(mu, s, z, &c_psi1, p1),
        None => kern.psi1_vjp(mu, s, z, &c_psi1),
    };
    let (dmu2, ds2, dz2, dhyp2) = kern.psi2_vjp(mu, s, w, z, &cts.c_psi2);
    dmu.axpy(1.0, &dmu2);
    ds.axpy(1.0, &ds2);
    dz.axpy(1.0, &dz2);
    for (a, b) in dhyp.iter_mut().zip(&dhyp2) {
        *a += b;
    }

    // ψ0 depends only on log σ²: ∂ψ0/∂logσ² = ψ0.
    dhyp[0] += cts.c_psi0 * kern.psi0(w);

    // KL term (c_kl is typically −1): ∂KL/∂μ = wμ, ∂KL/∂S = ½w(1 − 1/S).
    for n in 0..c {
        if w[n] == 0.0 {
            continue;
        }
        for qq in 0..q {
            dmu[(n, qq)] += c_kl * w[n] * mu[(n, qq)];
            ds[(n, qq)] += c_kl * 0.5 * w[n] * (1.0 - 1.0 / s[(n, qq)]);
        }
    }

    ChunkGrads { dmu, ds, dz, dhyp }
}

/// Supervised VJP: only (dZ, dhyp); the μ/S slots are returned empty.
pub fn sgpr_stats_vjp(kern: &RbfArd, x: &Mat, w: &[f64], y: &Mat, z: &Mat,
                      cts: &StatsCts) -> ChunkGrads {
    sgpr_stats_vjp_cached(kern, x, w, y, z, cts, None)
}

/// [`sgpr_stats_vjp`] reusing the forward pass's K_fu (`kfu` from
/// [`sgpr_stats_fwd_cached`]) as the Ψ1(S = 0) cache.
pub fn sgpr_stats_vjp_cached(kern: &RbfArd, x: &Mat, w: &[f64], y: &Mat, z: &Mat,
                             cts: &StatsCts, kfu: Option<&Mat>) -> ChunkGrads {
    let s0 = Mat::zeros(x.rows(), x.cols());
    let g = stats_vjp_impl(kern, x, &s0, w, y, z, cts, 0.0, kfu);
    ChunkGrads { dmu: Mat::zeros(0, 0), ds: Mat::zeros(0, 0), dz: g.dz, dhyp: g.dhyp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fd::{assert_grad_close, grad_fd};
    use crate::testutil::prop::{Prop, Rng64};

    fn setup(rng: &mut Rng64, c: usize, m: usize, q: usize, d: usize)
             -> (RbfArd, Mat, Mat, Vec<f64>, Mat, Mat) {
        let kern = RbfArd::new(rng.uniform_range(0.5, 1.5),
                               (0..q).map(|_| rng.uniform_range(0.6, 1.8)).collect());
        let mu = Mat::from_fn(c, q, |_, _| rng.normal());
        let s = Mat::from_fn(c, q, |_, _| rng.uniform_range(0.2, 1.2));
        let w: Vec<f64> = (0..c).map(|i| if i % 5 == 4 { 0.0 } else { 1.0 }).collect();
        let y = Mat::from_fn(c, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        (kern, mu, s, w, y, z)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng64::new(31);
        let (kern, mu, s, w, y, z) = setup(&mut rng, 9, 4, 2, 3);
        let st = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);
        let st2 = Stats::unpack(4, 3, &st.pack());
        assert_eq!(st.psi0, st2.psi0);
        assert_eq!(st.kl, st2.kl);
        assert!(st.p.max_abs_diff(&st2.p) == 0.0);
        assert!(st.psi2.max_abs_diff(&st2.psi2) == 0.0);
    }

    #[test]
    fn prop_chunked_equals_full() {
        // stats computed in two half-chunks sum to the full-chunk stats.
        Prop::new("stats_chunk_additivity").cases(10).run(|rng| {
            let (kern, mu, s, w, y, z) = setup(rng, 12, 5, 2, 3);
            let full = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);

            let take = |m: &Mat, lo: usize, hi: usize| {
                Mat::from_vec(hi - lo, m.cols(),
                              m.as_slice()[lo * m.cols()..hi * m.cols()].to_vec())
            };
            let mut acc = Stats::zeros(5, 3);
            for (lo, hi) in [(0, 7), (7, 12)] {
                let st = bgplvm_stats_fwd(&kern, &take(&mu, lo, hi), &take(&s, lo, hi),
                                          &w[lo..hi], &take(&y, lo, hi), &z);
                acc.add_assign(&st);
            }
            assert!((acc.psi0 - full.psi0).abs() < 1e-12);
            assert!((acc.kl - full.kl).abs() < 1e-11);
            assert!((acc.tryy - full.tryy).abs() < 1e-11);
            assert!(acc.p.max_abs_diff(&full.p) < 1e-12);
            assert!(acc.psi2.max_abs_diff(&full.psi2) < 1e-12);
        });
    }

    #[test]
    fn vjp_matches_finite_difference_through_projection() {
        let mut rng = Rng64::new(33);
        let (kern, mu, s, w, y, z) = setup(&mut rng, 6, 4, 2, 2);
        // random projection of the stats as a scalar objective
        let cp = Mat::from_fn(4, 2, |_, _| rng.normal());
        let cp2 = Mat::from_fn(4, 4, |_, _| rng.normal());
        let (a0, at, ak) = (rng.normal(), rng.normal(), rng.normal());
        let cts = StatsCts { c_psi0: a0, c_p: cp.clone(), c_psi2: cp2.clone(),
                             c_tryy: at, c_kl: ak };

        let obj = |kern: &RbfArd, mu: &Mat, s: &Mat, z: &Mat| {
            let st = bgplvm_stats_fwd(kern, mu, s, &w, &y, z);
            a0 * st.psi0 + st.p.dot(&cp) + st.psi2.dot(&cp2) + at * st.tryy + ak * st.kl
        };

        let g = bgplvm_stats_vjp(&kern, &mu, &s, &w, &y, &z, &cts);

        let f_mu = |x: &[f64]| obj(&kern, &Mat::from_vec(6, 2, x.to_vec()), &s, &z);
        assert_grad_close(g.dmu.as_slice(), &grad_fd(f_mu, mu.as_slice(), 1e-6),
                          2e-6, 1e-8, "stats/dmu");
        let f_s = |x: &[f64]| obj(&kern, &mu, &Mat::from_vec(6, 2, x.to_vec()), &z);
        assert_grad_close(g.ds.as_slice(), &grad_fd(f_s, s.as_slice(), 1e-6),
                          2e-6, 1e-8, "stats/ds");
        let f_z = |x: &[f64]| obj(&kern, &mu, &s, &Mat::from_vec(4, 2, x.to_vec()));
        assert_grad_close(g.dz.as_slice(), &grad_fd(f_z, z.as_slice(), 1e-6),
                          2e-6, 1e-8, "stats/dz");
        let lh = kern.to_log_hyp();
        let f_h = |x: &[f64]| obj(&RbfArd::from_log_hyp(x), &mu, &s, &z);
        assert_grad_close(&g.dhyp, &grad_fd(f_h, &lh, 1e-6), 2e-6, 1e-8, "stats/dhyp");
    }

    #[test]
    fn sgpr_fwd_has_no_kl_and_matches_exact_kernel() {
        let mut rng = Rng64::new(34);
        let (kern, x, _, w, y, z) = setup(&mut rng, 8, 3, 2, 2);
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
        assert_eq!(st.kl, 0.0);
        let kfu = kern.k(&x, &z);
        let mut p_want = Mat::zeros(3, 2);
        for n in 0..8 {
            for mm in 0..3 {
                for dd in 0..2 {
                    p_want[(mm, dd)] += w[n] * kfu[(n, mm)] * y[(n, dd)];
                }
            }
        }
        assert!(st.p.max_abs_diff(&p_want) < 1e-12);
    }

    #[test]
    fn prop_sgpr_fast_path_matches_general_psi_path() {
        // The syrk-based supervised forward must agree with the general
        // psi-statistics evaluated at S = 0.
        Prop::new("sgpr_fast_path").cases(10).run(|rng| {
            let (kern, x, _, w, y, z) = setup(rng, 11, 4, 2, 3);
            let fast = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
            let s0 = Mat::zeros(x.rows(), x.cols());
            let mut gen = bgplvm_stats_fwd(&kern, &x, &s0, &w, &y, &z);
            gen.kl = 0.0;
            assert!((fast.psi0 - gen.psi0).abs() < 1e-12);
            assert!((fast.tryy - gen.tryy).abs() < 1e-11);
            assert!((fast.n_eff - gen.n_eff).abs() == 0.0);
            assert!(fast.p.max_abs_diff(&gen.p) < 1e-12);
            assert!(fast.psi2.max_abs_diff(&gen.psi2) < 1e-12);
        });
    }

    /// The fwd→vjp cache must change nothing observable: bit-identical
    /// gradients for BGP-LVM (same Ψ1 bits both ways) and rounding-error
    /// agreement for the supervised K_fu form.
    #[test]
    fn prop_cached_vjp_matches_uncached() {
        Prop::new("stats_vjp_cached").cases(10).run(|rng| {
            let (kern, mu, s, w, y, z) = setup(rng, 10, 4, 2, 3);
            let cts = StatsCts {
                c_psi0: rng.normal(),
                c_p: Mat::from_fn(4, 3, |_, _| rng.normal()),
                c_psi2: Mat::from_fn(4, 4, |_, _| rng.normal()),
                c_tryy: rng.normal(),
                c_kl: rng.normal(),
            };

            let (st, psi1) = bgplvm_stats_fwd_cached(&kern, &mu, &s, &w, &y, &z);
            assert!(psi1.max_abs_diff(&kern.psi1(&mu, &s, &z)) == 0.0);
            let st2 = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);
            assert!(st.p.max_abs_diff(&st2.p) == 0.0 && st.psi0 == st2.psi0);

            let a = bgplvm_stats_vjp(&kern, &mu, &s, &w, &y, &z, &cts);
            let b = bgplvm_stats_vjp_cached(&kern, &mu, &s, &w, &y, &z, &cts, Some(&psi1));
            assert!(a.dmu.max_abs_diff(&b.dmu) == 0.0, "dmu");
            assert!(a.ds.max_abs_diff(&b.ds) == 0.0, "ds");
            assert!(a.dz.max_abs_diff(&b.dz) == 0.0, "dz");
            assert_eq!(a.dhyp, b.dhyp, "dhyp");

            let (st, kfu) = sgpr_stats_fwd_cached(&kern, &mu, &w, &y, &z);
            assert!(st.p.max_abs_diff(&sgpr_stats_fwd(&kern, &mu, &w, &y, &z).p) == 0.0);
            let a = sgpr_stats_vjp(&kern, &mu, &w, &y, &z, &cts);
            let b = sgpr_stats_vjp_cached(&kern, &mu, &w, &y, &z, &cts, Some(&kfu));
            assert!(a.dz.max_abs_diff(&b.dz) < 1e-11, "sgpr dz");
            for (x, yv) in a.dhyp.iter().zip(&b.dhyp) {
                assert!((x - yv).abs() < 1e-11 * (1.0 + x.abs()), "sgpr dhyp");
            }
        });
    }

    /// The chunked serial reference must agree with the monolithic pass
    /// to rounding error for any chunking, be exactly the monolithic
    /// pass when one chunk covers everything, and be invariant to the
    /// padding of the ragged tail.
    #[test]
    fn prop_chunked_reference_matches_monolithic() {
        Prop::new("sgpr_chunked_reference").cases(10).run(|rng| {
            let (kern, x, _, w, y, z) = setup(rng, 13, 4, 2, 3);
            let full = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
            // one covering chunk: identical construction, identical bits
            let whole = sgpr_stats_fwd_chunked(&kern, &x, &w, &y, &z, 13);
            assert_eq!(whole.psi0, full.psi0);
            assert_eq!(whole.tryy, full.tryy);
            assert!(whole.p.max_abs_diff(&full.p) == 0.0);
            assert!(whole.psi2.max_abs_diff(&full.psi2) == 0.0);
            for chunk in [1usize, 4, 5, 13, 40] {
                let c = sgpr_stats_fwd_chunked(&kern, &x, &w, &y, &z, chunk);
                assert!((c.psi0 - full.psi0).abs() < 1e-12, "chunk {chunk}");
                assert!((c.tryy - full.tryy).abs() < 1e-11, "chunk {chunk}");
                assert!((c.n_eff - full.n_eff).abs() == 0.0, "chunk {chunk}");
                assert!(c.p.max_abs_diff(&full.p) < 1e-12, "chunk {chunk}");
                assert!(c.psi2.max_abs_diff(&full.psi2) < 1e-12, "chunk {chunk}");
                assert_eq!(c.kl, 0.0, "chunk {chunk}");
            }
        });
    }

    #[test]
    fn sgpr_vjp_matches_fd() {
        let mut rng = Rng64::new(35);
        let (kern, x, _, w, y, z) = setup(&mut rng, 7, 3, 2, 2);
        let cp = Mat::from_fn(3, 2, |_, _| rng.normal());
        let cp2 = Mat::from_fn(3, 3, |_, _| rng.normal());
        let cts = StatsCts { c_psi0: 0.7, c_p: cp.clone(), c_psi2: cp2.clone(),
                             c_tryy: -0.3, c_kl: 0.0 };
        let obj = |kern: &RbfArd, z: &Mat| {
            let st = sgpr_stats_fwd(kern, &x, &w, &y, z);
            0.7 * st.psi0 + st.p.dot(&cp) + st.psi2.dot(&cp2) - 0.3 * st.tryy
        };
        let g = sgpr_stats_vjp(&kern, &x, &w, &y, &z, &cts);
        let f_z = |v: &[f64]| obj(&kern, &Mat::from_vec(3, 2, v.to_vec()));
        assert_grad_close(g.dz.as_slice(), &grad_fd(f_z, z.as_slice(), 1e-6),
                          2e-6, 1e-8, "sgpr/dz");
        let lh = kern.to_log_hyp();
        let f_h = |v: &[f64]| obj(&RbfArd::from_log_hyp(v), &z);
        assert_grad_close(&g.dhyp, &grad_fd(f_h, &lh, 1e-6), 2e-6, 1e-8, "sgpr/dhyp");
    }
}
