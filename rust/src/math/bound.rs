//! The leader's indistributable core: bound value + analytic gradients
//! from the reduced statistics (the Rust mirror of jax.grad over
//! `model.bound_from_stats`; derivation in DESIGN.md §5).
//!
//!   A = K_uu + β Φ,   P = ΨᵀY   (M×D)
//!   F = D/2 (N log β − N log 2π + logdet K_uu − logdet A)
//!       − β/2 trYY + β²/2 tr(Pᵀ A⁻¹ P) − βD/2 ψ0 + βD/2 tr(K_uu⁻¹ Φ) − KL

use super::stats::{Stats, StatsCts};
use crate::kern::RbfArd;
use crate::linalg::{Chol, Mat};
use anyhow::{Context, Result};

/// `ln(2π)` — the Gaussian normalisation constant.
pub const LOG2PI: f64 = 1.8378770664093453;

/// Everything the leader sends back: bound value, stat cotangents for the
/// workers, and the direct global-parameter gradients.
#[derive(Clone, Debug)]
pub struct BoundOut {
    /// The (maximised) variational bound F.
    pub f: f64,
    /// Cotangents of the reduced statistics (broadcast to workers).
    pub cts: StatsCts,
    /// Direct ∂F/∂Z (via K_uu only; workers add the Ψ-path partials).
    pub dz: Mat,
    /// Direct ∂F/∂log_hyp.
    pub dhyp: Vec<f64>,
    /// ∂F/∂log β (complete — β does not enter the worker statistics).
    pub dlog_beta: f64,
}

/// Compute F and all gradients at the leader. `log_beta` is the log noise
/// precision; `kern` carries (σ², ℓ).
pub fn bound_and_grads(stats: &Stats, z: &Mat, kern: &RbfArd, log_beta: f64)
                       -> Result<BoundOut> {
    let d = stats.p.cols();
    let d_f = d as f64;
    let n = stats.n_eff;
    let beta = log_beta.exp();

    let kuu = kern.kuu(z);
    let mut a = stats.psi2.scale(beta);
    a.axpy(1.0, &kuu);

    let (lk, _) = Chol::new_with_jitter(&kuu, 6).context("K_uu factorisation")?;
    let (la, _) = Chol::new_with_jitter(&a, 6).context("A = K_uu + βΦ factorisation")?;

    let logdet_kuu = lk.logdet();
    let logdet_a = la.logdet();

    let ainv_p = la.solve(&stats.p); // M × D
    let kuuinv_psi2 = lk.solve(&stats.psi2); // M × M
    let tr_kuuinv_psi2 = kuuinv_psi2.trace();
    let p_ainv_p = stats.p.dot(&ainv_p); // tr(Pᵀ A⁻¹ P)

    let f = 0.5 * d_f * (n * log_beta - n * LOG2PI + logdet_kuu - logdet_a)
        - 0.5 * beta * stats.tryy
        + 0.5 * beta * beta * p_ainv_p
        - 0.5 * beta * d_f * stats.psi0
        + 0.5 * beta * d_f * tr_kuuinv_psi2
        - stats.kl;

    // ---- gradients ----
    let ainv = la.inverse();
    let kuuinv = lk.inverse();

    // dF/dA = −D/2 A⁻¹ − β²/2 (A⁻¹P)(A⁻¹P)ᵀ
    let mut df_da = ainv.scale(-0.5 * d_f);
    let app = ainv_p.syrk(); // A⁻¹ P Pᵀ A⁻¹ — symmetric rank-k, half the flops
    df_da.axpy(-0.5 * beta * beta, &app);

    // cotangents for the workers
    let c_p = ainv_p.scale(beta * beta);
    let mut c_psi2 = df_da.scale(beta);
    c_psi2.axpy(0.5 * beta * d_f, &kuuinv);
    let cts = StatsCts {
        c_psi0: -0.5 * beta * d_f,
        c_p,
        c_psi2,
        c_tryy: -0.5 * beta,
        c_kl: -1.0,
    };

    // dF/dK_uu = D/2 K_uu⁻¹ + dF/dA − βD/2 K_uu⁻¹ Φ K_uu⁻¹
    let mut df_dkuu = kuuinv.scale(0.5 * d_f);
    df_dkuu.axpy(1.0, &df_da);
    let kik = lk.solve(&kuuinv_psi2.t()); // K⁻¹ Φᵀ K⁻¹ = K⁻¹ Φ K⁻¹ (Φ sym)
    df_dkuu.axpy(-0.5 * beta * d_f, &kik);

    let (dz, dhyp) = kern.kuu_vjp(z, &df_dkuu);

    // dF/dβ, then × β for log-space.
    let tr_ainv_psi2 = ainv.trace_product(&stats.psi2);
    let tr_app_psi2 = app.trace_product(&stats.psi2);
    let df_dbeta = 0.5 * d_f * n / beta
        - 0.5 * d_f * tr_ainv_psi2
        - 0.5 * stats.tryy
        + beta * p_ainv_p
        - 0.5 * beta * beta * tr_app_psi2
        - 0.5 * d_f * stats.psi0
        + 0.5 * d_f * tr_kuuinv_psi2;
    let dlog_beta = beta * df_dbeta;

    Ok(BoundOut { f, cts, dz, dhyp, dlog_beta })
}

/// Bound value only (no gradients) — for line-search style probes and
/// tests that perturb single inputs.
pub fn bound_value(stats: &Stats, z: &Mat, kern: &RbfArd, log_beta: f64) -> Result<f64> {
    let d_f = stats.p.cols() as f64;
    let n = stats.n_eff;
    let beta = log_beta.exp();
    let kuu = kern.kuu(z);
    let mut a = stats.psi2.scale(beta);
    a.axpy(1.0, &kuu);
    let (lk, _) = Chol::new_with_jitter(&kuu, 6).context("K_uu")?;
    let (la, _) = Chol::new_with_jitter(&a, 6).context("A")?;
    let ainv_p = la.solve(&stats.p);
    Ok(0.5 * d_f * (n * log_beta - n * LOG2PI + lk.logdet() - la.logdet())
        - 0.5 * beta * stats.tryy
        + 0.5 * beta * beta * stats.p.dot(&ainv_p)
        - 0.5 * beta * d_f * stats.psi0
        + 0.5 * beta * d_f * lk.solve(&stats.psi2).trace()
        - stats.kl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::bgplvm_stats_fwd;
    use crate::testutil::fd::{assert_grad_close, grad_fd};
    use crate::testutil::prop::Rng64;

    fn problem(seed: u64) -> (RbfArd, Mat, Mat, Vec<f64>, Mat, Mat, f64) {
        let mut rng = Rng64::new(seed);
        let (c, m, q, d) = (14, 5, 2, 3);
        let kern = RbfArd::new(rng.uniform_range(0.5, 1.5),
                               (0..q).map(|_| rng.uniform_range(0.6, 1.6)).collect());
        let mu = Mat::from_fn(c, q, |_, _| rng.normal());
        let s = Mat::from_fn(c, q, |_, _| rng.uniform_range(0.2, 1.0));
        let w = vec![1.0; c];
        let y = Mat::from_fn(c, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal() * 1.2);
        let log_beta = rng.uniform_range(-0.5, 0.8);
        (kern, mu, s, w, y, z, log_beta)
    }

    #[test]
    fn value_matches_value_and_grads() {
        let (kern, mu, s, w, y, z, lb) = problem(41);
        let st = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);
        let out = bound_and_grads(&st, &z, &kern, lb).unwrap();
        let v = bound_value(&st, &z, &kern, lb).unwrap();
        assert!((out.f - v).abs() < 1e-10);
        assert!(v.is_finite());
    }

    #[test]
    fn stat_cotangents_match_fd() {
        let (kern, mu, s, w, y, z, lb) = problem(42);
        let st = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);
        let out = bound_and_grads(&st, &z, &kern, lb).unwrap();
        let eps = 1e-6;

        // scalar stats
        for (ct, field) in [(out.cts.c_psi0, "psi0"), (out.cts.c_tryy, "tryy"),
                            (out.cts.c_kl, "kl")] {
            let mut sp = st.clone();
            let mut sm = st.clone();
            match field {
                "psi0" => { sp.psi0 += eps; sm.psi0 -= eps; }
                "tryy" => { sp.tryy += eps; sm.tryy -= eps; }
                _ => { sp.kl += eps; sm.kl -= eps; }
            }
            let fd = (bound_value(&sp, &z, &kern, lb).unwrap()
                      - bound_value(&sm, &z, &kern, lb).unwrap()) / (2.0 * eps);
            assert!((ct - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                    "{field}: {ct} vs {fd}");
        }

        // P matrix cotangent (spot-check entries)
        for (i, j) in [(0, 0), (2, 1), (4, 2)] {
            let mut sp = st.clone();
            sp.p[(i, j)] += eps;
            let mut sm = st.clone();
            sm.p[(i, j)] -= eps;
            let fd = (bound_value(&sp, &z, &kern, lb).unwrap()
                      - bound_value(&sm, &z, &kern, lb).unwrap()) / (2.0 * eps);
            let ct = out.cts.c_p[(i, j)];
            assert!((ct - fd).abs() < 1e-5 * (1.0 + fd.abs()), "c_p[{i},{j}]: {ct} vs {fd}");
        }

        // Ψ2 cotangent: perturb symmetrically (Ψ2 is constrained symmetric),
        // fd = c[i,j] + c[j,i] for i≠j.
        for (i, j) in [(0, 0), (1, 3), (2, 4)] {
            let mut sp = st.clone();
            sp.psi2[(i, j)] += eps;
            if i != j { sp.psi2[(j, i)] += eps; }
            let mut sm = st.clone();
            sm.psi2[(i, j)] -= eps;
            if i != j { sm.psi2[(j, i)] -= eps; }
            let fd = (bound_value(&sp, &z, &kern, lb).unwrap()
                      - bound_value(&sm, &z, &kern, lb).unwrap()) / (2.0 * eps);
            let ct = out.cts.c_psi2[(i, j)] + if i != j { out.cts.c_psi2[(j, i)] } else { 0.0 };
            assert!((ct - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "c_psi2[{i},{j}]: {ct} vs {fd}");
        }
    }

    #[test]
    fn direct_z_hyp_beta_grads_match_fd() {
        let (kern, mu, s, w, y, z, lb) = problem(43);
        let st = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);
        let out = bound_and_grads(&st, &z, &kern, lb).unwrap();

        // Z (direct path: stats held fixed)
        let f_z = |v: &[f64]| {
            let zz = Mat::from_vec(5, 2, v.to_vec());
            bound_value(&st, &zz, &kern, lb).unwrap()
        };
        assert_grad_close(out.dz.as_slice(), &grad_fd(f_z, z.as_slice(), 1e-6),
                          1e-5, 1e-8, "bound/dz");

        // log_hyp (direct)
        let lh = kern.to_log_hyp();
        let f_h = |v: &[f64]| {
            bound_value(&st, &z, &RbfArd::from_log_hyp(v), lb).unwrap()
        };
        assert_grad_close(&out.dhyp, &grad_fd(f_h, &lh, 1e-6), 1e-5, 1e-8, "bound/dhyp");

        // log β
        let f_b = |v: &[f64]| bound_value(&st, &z, &kern, v[0]).unwrap();
        assert_grad_close(&[out.dlog_beta], &grad_fd(f_b, &[lb], 1e-7),
                          1e-6, 1e-9, "bound/dlogbeta");
    }

    #[test]
    fn more_inducing_points_tighten_bound() {
        // Adding inducing points (a superset Z) should not decrease the
        // optimal bound materially; check the bound is finite + ordered
        // for nested Z on a fixed dataset.
        let (kern, mu, s, w, y, _, lb) = problem(44);
        let z_small = Mat::from_fn(3, 2, |i, j| (i as f64 - 1.0) + 0.1 * j as f64);
        let z_big = Mat::from_fn(6, 2, |i, j| (i as f64 - 2.5) * 0.8 + 0.1 * j as f64);
        let st_s = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z_small);
        let st_b = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z_big);
        let f_s = bound_value(&st_s, &z_small, &kern, lb).unwrap();
        let f_b = bound_value(&st_b, &z_big, &kern, lb).unwrap();
        assert!(f_s.is_finite() && f_b.is_finite());
        assert!(f_b > f_s - 5.0, "wildly looser with more inducing points: {f_s} vs {f_b}");
    }
}
