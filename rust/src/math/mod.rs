//! Model mathematics in Rust — the second, independent implementation of
//! everything `python/compile/model.py` lowers to HLO.
//!
//! Three jobs:
//! 1. the **RustCpu backend**: scalar per-worker statistics (`stats`),
//!    playing the role GPy's NumPy code plays in the paper's CPU runs;
//! 2. the **leader core** (`bound`): the indistributable M×M bound +
//!    analytic gradient assembly (the Rust mirror of jax.grad over eq. 3);
//! 3. the **posterior core** (`predict`): the precomputed predictive
//!    state + per-row predictive equations shared by single-node and
//!    sharded serving.
//!
//! The two statistics paths (Rust here, XLA artifacts from L2) are
//! cross-checked to ~1e-8 in `rust/tests/xla_vs_rust.rs`.

pub mod bound;
pub mod predict;
pub mod stats;

pub use bound::{bound_and_grads, BoundOut};
pub use predict::{PosteriorCore, MIN_PREDICTIVE_VARIANCE};
pub use stats::{ChunkGrads, Stats, StatsCts};
