//! Artifact manifest: what `python/compile/aot.py` lowered, with the
//! positional input/output specs the runtime validates against.

use crate::config::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor slot (positional) of a module.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Slot name (diagnostics only; binding is positional).
    pub name: String,
    /// Tensor shape, row-major.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Is the tensor zero-sized?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One lowered HLO module.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    /// Config family (e.g. "paper").
    pub config: String,
    /// Module name within the family (e.g. "bgplvm_fwd").
    pub module: String,
    /// The HLO-text artifact on disk.
    pub file: PathBuf,
    /// (chunk, m, q, d).
    pub dims: Dims,
    /// Positional input tensor specs.
    pub inputs: Vec<TensorSpec>,
    /// Positional output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

/// The static shape configuration of a module family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims {
    /// Chunk size C.
    pub c: usize,
    /// Inducing point count M.
    pub m: usize,
    /// Latent dimensionality Q.
    pub q: usize,
    /// Output dimensionality D.
    pub d: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// The artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    modules: BTreeMap<(String, String), ModuleSpec>,
}

fn tensor_list(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensors"))?
        .iter()
        .map(|t| {
            let name = t.get("name").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor missing name"))?.to_string();
            let shape = t.get("shape").and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parse manifest.json")?;
        if j.get("dtype").and_then(Json::as_str) != Some("f64") {
            bail!("manifest dtype must be f64");
        }
        let mut modules = BTreeMap::new();
        for e in j.get("modules").and_then(Json::as_arr).unwrap_or(&[]) {
            let config = e.get("config").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("module missing config"))?.to_string();
            let module = e.get("module").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("module missing module"))?.to_string();
            let file = dir.join(e.get("file").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("module missing file"))?);
            if !file.exists() {
                bail!("artifact {} listed in manifest but missing on disk", file.display());
            }
            let d = e.get("dims").ok_or_else(|| anyhow!("missing dims"))?;
            let dim = |k: &str| d.get(k).and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing dim {k}"));
            let dims = Dims { c: dim("c")?, m: dim("m")?, q: dim("q")?, d: dim("d")? };
            let spec = ModuleSpec {
                config: config.clone(),
                module: module.clone(),
                file,
                dims,
                inputs: tensor_list(e.get("inputs").ok_or_else(|| anyhow!("missing inputs"))?)?,
                outputs: tensor_list(e.get("outputs").ok_or_else(|| anyhow!("missing outputs"))?)?,
            };
            modules.insert((config, module), spec);
        }
        if modules.is_empty() {
            bail!("manifest has no modules");
        }
        Ok(Manifest { dir: dir.to_path_buf(), modules })
    }

    /// Look up one module of one config.
    pub fn get(&self, config: &str, module: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(&(config.to_string(), module.to_string()))
            .ok_or_else(|| anyhow!("no module {config}/{module} in manifest \
                                    (available: {:?})", self.config_names()))
    }

    /// Every config name in the manifest (duplicates collapsed).
    pub fn config_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.modules.keys().map(|(c, _)| c.as_str()).collect();
        v.dedup();
        v
    }

    /// Dims of a config (via its bound module, which every config has).
    pub fn dims(&self, config: &str) -> Result<Dims> {
        Ok(self.get(config, "bound")?.dims)
    }

    /// Pick a config matching (m, q, d) with chunk >= a minimum, preferring
    /// the smallest adequate chunk.
    pub fn find_config(&self, m: usize, q: usize, d: usize) -> Option<&str> {
        self.modules
            .values()
            .filter(|s| s.module == "bound" && s.dims.m == m && s.dims.q == q && s.dims.d == d)
            .map(|s| s.config.as_str())
            .next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let spec = man.get("test", "bgplvm_fwd").unwrap();
        assert_eq!(spec.dims, Dims { c: 64, m: 16, q: 2, d: 3 });
        assert_eq!(spec.inputs[0].name, "mu");
        assert_eq!(spec.inputs[0].shape, vec![64, 2]);
        assert_eq!(spec.outputs.len(), 5);
        // every config exposes the full module family
        for cfg in ["test", "paper", "quickstart", "mrd"] {
            for m in ["bgplvm_fwd", "bgplvm_vjp", "sgpr_fwd", "sgpr_vjp", "bound"] {
                assert!(man.get(cfg, m).is_ok(), "{cfg}/{m}");
            }
        }
        assert_eq!(man.dims("paper").unwrap().m, 100);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent/xyz")).is_err());
    }
}
