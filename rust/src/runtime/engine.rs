//! PJRT execution engine: load HLO-text artifacts, compile them on a CPU
//! PJRT client, execute them from the coordinator's hot path.
//!
//! One `Runtime` per worker thread — the `xla` crate's client is
//! `Rc`-based (deliberately not `Send`), which maps one device context to
//! one worker exactly like the paper assigns one GPU card per MPI process.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax ≥
//! 0.5 emits serialized protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use super::manifest::{Manifest, ModuleSpec};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// A borrowed argument for a module call.
pub enum Arg<'a> {
    /// A scalar (rank-0) argument.
    Scalar(f64),
    /// Row-major data; the shape is validated against the manifest.
    Buf(&'a [f64]),
}

/// A compiled, callable module.
pub struct Executable {
    spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional args; returns one flat `Vec<f64>` per
    /// declared output (row-major).
    pub fn call(&self, args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        if args.len() != self.spec.inputs.len() {
            bail!("{}/{}: expected {} args, got {}", self.spec.config,
                  self.spec.module, self.spec.inputs.len(), args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            let lit = match arg {
                Arg::Scalar(v) => {
                    if !spec.shape.is_empty() {
                        bail!("{}: scalar passed for tensor {:?}", spec.name, spec.shape);
                    }
                    xla::Literal::scalar(*v)
                }
                Arg::Buf(data) => {
                    if data.len() != spec.len() {
                        bail!("{}: length {} != shape {:?}", spec.name, data.len(), spec.shape);
                    }
                    let flat = xla::Literal::vec1(data);
                    if spec.shape.len() == 1 {
                        flat
                    } else {
                        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                        flat.reshape(&dims)
                            .with_context(|| format!("reshape {} to {:?}", spec.name, spec.shape))?
                    }
                }
            };
            literals.push(lit);
        }

        let result = self.exe.execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}/{}", self.spec.config, self.spec.module))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        // aot.py lowers with return_tuple=True: always a tuple root.
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}/{}: {} outputs, manifest says {}", self.spec.config,
                  self.spec.module, parts.len(), self.spec.outputs.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&self.spec.outputs) {
            let v = lit.to_vec::<f64>()
                .with_context(|| format!("output {} as f64", ospec.name))?;
            if v.len() != ospec.len() {
                bail!("output {}: got {} values, expected {:?}", ospec.name, v.len(), ospec.shape);
            }
            out.push(v);
        }
        Ok(out)
    }

    /// The manifest spec this executable was compiled from.
    pub fn spec(&self) -> &ModuleSpec {
        &self.spec
    }
}

/// Per-thread runtime: PJRT client + compiled-module cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<(String, String), Rc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling + caching on first use) a module.
    pub fn module(&self, config: &str, module: &str) -> Result<Rc<Executable>> {
        let key = (config.to_string(), module.to_string());
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(config, module)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not UTF-8")?)
            .with_context(|| format!("parse HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compile {}/{}", config, module))?;
        let handle = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(key, handle.clone());
        Ok(handle)
    }

    /// The PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kern::RbfArd;
    use crate::linalg::Mat;
    use crate::math::stats::bgplvm_stats_fwd;
    use crate::testutil::prop::Rng64;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn compile_and_run_bgplvm_fwd_matches_rust() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        let exe = rt.module("test", "bgplvm_fwd").unwrap();
        let dims = exe.spec().dims;
        let (c, m, q, d) = (dims.c, dims.m, dims.q, dims.d);

        let mut rng = Rng64::new(51);
        let mu = Mat::from_fn(c, q, |_, _| rng.normal());
        let s = Mat::from_fn(c, q, |_, _| rng.uniform_range(0.3, 1.4));
        let w: Vec<f64> = (0..c).map(|i| if i < c - 5 { 1.0 } else { 0.0 }).collect();
        let y = Mat::from_fn(c, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        let kern = RbfArd::new(1.3, vec![0.9; q]);
        let lh = kern.to_log_hyp();

        let out = exe.call(&[
            Arg::Buf(mu.as_slice()), Arg::Buf(s.as_slice()), Arg::Buf(&w),
            Arg::Buf(y.as_slice()), Arg::Buf(z.as_slice()), Arg::Buf(&lh),
        ]).unwrap();

        let st = bgplvm_stats_fwd(&kern, &mu, &s, &w, &y, &z);
        assert!((out[0][0] - st.psi0).abs() < 1e-9, "psi0");
        let p_x = Mat::from_vec(m, d, out[1].clone());
        assert!(p_x.max_abs_diff(&st.p) < 1e-9, "P");
        let p2_x = Mat::from_vec(m, m, out[2].clone());
        assert!(p2_x.max_abs_diff(&st.psi2) < 1e-9, "Psi2");
        assert!((out[3][0] - st.tryy).abs() < 1e-9, "tryy");
        assert!((out[4][0] - st.kl).abs() < 1e-8, "kl");
    }

    #[test]
    fn module_cache_reuses_compilation() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        let a = rt.module("test", "bound").unwrap();
        let b = rt.module("test", "bound").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn arg_validation_errors() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        let exe = rt.module("test", "bgplvm_fwd").unwrap();
        assert!(exe.call(&[]).is_err(), "arity check");
        let wrong = vec![0.0; 3];
        let args: Vec<Arg> = (0..6).map(|_| Arg::Buf(&wrong)).collect();
        assert!(exe.call(&args).is_err(), "shape check");
    }
}
