//! Runtime layer: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them through the PJRT C API
//! (`xla` crate). Python never runs at inference time — the artifacts
//! are the only hand-off between the layers.
//!
//! The PJRT path is gated behind the off-by-default `xla` cargo feature
//! so the default build is pure Rust with no external native deps. When
//! the feature is off, `engine` is replaced by a stub with the same API
//! whose constructors return a descriptive error; everything that merely
//! *mentions* the runtime (the `XlaBackend` plumbing, the manifest
//! tooling, `gpparallel info`) still compiles and runs.

#[cfg(feature = "xla")]
mod engine;
#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
mod engine;

mod manifest;

pub use engine::{Arg, Executable, Runtime};
pub use manifest::{Dims, Manifest, ModuleSpec, TensorSpec};
