//! Runtime layer: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them through the PJRT C API
//! (`xla` crate). Python never runs at inference time — the artifacts
//! are the only hand-off between the layers.

mod engine;
mod manifest;

pub use engine::{Arg, Executable, Runtime};
pub use manifest::{Dims, Manifest, ModuleSpec, TensorSpec};
