//! Stub runtime compiled when the `xla` feature is off (the default).
//!
//! Mirrors the API of `runtime::engine` exactly — same types, same
//! signatures — but `Runtime::new` fails with a pointer at the feature
//! flag instead of creating a PJRT client. This keeps the default build
//! pure Rust: the coordinator's `XlaBackend` plumbing compiles and
//! selecting it at runtime produces a clear error. In practice the
//! artifact-gated tests skip (producing artifacts requires the same
//! toolchain the feature needs); on a machine that *does* have
//! `artifacts/manifest.json` but not the feature, they fail loudly with
//! this stub's message rather than silently passing.

use super::manifest::ModuleSpec;
use anyhow::{bail, Result};
use std::path::Path;
use std::rc::Rc;

const UNAVAILABLE: &str = "built without the `xla` feature: the PJRT runtime is unavailable \
     (executing AOT artifacts needs a build with the `xla` feature enabled AND the external \
     `xla` crate added as a dependency — see the feature notes in rust/Cargo.toml)";

/// A borrowed argument for a module call.
pub enum Arg<'a> {
    /// A scalar (rank-0) argument.
    Scalar(f64),
    /// Row-major data; the shape is validated against the manifest.
    Buf(&'a [f64]),
}

/// A compiled, callable module. Never constructed in stub builds.
pub struct Executable {
    spec: ModuleSpec,
}

impl Executable {
    /// Execute with positional args — always an error in stub builds.
    pub fn call(&self, _args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        bail!("{}/{}: {UNAVAILABLE}", self.spec.config, self.spec.module);
    }

    /// The manifest spec this executable was built from.
    pub fn spec(&self) -> &ModuleSpec {
        &self.spec
    }
}

/// Stub runtime: creation always fails (there is no device to attach).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails in stub builds — points at the `xla` feature flag.
    pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
        bail!("{UNAVAILABLE}");
    }

    /// Always fails in stub builds (there is nothing to load).
    pub fn module(&self, config: &str, module: &str) -> Result<Rc<Executable>> {
        bail!("{config}/{module}: {UNAVAILABLE}");
    }

    /// The PJRT platform name — `"unavailable"` in stub builds.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::new(Path::new("artifacts")).err().expect("stub must fail");
        assert!(format!("{err}").contains("xla"), "error should name the feature: {err}");
    }
}
