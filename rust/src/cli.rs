//! Command-line argument parsing (offline stand-in for `clap`): a small
//! flag parser plus the launcher's option structs.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed `--key value` / `--flag` arguments plus positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// Non-flag arguments, in order (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches that were present.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw argument list (no program name). `--key=value`,
    /// `--key value` and bare `--flag` are all accepted; flags must be
    /// declared so `--flag value` is unambiguous.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = iter.next()
                        .ok_or_else(|| anyhow!("--{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Was the bare switch `--name` present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The raw value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// Parse option `--name` into `T`, falling back to `default` when
    /// absent; parse failures are errors.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>()
                .map_err(|_| anyhow!("--{name}: cannot parse {v:?}")),
        }
    }

    /// The value of option `--name`, or an error naming it.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    /// Reject unknown options (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose"]).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse("train --n 100 --backend=xla --verbose pos2");
        assert_eq!(a.positional, vec!["train", "pos2"]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get("backend"), Some("xla"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("--n 42");
        assert_eq!(a.get_parse("n", 7usize).unwrap(), 42);
        assert_eq!(a.get_parse("m", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n", 0).is_ok());
        let bad = parse("--n abc");
        assert!(bad.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("--typo 1");
        assert!(a.check_known(&["n", "m"]).is_err());
        assert!(a.check_known(&["typo"]).is_ok());
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(["--n".to_string()].into_iter(), &[]);
        assert!(r.is_err());
    }

    #[test]
    fn require_works() {
        let a = parse("--n 1");
        assert!(a.require("n").is_ok());
        assert!(a.require("zz").is_err());
    }
}
