//! Command-line argument parsing (offline stand-in for `clap`): a small
//! flag parser plus the launcher's option structs.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed `--key value` / `--flag` arguments plus positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// Non-flag arguments, in order (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches that were present.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw argument list (no program name). `--key=value`,
    /// `--key value` and bare `--flag` are all accepted; flags must be
    /// declared so `--flag value` is unambiguous.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = iter.next()
                        .ok_or_else(|| anyhow!("--{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Was the bare switch `--name` present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The raw value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// Parse option `--name` into `T`, falling back to `default` when
    /// absent; parse failures are errors.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>()
                .map_err(|_| anyhow!("--{name}: cannot parse {v:?}")),
        }
    }

    /// The value of option `--name`, or an error naming it.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    /// Reject unknown options (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }

    /// Reject bare switches the current subcommand does not accept.
    pub fn check_known_flags(&self, known: &[&str]) -> Result<()> {
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("--{f} is not accepted here (known flags: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// the launcher's per-subcommand argument scopes
// ---------------------------------------------------------------------

/// Dataset/model/engine options shared by every engine-driving
/// subcommand (each adds its own extras on top — see [`known_options`]).
const ENGINE_OPTIONS: &[&str] = &["n", "q", "d", "m", "workers", "chunk", "backend",
                                  "seed", "artifacts", "aot-config", "simd"];
/// Flags shared by every engine-driving subcommand.
const ENGINE_FLAGS: &[&str] = &["verbose", "no-pipeline", "help"];

/// The `--key value` options subcommand `cmd` accepts, or `None` for an
/// unknown subcommand. Validation is **per subcommand**, not global:
/// `gpparallel time --batch 64` is an error, not a silently ignored
/// option (`--batch` belongs to `predict`). Every engine-driving scope
/// is built from the one shared [`ENGINE_OPTIONS`] base plus its own
/// extras, so a new shared option cannot drift out of some scopes.
pub fn known_options(cmd: &str) -> Option<Vec<&'static str>> {
    let (base, extra): (&[&str], &[&str]) = match cmd {
        "train-bgplvm" => (ENGINE_OPTIONS, &["iters"]),
        "train-sgpr" => (ENGINE_OPTIONS, &["iters", "data-dir", "data-csv"]),
        "predict" => (ENGINE_OPTIONS,
                      &["iters", "nt", "batch", "clients", "max-batch-rows",
                        "max-wait-us", "serve-requests", "req-rows", "queue-rows"]),
        "time" => (ENGINE_OPTIONS, &["evals"]),
        "ingest" => (&[], &["csv", "out", "q", "chunk-rows"]),
        "info" => (&[], &["artifacts"]),
        "help" => (&[], &[]),
        _ => return None,
    };
    Some(base.iter().chain(extra).copied().collect())
}

/// The bare `--flag` switches subcommand `cmd` accepts (same per-scope
/// discipline as [`known_options`], built from the shared
/// [`ENGINE_FLAGS`] base so a new shared flag cannot drift out of some
/// scopes).
pub fn known_flags(cmd: &str) -> Vec<&'static str> {
    let (base, extra): (&[&str], &[&str]) = match cmd {
        "train-bgplvm" | "time" => (ENGINE_FLAGS, &[]),
        "train-sgpr" => (ENGINE_FLAGS, &["has-header"]),
        "predict" => (ENGINE_FLAGS, &["refit-demo", "stream", "serve"]),
        "ingest" => (&[], &["center", "has-header", "help"]),
        _ => (&[], &["help"]),
    };
    base.iter().chain(extra).copied().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose"]).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse("train --n 100 --backend=xla --verbose pos2");
        assert_eq!(a.positional, vec!["train", "pos2"]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get("backend"), Some("xla"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("--n 42");
        assert_eq!(a.get_parse("n", 7usize).unwrap(), 42);
        assert_eq!(a.get_parse("m", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n", 0).is_ok());
        let bad = parse("--n abc");
        assert!(bad.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("--typo 1");
        assert!(a.check_known(&["n", "m"]).is_err());
        assert!(a.check_known(&["typo"]).is_ok());
    }

    /// Regression: argument validation is per-subcommand — an option
    /// that belongs to a *different* subcommand is rejected instead of
    /// being silently ignored.
    #[test]
    fn per_subcommand_scopes_reject_out_of_scope_options() {
        // `time --batch 64` used to pass the (global) typo guard and be
        // silently ignored; now it is an error
        let a = parse("time --batch 64");
        let known = known_options("time").expect("time is a known command");
        assert!(!known.contains(&"batch"));
        assert!(a.check_known(&known).is_err());

        // the same option is in scope for `predict`
        let p = known_options("predict").expect("predict is a known command");
        assert!(p.contains(&"batch") && p.contains(&"nt"));
        assert!(parse("predict --batch 64").check_known(&p).is_ok());

        // `evals` belongs to `time`, not the training subcommands
        assert!(known_options("train-sgpr").unwrap().contains(&"iters"));
        assert!(!known_options("train-sgpr").unwrap().contains(&"evals"));

        // the chunk-store data paths are sgpr-only (BGP-LVM cannot
        // stream: its variational latents are O(N/P) by protocol)
        let s = known_options("train-sgpr").unwrap();
        assert!(s.contains(&"data-dir") && s.contains(&"data-csv"));
        let b = known_options("train-bgplvm").unwrap();
        assert!(!b.contains(&"data-dir") && !b.contains(&"data-csv"));

        // `ingest` is a pure data command: no engine options in scope
        let ing = known_options("ingest").unwrap();
        for opt in ["csv", "out", "q", "chunk-rows"] {
            assert!(ing.contains(&opt), "{opt}");
        }
        assert!(!ing.contains(&"workers") && !ing.contains(&"backend"));

        // the shared engine base appears in every engine-driving scope
        for cmd in ["train-bgplvm", "train-sgpr", "predict", "time"] {
            assert!(known_options(cmd).unwrap().contains(&"workers"), "{cmd}");
        }

        assert!(known_options("frobnicate").is_none());
    }

    /// Flags follow the same scoping: `--refit-demo` is predict-only,
    /// and the shared engine flags appear in every engine-driving scope.
    #[test]
    fn per_subcommand_flag_scopes() {
        let a = Args::parse("time --refit-demo".split_whitespace().map(String::from),
                            &["refit-demo"]).unwrap();
        assert!(a.check_known_flags(&known_flags("time")).is_err());
        assert!(a.check_known_flags(&known_flags("predict")).is_ok());
        assert_eq!(known_flags("info"), vec!["help"]);
        for cmd in ["train-bgplvm", "train-sgpr", "predict", "time"] {
            assert!(known_flags(cmd).contains(&"no-pipeline"), "{cmd}");
        }
        // `--stream` (streamed serving) is predict-only too
        assert!(known_flags("predict").contains(&"stream"));
        assert!(!known_flags("time").contains(&"stream"));
        // so is the front-end's `--serve` mode and its knobs
        assert!(known_flags("predict").contains(&"serve"));
        assert!(!known_flags("train-sgpr").contains(&"serve"));
        let p = known_options("predict").unwrap();
        for opt in ["clients", "max-batch-rows", "max-wait-us", "serve-requests",
                    "req-rows", "queue-rows"] {
            assert!(p.contains(&opt), "{opt}");
            assert!(!known_options("time").unwrap().contains(&opt), "{opt}");
        }
        // `--center` is an ingest-time decision (recorded in the
        // manifest), not a training flag; `--has-header` rides on both
        // CSV-reading commands
        assert!(known_flags("ingest").contains(&"center"));
        assert!(!known_flags("train-sgpr").contains(&"center"));
        assert!(known_flags("train-sgpr").contains(&"has-header"));
        assert!(known_flags("ingest").contains(&"has-header"));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(["--n".to_string()].into_iter(), &[]);
        assert!(r.is_err());
    }

    #[test]
    fn require_works() {
        let a = parse("--n 1");
        assert!(a.require("n").is_ok());
        assert!(a.require("zz").is_err());
    }
}
