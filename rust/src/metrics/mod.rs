//! Phase timing and iteration reporting.
//!
//! The coordinator attributes every microsecond of an optimisation
//! iteration to a named phase; the distributable/indistributable split is
//! exactly what the paper's Fig 1b plots. The serving front-end reuses
//! the same [`PhaseTimer`] over its own `Srv*` phases, and layers the
//! counter/histogram side of serving observability in
//! [`serving::ServingMetrics`].

pub mod serving;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Per-thread CPU time in seconds (CLOCK_THREAD_CPUTIME_ID).
///
/// On a time-shared host, wall-clock inside a worker includes the slices
/// other ranks ran; thread CPU time is what the rank actually burned and
/// is the quantity that divides with the worker count (the basis of
/// `TrainResult::projected_sec_per_eval`).
///
/// Calls `clock_gettime` directly (declared here rather than through the
/// `libc` crate: this is the crate's only FFI and the build is
/// dependency-free by policy). The hand-declared `Timespec` matches the
/// 64-bit glibc layout, so the FFI path is gated to 64-bit Linux; other
/// targets take the portable wall-clock fallback below.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn thread_cpu_time() -> f64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain FFI into libc's clock_gettime with a valid clock id
    // and a pointer to a live, correctly-laid-out (repr(C)) Timespec on
    // this stack frame; the call writes only through that pointer.
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Fallback for hosts without the FFI path: per-thread wall-clock since
/// first use. Coarser than CPU time (it includes time-sharing slices) but
/// keeps the phase accounting monotone and the crate portable.
#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn thread_cpu_time() -> f64 {
    use std::cell::Cell;
    thread_local! {
        static START: Cell<Option<Instant>> = const { Cell::new(None) };
    }
    START.with(|s| {
        if s.get().is_none() {
            s.set(Some(Instant::now()));
        }
        s.get().unwrap().elapsed().as_secs_f64()
    })
}

/// Named phases of one coordinator iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Parameter broadcast to workers.
    Bcast,
    /// Worker-side statistics forward pass (distributable).
    StatsFwd,
    /// Reduction of partial statistics.
    Reduce,
    /// Leader-side bound + cotangents (indistributable M×M core).
    BoundCore,
    /// Worker-side VJP (distributable).
    StatsVjp,
    /// Gradient gather/reduce.
    GatherGrads,
    /// Optimiser step (leader).
    OptStep,
    /// Serving front-end: batcher idle, waiting for client requests to
    /// arrive (or for a micro-batch deadline to expire).
    SrvEnqueueWait,
    /// Serving front-end: coalescing queued requests into one
    /// micro-batch (row concatenation + span bookkeeping).
    SrvBatchAssembly,
    /// Serving front-end: the sharded cluster round (issue + own-shard
    /// compute + gather) for a coalesced batch.
    SrvClusterRound,
    /// Serving front-end: splitting a completed batch's rows back out to
    /// the originating client requests.
    SrvFanout,
}

impl Phase {
    /// Every phase, in cycle order (for iteration/reporting); the
    /// serving front-end phases follow the training cycle's.
    pub const ALL: [Phase; 11] = [
        Phase::Bcast, Phase::StatsFwd, Phase::Reduce, Phase::BoundCore,
        Phase::StatsVjp, Phase::GatherGrads, Phase::OptStep,
        Phase::SrvEnqueueWait, Phase::SrvBatchAssembly, Phase::SrvClusterRound,
        Phase::SrvFanout,
    ];

    /// Stable snake_case label (used in timing summaries and benches).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Bcast => "bcast",
            Phase::StatsFwd => "stats_fwd",
            Phase::Reduce => "reduce",
            Phase::BoundCore => "bound_core",
            Phase::StatsVjp => "stats_vjp",
            Phase::GatherGrads => "gather_grads",
            Phase::OptStep => "opt_step",
            Phase::SrvEnqueueWait => "srv_enqueue_wait",
            Phase::SrvBatchAssembly => "srv_batch_assembly",
            Phase::SrvClusterRound => "srv_cluster_round",
            Phase::SrvFanout => "srv_fanout",
        }
    }

    /// Is this phase parallelisable over datapoints (the paper's
    /// "distributable computation")? The serving phases are leader-side
    /// scheduling work, not datapoint compute, so they are all
    /// indistributable by this classification (they never feed Fig 1b —
    /// the serving dump reports them separately).
    pub fn distributable(self) -> bool {
        matches!(self, Phase::StatsFwd | Phase::StatsVjp)
    }
}

/// Accumulates wall-clock per phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    acc: BTreeMap<Phase, Duration>,
    evals: usize,
}

impl PhaseTimer {
    /// Empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase label.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.acc.entry(phase).or_default() += t0.elapsed();
        out
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        *self.acc.entry(phase).or_default() += d;
    }

    /// Count one completed objective evaluation.
    pub fn note_eval(&mut self) {
        self.evals += 1;
    }

    /// Completed objective evaluations.
    pub fn evals(&self) -> usize {
        self.evals
    }

    /// Total accumulated time across all phases.
    pub fn total(&self) -> Duration {
        self.acc.values().sum()
    }

    /// Accumulated time in one phase.
    pub fn get(&self, phase: Phase) -> Duration {
        self.acc.get(&phase).copied().unwrap_or_default()
    }

    /// Fraction of total time in non-distributable phases — Fig 1b's y-axis.
    pub fn indistributable_fraction(&self) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            return 0.0;
        }
        let indist: f64 = Phase::ALL
            .iter()
            .filter(|p| !p.distributable())
            .map(|p| self.get(*p).as_secs_f64())
            .sum();
        indist / total
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for p in Phase::ALL {
            let d = self.get(p);
            if !d.is_zero() {
                parts.push(format!("{}={:.1}ms", p.name(), d.as_secs_f64() * 1e3));
            }
        }
        format!(
            "{} | total={:.1}ms indist={:.1}%",
            parts.join(" "),
            self.total().as_secs_f64() * 1e3,
            self.indistributable_fraction() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_time_advances_with_work() {
        let t0 = thread_cpu_time();
        let mut acc = 0.0f64;
        for i in 0..2_000_000 {
            acc += (i as f64).sqrt();
        }
        std::hint::black_box(acc);
        let t1 = thread_cpu_time();
        assert!(t1 > t0, "cpu time did not advance");
    }

    #[test]
    fn accumulates_and_fractions() {
        let mut t = PhaseTimer::new();
        t.add(Phase::StatsFwd, Duration::from_millis(90));
        t.add(Phase::BoundCore, Duration::from_millis(10));
        assert!((t.indistributable_fraction() - 0.1).abs() < 1e-9);
        assert_eq!(t.total(), Duration::from_millis(100));
    }

    #[test]
    fn time_closure_runs_once() {
        let mut t = PhaseTimer::new();
        let mut calls = 0;
        let v = t.time(Phase::OptStep, || {
            calls += 1;
            42
        });
        assert_eq!((v, calls), (42, 1));
        assert!(t.get(Phase::OptStep) > Duration::ZERO);
    }

    #[test]
    fn phase_classification() {
        assert!(Phase::StatsFwd.distributable());
        assert!(Phase::StatsVjp.distributable());
        assert!(!Phase::BoundCore.distributable());
        assert!(!Phase::Reduce.distributable());
    }
}
