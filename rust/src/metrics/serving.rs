//! Serving-side observability: lock-free counters and a latency
//! histogram for the micro-batching front-end.
//!
//! [`ServingMetrics`] is the shared sink: client handles record
//! enqueue/complete events (including the enqueue-to-complete latency of
//! every request) and the batcher records batch formation and queue
//! depth — all through atomics, so the hot path never takes a lock. A
//! [`ServingSnapshot`] is a consistent-enough point-in-time read with
//! derived rates (rows/sec, batch-fill ratio, p50/p99 latency), rendered
//! either as a Prometheus-style text dump ([`ServingSnapshot::render_text`],
//! the `--serve` periodic dump) or as a machine-readable JSON record
//! ([`ServingSnapshot::to_json`]).
//!
//! The latency histogram uses power-of-two nanosecond buckets with
//! linear interpolation inside the winning bucket — coarse but
//! allocation-free, bounded (64 buckets cover 1 ns to ~584 years), and
//! mergeable across threads without coordination, which is exactly the
//! Prometheus histogram trade-off.

use crate::config::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Power-of-two nanosecond buckets: index `i` counts latencies in
/// `[2^i, 2^(i+1))` ns (index 0 also absorbs 0 ns).
const BUCKETS: usize = 64;

/// Lock-free latency histogram over power-of-two nanosecond buckets.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [const { AtomicU64::new(0) }; BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

// Every atomic in this module uses `Ordering::Relaxed` for the same
// reason: these are pure statistics. Each cell is an independent
// monotonic counter (or a last-write-wins gauge); nothing synchronises
// *through* them, and readers explicitly tolerate a slightly-skewed
// cross-cell view ("consistent-enough snapshot" in the docs above). The
// per-site comments below say which flavour each one is.
impl LatencyHistogram {
    /// Record one latency observation.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let idx = (63 - ns.max(1).leading_zeros()) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
        self.count.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
        self.sum_ns.fetch_add(ns, Ordering::Relaxed); // relaxed: independent stat counter
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed) // relaxed: stat snapshot read
    }

    /// Mean latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 // relaxed: stat snapshot read
    }

    /// The `q`-quantile latency in nanoseconds (`q` in `[0, 1]`),
    /// linearly interpolated inside the winning power-of-two bucket.
    /// Returns 0 when no observations have been recorded.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        // rank of the wanted observation, 1-based, clamped to the range
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed); // relaxed: stat snapshot read
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                // interpolate within [2^i, 2^(i+1)) by the rank's
                // position among this bucket's observations
                let lo = (1u64 << i) as f64;
                let frac = (rank - seen) as f64 / c as f64;
                return lo + lo * frac;
            }
            seen += c;
        }
        // unreachable with a consistent count; fall back to the top edge
        f64::MAX
    }
}

/// Shared serving metrics sink: atomically updated by every client
/// handle and by the batcher thread. Construct once per front-end and
/// share behind an `Arc`.
#[derive(Debug)]
pub struct ServingMetrics {
    start: Instant,
    /// Micro-batch size cap — denominator of the batch-fill ratio.
    max_batch_rows: u64,
    requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    rows_done: AtomicU64,
    batches: AtomicU64,
    batch_rows: AtomicU64,
    queue_rows: AtomicU64,
    queue_rows_max: AtomicU64,
    enqueue_blocked: AtomicU64,
    enqueue_blocked_ns: AtomicU64,
    /// Enqueue-to-complete latency of every finished request.
    pub latency: LatencyHistogram,
}

impl ServingMetrics {
    /// Fresh sink. `max_batch_rows` is the batcher's size trigger (the
    /// batch-fill ratio's denominator).
    pub fn new(max_batch_rows: usize) -> ServingMetrics {
        ServingMetrics {
            start: Instant::now(),
            max_batch_rows: max_batch_rows.max(1) as u64,
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            rows_done: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_rows: AtomicU64::new(0),
            queue_rows: AtomicU64::new(0),
            queue_rows_max: AtomicU64::new(0),
            enqueue_blocked: AtomicU64::new(0),
            enqueue_blocked_ns: AtomicU64::new(0),
            latency: LatencyHistogram::default(),
        }
    }

    /// A request entered the queue; `depth_rows` is the queue depth (in
    /// rows) right after the push.
    pub fn note_enqueued(&self, depth_rows: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
        self.set_queue_depth(depth_rows);
    }

    /// A request was answered without touching the queue (the empty
    /// request fast path): counted, no depth update.
    pub fn note_unqueued_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
    }

    /// An enqueue had to block on backpressure for `waited`.
    pub fn note_blocked(&self, waited: Duration) {
        self.enqueue_blocked.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
        self.enqueue_blocked_ns
            .fetch_add(waited.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed); // relaxed: independent stat counter
    }

    /// The batcher closed one micro-batch of `rows` rows; `depth_rows`
    /// is the queue depth right after the batch was taken.
    pub fn note_batch(&self, rows: usize, depth_rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
        self.batch_rows.fetch_add(rows as u64, Ordering::Relaxed); // relaxed: independent stat counter
        self.set_queue_depth(depth_rows);
    }

    /// A request finished; `ok` tells success from failure, `rows` is
    /// its row count and `latency` its enqueue-to-complete time.
    pub fn note_finished(&self, ok: bool, rows: usize, latency: Duration) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
            self.rows_done.fetch_add(rows as u64, Ordering::Relaxed); // relaxed: independent stat counter
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed); // relaxed: independent stat counter
        }
        self.latency.record(latency);
    }

    fn set_queue_depth(&self, depth_rows: usize) {
        let d = depth_rows as u64;
        self.queue_rows.store(d, Ordering::Relaxed); // relaxed: last-write-wins gauge
        self.queue_rows_max.fetch_max(d, Ordering::Relaxed); // relaxed: monotonic high-water mark
    }

    /// Point-in-time read with derived rates. `comm` carries the
    /// session's transport counter deltas (bytes, messages) when the
    /// caller has them — the metrics sink itself never touches the
    /// transport.
    pub fn snapshot(&self, comm: Option<(u64, u64)>) -> ServingSnapshot {
        let elapsed = self.start.elapsed().as_secs_f64().max(1e-9);
        // relaxed: all loads below are stat snapshot reads — the
        // snapshot is documented as consistent-enough, not atomic
        // across cells.
        let batches = self.batches.load(Ordering::Relaxed); // relaxed: stat snapshot read
        let batch_rows = self.batch_rows.load(Ordering::Relaxed); // relaxed: stat snapshot read
        let rows_done = self.rows_done.load(Ordering::Relaxed); // relaxed: stat snapshot read
        let (comm_bytes, comm_messages) = comm.unwrap_or((0, 0));
        ServingSnapshot {
            elapsed_sec: elapsed,
            requests: self.requests.load(Ordering::Relaxed), // relaxed: stat snapshot read
            completed: self.completed.load(Ordering::Relaxed), // relaxed: stat snapshot read
            failed: self.failed.load(Ordering::Relaxed), // relaxed: stat snapshot read
            rows: rows_done,
            rows_per_sec: rows_done as f64 / elapsed,
            batches,
            batch_fill: if batches == 0 {
                0.0
            } else {
                batch_rows as f64 / (batches * self.max_batch_rows) as f64
            },
            queue_rows: self.queue_rows.load(Ordering::Relaxed), // relaxed: stat snapshot read
            queue_rows_max: self.queue_rows_max.load(Ordering::Relaxed), // relaxed: stat snapshot read
            enqueue_blocked: self.enqueue_blocked.load(Ordering::Relaxed), // relaxed: stat snapshot read
            enqueue_blocked_sec: self.enqueue_blocked_ns.load(Ordering::Relaxed) as f64 // relaxed: stat snapshot read
                * 1e-9,
            latency_mean_us: self.latency.mean_ns() * 1e-3,
            latency_p50_us: self.latency.quantile_ns(0.50) * 1e-3,
            latency_p99_us: self.latency.quantile_ns(0.99) * 1e-3,
            comm_bytes,
            comm_messages,
        }
    }
}

/// One consistent-enough read of a [`ServingMetrics`] sink, with the
/// derived rates the dumps report.
#[derive(Clone, Debug, Default)]
pub struct ServingSnapshot {
    /// Seconds since the sink was created.
    pub elapsed_sec: f64,
    /// Requests that entered the queue.
    pub requests: u64,
    /// Requests that completed successfully.
    pub completed: u64,
    /// Requests that finished with an error.
    pub failed: u64,
    /// Prediction rows served (successful requests only).
    pub rows: u64,
    /// Served rows per second since the sink was created.
    pub rows_per_sec: f64,
    /// Coalesced micro-batches issued to the cluster.
    pub batches: u64,
    /// Mean batch rows / `max_batch_rows` — 1.0 means every batch closed
    /// on the size trigger, small values mean the deadline fired first.
    pub batch_fill: f64,
    /// Queue depth in rows at snapshot time.
    pub queue_rows: u64,
    /// High-water queue depth in rows.
    pub queue_rows_max: u64,
    /// Enqueues that blocked on backpressure.
    pub enqueue_blocked: u64,
    /// Total seconds enqueues spent blocked on backpressure.
    pub enqueue_blocked_sec: f64,
    /// Mean enqueue-to-complete latency, microseconds.
    pub latency_mean_us: f64,
    /// Median enqueue-to-complete latency, microseconds.
    pub latency_p50_us: f64,
    /// 99th-percentile enqueue-to-complete latency, microseconds.
    pub latency_p99_us: f64,
    /// Transport bytes sent over the session (0 when not supplied).
    pub comm_bytes: u64,
    /// Transport messages sent over the session (0 when not supplied).
    pub comm_messages: u64,
}

impl ServingSnapshot {
    /// Prometheus-style text exposition (the `--serve` periodic dump).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("# serving front-end t={:.1}s\n", self.elapsed_sec));
        s.push_str(&format!("gp_serve_requests_total {}\n", self.requests));
        s.push_str(&format!("gp_serve_requests_completed {}\n", self.completed));
        s.push_str(&format!("gp_serve_requests_failed {}\n", self.failed));
        s.push_str(&format!("gp_serve_rows_total {}\n", self.rows));
        s.push_str(&format!("gp_serve_rows_per_sec {:.1}\n", self.rows_per_sec));
        s.push_str(&format!("gp_serve_latency_us{{quantile=\"0.5\"}} {:.1}\n",
                            self.latency_p50_us));
        s.push_str(&format!("gp_serve_latency_us{{quantile=\"0.99\"}} {:.1}\n",
                            self.latency_p99_us));
        s.push_str(&format!("gp_serve_latency_us_mean {:.1}\n", self.latency_mean_us));
        s.push_str(&format!("gp_serve_batches_total {}\n", self.batches));
        s.push_str(&format!("gp_serve_batch_fill_ratio {:.3}\n", self.batch_fill));
        s.push_str(&format!("gp_serve_queue_rows {}\n", self.queue_rows));
        s.push_str(&format!("gp_serve_queue_rows_max {}\n", self.queue_rows_max));
        s.push_str(&format!("gp_serve_enqueue_blocked_total {}\n", self.enqueue_blocked));
        s.push_str(&format!("gp_serve_enqueue_blocked_sec {:.3}\n",
                            self.enqueue_blocked_sec));
        s.push_str(&format!("gp_serve_comm_bytes_total {}\n", self.comm_bytes));
        s.push_str(&format!("gp_serve_comm_messages_total {}\n", self.comm_messages));
        s
    }

    /// Machine-readable record (one [`Json`] object, sorted keys).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("elapsed_sec".into(), Json::Num(self.elapsed_sec));
        m.insert("requests".into(), Json::Num(self.requests as f64));
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("failed".into(), Json::Num(self.failed as f64));
        m.insert("rows".into(), Json::Num(self.rows as f64));
        m.insert("rows_per_sec".into(), Json::Num(self.rows_per_sec));
        m.insert("batches".into(), Json::Num(self.batches as f64));
        m.insert("batch_fill".into(), Json::Num(self.batch_fill));
        m.insert("queue_rows".into(), Json::Num(self.queue_rows as f64));
        m.insert("queue_rows_max".into(), Json::Num(self.queue_rows_max as f64));
        m.insert("enqueue_blocked".into(), Json::Num(self.enqueue_blocked as f64));
        m.insert("enqueue_blocked_sec".into(), Json::Num(self.enqueue_blocked_sec));
        m.insert("latency_mean_us".into(), Json::Num(self.latency_mean_us));
        m.insert("latency_p50_us".into(), Json::Num(self.latency_p50_us));
        m.insert("latency_p99_us".into(), Json::Num(self.latency_p99_us));
        m.insert("comm_bytes".into(), Json::Num(self.comm_bytes as f64));
        m.insert("comm_messages".into(), Json::Num(self.comm_messages as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_interpolate_sensibly() {
        let h = LatencyHistogram::default();
        // 100 observations at ~1 µs, 1 at ~1 ms: p50 lands in the µs
        // bucket, p99+ near the outlier's bucket
        for _ in 0..100 {
            h.record(Duration::from_nanos(1_100));
        }
        h.record(Duration::from_millis(1));
        assert_eq!(h.count(), 101);
        let p50 = h.quantile_ns(0.5);
        assert!((1_024.0..2_048.0).contains(&p50), "p50 = {p50}");
        let p100 = h.quantile_ns(1.0);
        assert!(p100 >= 524_288.0, "max quantile must reach the outlier: {p100}");
        // quantiles are monotone in q
        assert!(h.quantile_ns(0.99) >= p50);
        // mean sits between the mass and the outlier
        assert!(h.mean_ns() > 1_000.0 && h.mean_ns() < 1_000_000.0);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_ns(0.5), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn snapshot_derives_rates_and_renders() {
        let m = ServingMetrics::new(8);
        m.note_enqueued(3);
        m.note_enqueued(5);
        m.note_blocked(Duration::from_micros(10));
        m.note_batch(5, 0);
        m.note_finished(true, 3, Duration::from_micros(50));
        m.note_finished(false, 2, Duration::from_micros(70));
        let s = m.snapshot(Some((1234, 7)));
        assert_eq!((s.requests, s.completed, s.failed), (2, 1, 1));
        assert_eq!(s.rows, 3);
        assert_eq!(s.batches, 1);
        assert!((s.batch_fill - 5.0 / 8.0).abs() < 1e-12);
        assert_eq!(s.queue_rows_max, 5);
        assert_eq!(s.enqueue_blocked, 1);
        assert_eq!((s.comm_bytes, s.comm_messages), (1234, 7));
        let text = s.render_text();
        for key in ["gp_serve_requests_total 2", "gp_serve_requests_failed 1",
                    "gp_serve_batches_total 1", "gp_serve_queue_rows_max 5",
                    "gp_serve_enqueue_blocked_total 1",
                    "gp_serve_comm_messages_total 7",
                    "gp_serve_latency_us{quantile=\"0.99\"}"] {
            assert!(text.contains(key), "dump missing `{key}`:\n{text}");
        }
        let j = s.to_json().to_string_pretty();
        assert!(j.contains("\"requests\": 2"), "{j}");
    }
}
