//! `gpparallel` — launcher for the distributed sparse-GP system.
//!
//! Subcommands:
//!   train-bgplvm   fit a Bayesian GP-LVM to the paper's synthetic data
//!   train-sgpr     fit sparse GP regression to synthetic data, a CSV
//!                  (`--data-csv`), or an on-disk chunk store
//!                  (`--data-dir`, streamed in O(chunk) memory per rank)
//!   ingest         stream a CSV into an on-disk chunk store
//!                  (`manifest.json` + `chunks.bin`)
//!   predict        fit sparse GP regression, then serve a held-out test
//!                  batch through the sharded posterior (prediction rows
//!                  partitioned across the same ranks that trained)
//!   time           benchmark mode: time objective evaluations
//!                  (the paper's "average time per iteration")
//!   info           show the artifact manifest
//!
//! Examples:
//!   gpparallel train-bgplvm --n 2000 --workers 4 --backend xla --iters 100
//!   gpparallel ingest --csv data.csv --q 1 --out store/ --chunk-rows 1024
//!   gpparallel train-sgpr --data-dir store/ --m 32 --workers 4
//!   gpparallel predict --n 2000 --nt 1000 --workers 4 --backend parallel --batch 256
//!   gpparallel predict --n 2000 --workers 4 --serve --clients 8 --max-batch-rows 64
//!   gpparallel time --n 8000 --workers 8 --backend cpu --evals 5

use anyhow::{bail, Result};
use gpparallel::cli::{known_flags, known_options, Args};
use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, FrontendConfig, OptChoice};
use gpparallel::data::csv::{ingest_csv, read_matrix};
use gpparallel::data::store::DEFAULT_CHUNK_ROWS;
use gpparallel::data::synthetic::{generate, generate_supervised, SyntheticSpec};
use gpparallel::data::Dataset;
use gpparallel::linalg::{mean, Mat, SimdLevel};
use gpparallel::models::{BayesianGplvm, SparseGpRegression};
use gpparallel::optim::Lbfgs;
use gpparallel::runtime::Manifest;
use std::path::PathBuf;
use std::time::Duration;

fn engine_config(a: &Args) -> Result<(EngineConfig, String)> {
    let backend = BackendKind::parse(a.get("backend").unwrap_or("cpu"))
        .ok_or_else(|| anyhow::anyhow!("--backend must be cpu|parallel[:N]|xla"))?;
    let aot = a.get("aot-config").unwrap_or("paper").to_string();
    // --simd off|scalar|native pins the dispatch tier; "auto" (or absent)
    // defers to GPPAR_SIMD and then CPU detection
    let simd = match a.get("simd") {
        None => None,
        Some(s) if s.eq_ignore_ascii_case("auto") => None,
        Some(s) => Some(SimdLevel::parse(s).ok_or_else(|| {
            anyhow::anyhow!("--simd must be off|scalar|native|auto, got {s:?}")
        })?),
    };
    let cfg = EngineConfig {
        workers: a.get_parse("workers", 1usize)?,
        chunk: a.get_parse("chunk", 1024usize)?,
        backend,
        artifacts_dir: PathBuf::from(a.get("artifacts").unwrap_or("artifacts")),
        opt: OptChoice::Lbfgs(Lbfgs {
            max_iters: a.get_parse("iters", 100usize)?,
            ..Default::default()
        }),
        pipeline: !a.flag("no-pipeline"),
        verbose: a.flag("verbose"),
        simd,
    };
    Ok((cfg, aot))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv, &["verbose", "help", "no-pipeline", "refit-demo",
                                   "stream", "serve", "center", "has-header"])?;

    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    // per-subcommand argument validation: an option or flag that only
    // another subcommand accepts is an error, not silently ignored.
    // A bare `gpparallel --opt ...` with no subcommand at all, and
    // unknown subcommands, fall through to the usage text instead.
    if !args.positional.is_empty() {
        if let Some(known) = known_options(cmd) {
            args.check_known(&known)?;
            args.check_known_flags(&known_flags(cmd))?;
        }
    }
    match cmd {
        "train-bgplvm" => {
            let spec = SyntheticSpec {
                n: args.get_parse("n", 2000usize)?,
                q: args.get_parse("q", 1usize)?,
                d: args.get_parse("d", 3usize)?,
                ..Default::default()
            };
            let seed = args.get_parse("seed", 0u64)?;
            let m = args.get_parse("m", 100usize)?;
            let (cfg, aot) = engine_config(&args)?;
            let ds = generate(&spec, seed);
            eprintln!("dataset: N={} D={} Q={}  backend={} workers={}",
                      spec.n, spec.d, spec.q, cfg.backend.name(), cfg.workers);
            let model = BayesianGplvm::fit(&ds.y(), spec.q, m, &aot, cfg, seed)?;
            let r = &model.result;
            println!("bound: {:.4}  iters: {} evals: {}  sec/eval: {:.4}",
                     r.f, r.iterations, r.evaluations, r.sec_per_eval);
            println!("timing: {}", r.timing.summary());
            if let Some(truth) = ds.latent_truth() {
                println!("latent alignment |corr|: {:.4}", model.latent_alignment(truth));
            }
        }
        "train-sgpr" => {
            let seed = args.get_parse("seed", 0u64)?;
            let m = args.get_parse("m", 16usize)?;
            let (cfg, aot) = engine_config(&args)?;
            if args.get("data-dir").is_some() && args.get("data-csv").is_some() {
                bail!("--data-dir and --data-csv are mutually exclusive");
            }
            if let Some(dir) = args.get("data-dir") {
                // out-of-core path: the store's chunk grid drives the
                // partition and each rank streams its chunks in
                // O(chunk) memory — bit-identical to the resident path
                let ds = Dataset::open(&PathBuf::from(dir))?;
                let man = ds.manifest();
                eprintln!("store: N={} D={} Q={} chunk_rows={} chunks={}  \
                           backend={} workers={}",
                          man.n, man.d, man.q, man.chunk_rows, man.num_chunks(),
                          cfg.backend.name(), cfg.workers);
                let problem = SparseGpRegression::problem_from_store(
                    ds.source(), m, &aot, seed)?;
                let engine = Engine::new(problem, cfg)?;
                let r = engine.train()?;
                println!("bound: {:.4}  iters: {}", r.f, r.iterations);
                println!("final bound: {:.17e}", r.f);
                println!("timing: {}", r.timing.summary());
            } else if let Some(csvp) = args.get("data-csv") {
                // resident CSV path: same column convention as `ingest`
                // (first q columns x, the rest y), same token parser —
                // the printed full-precision bound must match the
                // `--data-dir` path bit for bit (CI pins this)
                let q = args.get_parse("q", 1usize)?;
                let mat = read_matrix(&PathBuf::from(csvp), args.flag("has-header"))?;
                if mat.cols() <= q {
                    bail!("{csvp}: {} columns, need more than q={q}", mat.cols());
                }
                let n = mat.rows();
                let x = Mat::from_fn(n, q, |i, j| mat[(i, j)]);
                let y = Mat::from_fn(n, mat.cols() - q, |i, j| mat[(i, q + j)]);
                let problem = SparseGpRegression::problem(&x, &y, m, &aot, seed);
                let engine = Engine::new(problem, cfg)?;
                let r = engine.train()?;
                println!("bound: {:.4}  iters: {}", r.f, r.iterations);
                println!("final bound: {:.17e}", r.f);
                println!("timing: {}", r.timing.summary());
            } else {
                let spec = SyntheticSpec {
                    n: args.get_parse("n", 1000usize)?,
                    q: args.get_parse("q", 1usize)?,
                    d: args.get_parse("d", 1usize)?,
                    ..Default::default()
                };
                let ds = generate_supervised(&spec, seed);
                let x = ds.x().unwrap();
                let model = SparseGpRegression::fit(&x, &ds.y(), m, &aot, cfg, seed)?;
                let r = &model.result;
                println!("bound: {:.4}  iters: {}  train-RMSE: {:.4}",
                         r.f, r.iterations, model.rmse(&x, &ds.y()));
                println!("final bound: {:.17e}", r.f);
                println!("timing: {}", r.timing.summary());
            }
        }
        "ingest" => {
            let csv = PathBuf::from(args.require("csv")?);
            let out = PathBuf::from(args.require("out")?);
            let q = args.get_parse("q", 0usize)?;
            let chunk_rows = args.get_parse("chunk-rows", DEFAULT_CHUNK_ROWS)?;
            if chunk_rows == 0 {
                bail!("--chunk-rows must be positive");
            }
            let man = ingest_csv(&csv, q, &out, chunk_rows,
                                 args.flag("center"), args.flag("has-header"))?;
            println!("ingested {} rows into {}: q={} d={} chunk_rows={} chunks={}",
                     man.n, out.display(), man.q, man.d, man.chunk_rows,
                     man.num_chunks());
        }
        "predict" => {
            let spec = SyntheticSpec {
                n: args.get_parse("n", 2000usize)?,
                q: args.get_parse("q", 1usize)?,
                d: args.get_parse("d", 1usize)?,
                ..Default::default()
            };
            let seed = args.get_parse("seed", 0u64)?;
            let m = args.get_parse("m", 32usize)?;
            let nt = args.get_parse("nt", 1000usize)?;
            let batch = args.get_parse("batch", 256usize)?;
            let (cfg, aot) = engine_config(&args)?;

            let ds = generate_supervised(&spec, seed);
            let x = ds.x().unwrap();
            // held-out batch from the same generator, different seed
            let test_spec = SyntheticSpec { n: nt, ..spec.clone() };
            let test = generate_supervised(&test_spec, seed.wrapping_add(1));
            let xstar = test.x().unwrap();

            eprintln!("dataset: N={} Nt={nt} Q={} D={}  backend={} workers={} batch={batch}",
                      spec.n, spec.q, spec.d, cfg.backend.name(), cfg.workers);
            let problem = SparseGpRegression::problem(&x, &ds.y(), m, &aot, seed);
            let engine = Engine::new(problem, cfg)?;

            if args.flag("serve") {
                // long-running concurrent-client mode: N closed-loop
                // client threads drive the micro-batching front-end,
                // requests round-robin over the held-out rows
                if args.flag("refit-demo") || args.flag("stream") {
                    bail!("--serve is exclusive with --refit-demo and --stream \
                           (it is its own serving mode)");
                }
                let clients = args.get_parse("clients", 4usize)?;
                let per_client = args.get_parse("serve-requests", 64usize)?;
                let req_rows = args.get_parse("req-rows", 1usize)?;
                if clients == 0 || per_client == 0 || req_rows == 0 {
                    bail!("--clients, --serve-requests and --req-rows must be positive");
                }
                let fcfg = FrontendConfig {
                    max_batch_rows: args.get_parse("max-batch-rows", 256usize)?,
                    max_wait: Duration::from_micros(args.get_parse("max-wait-us", 200u64)?),
                    queue_rows: args.get_parse("queue-rows", 4096usize)?,
                    dump_every: Some(Duration::from_secs(1)),
                };
                let ranks = engine.cfg.workers.max(1);
                let rpc = ((fcfg.max_batch_rows + ranks - 1) / ranks).max(1);
                eprintln!("serving: {clients} client(s) × {per_client} request(s) × \
                           {req_rows} row(s); micro-batch ≤{} rows, deadline {}µs, \
                           queue {} rows",
                          fcfg.max_batch_rows, fcfg.max_wait.as_micros(), fcfg.queue_rows);
                let q = spec.q;
                let xs = xstar.as_slice();
                let (r, failed, report) = engine.train_then_serve(rpc, fcfg, |handle| {
                    std::thread::scope(|s| {
                        let joins: Vec<_> = (0..clients).map(|c| {
                            let h = handle.clone();
                            s.spawn(move || {
                                let mut failed = 0usize;
                                for i in 0..per_client {
                                    let start = ((c * per_client + i) * req_rows) % nt;
                                    let mut rows = Vec::with_capacity(req_rows * q);
                                    for k in 0..req_rows {
                                        let row = (start + k) % nt;
                                        rows.extend_from_slice(&xs[row * q..(row + 1) * q]);
                                    }
                                    if h.predict(Mat::from_vec(req_rows, q, rows)).is_err() {
                                        failed += 1;
                                    }
                                }
                                failed
                            })
                        }).collect();
                        joins.into_iter()
                             .map(|j| j.join().expect("client thread panicked"))
                             .sum::<usize>()
                    })
                })?;
                println!("bound: {:.4}  iters: {}  evals: {}",
                         r.f, r.iterations, r.evaluations);
                if failed > 0 {
                    println!("{failed} request(s) failed");
                }
                println!("{}", report.snapshot.render_text());
                println!("# serve phases: {}", report.timer.summary());
                println!("{}", report.snapshot.to_json().to_string_pretty());
                return Ok(());
            }

            let (r, pred_mean, pred_var) = if args.flag("refit-demo") {
                if args.flag("stream") {
                    bail!("--refit-demo and --stream are mutually exclusive \
                           (the refit demo serves sequentially)");
                }
                // serve, hot-swap the posterior at the fitted parameters
                // (a full distributed STATS round + swap broadcast, the
                // session stays open), serve again: the swap must change
                // nothing, and the printed |Δ| proves it
                let (r, (m1, v1), (m2, v2)) = engine.train_predict_refit(&xstar, batch)?;
                let mut dmax = m1.max_abs_diff(&m2);
                for (a, b) in v1.iter().zip(&v2) {
                    dmax = dmax.max((a - b).abs());
                }
                println!("hot-swap at fitted params: max |Δ| vs pre-swap = {dmax:.1e} \
                          (must be 0e0)");
                (r, m2, v2)
            } else if args.flag("stream") {
                // streamed serving: --batch rows per stream batch, split
                // across the ranks at a granularity that keeps every
                // rank busy within each batch
                if batch == 0 {
                    bail!("--batch must be positive");
                }
                let ranks = engine.cfg.workers.max(1);
                let rpc = ((batch + ranks - 1) / ranks).max(1);
                let out = engine.train_then_predict_stream(&xstar, rpc, batch)?;
                println!("streamed {} batch(es) of ≤{batch} rows (shard chunk {rpc})",
                         (nt + batch - 1) / batch);
                out
            } else {
                engine.train_then_predict(&xstar, batch)?
            };

            let ystar = test.y();
            let mut se = 0.0;
            for i in 0..nt {
                for j in 0..ystar.cols() {
                    let e = pred_mean[(i, j)] - ystar[(i, j)];
                    se += e * e;
                }
            }
            let rmse = (se / (nt * ystar.cols()) as f64).sqrt();
            println!("bound: {:.4}  iters: {}  evals: {}", r.f, r.iterations, r.evaluations);
            println!("served {nt} rows across {} rank(s): test-RMSE {:.4}  mean var {:.4}",
                     engine.cfg.workers, rmse, mean(&pred_var));
            println!("timing: {}", r.timing.summary());
        }
        "time" => {
            let spec = SyntheticSpec {
                n: args.get_parse("n", 8000usize)?,
                q: args.get_parse("q", 1usize)?,
                d: args.get_parse("d", 3usize)?,
                ..Default::default()
            };
            let seed = args.get_parse("seed", 0u64)?;
            let m = args.get_parse("m", 100usize)?;
            let evals = args.get_parse("evals", 5usize)?;
            let (cfg, aot) = engine_config(&args)?;
            let ds = generate(&spec, seed);
            let problem = BayesianGplvm::problem(&ds.y(), spec.q, m, &aot, seed);
            let engine = Engine::new(problem, cfg)?;
            let r = engine.time_iterations(evals)?;
            println!("N={} workers={} backend={}  sec/iter={:.4}  indist={:.2}%  bytes={}",
                     spec.n, engine.cfg.workers, engine.cfg.backend.name(),
                     r.sec_per_eval, r.timing.indistributable_fraction() * 100.0,
                     r.bytes_sent);
        }
        "info" => {
            let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
            let man = Manifest::load(&dir)?;
            println!("artifact configs in {}:", dir.display());
            let mut seen = std::collections::BTreeSet::new();
            for cfg in man.config_names() {
                if seen.insert(cfg.to_string()) {
                    let d = man.dims(cfg)?;
                    println!("  {cfg}: chunk={} M={} Q={} D={}", d.c, d.m, d.q, d.d);
                }
            }
        }
        _ => {
            println!("usage: gpparallel <train-bgplvm|train-sgpr|ingest|predict|time|info> [options]");
            println!("options: --n --q --d --m --workers --chunk --backend cpu|parallel[:N]|xla");
            println!("         --iters --evals --seed --artifacts --aot-config --verbose");
            println!("         --simd off|scalar|native|auto (f64 microkernel dispatch tier)");
            println!("         --data-dir <store> | --data-csv <file> (train-sgpr: train from an");
            println!("           on-disk chunk store / a CSV; csv splits at --q columns)");
            println!("         ingest: --csv <file> --out <dir> [--q N] [--chunk-rows N]");
            println!("           [--center] [--has-header] (CSV -> chunk store, O(chunk) memory)");
            println!("         --nt --batch (predict: test rows, serving batch granularity)");
            println!("         --refit-demo (predict: hot-swap the posterior mid-session)");
            println!("         --stream (predict: pipeline --batch-row serving batches)");
            println!("         --serve (predict: concurrent-client micro-batching front-end;");
            println!("           knobs: --clients --serve-requests --req-rows");
            println!("           --max-batch-rows --max-wait-us --queue-rows)");
            println!("         --no-pipeline (synchronous evaluation cycle)");
            println!("(options are validated per subcommand; see each command's scope)");
            if cmd != "help" {
                bail!("unknown command {cmd:?}");
            }
        }
    }
    Ok(())
}
