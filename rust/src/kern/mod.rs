//! Covariance-function substrate: the RBF-ARD kernel with its closed-form
//! psi statistics (expectations under a diagonal-Gaussian q(X)) and their
//! analytic gradients.
//!
//! This module is the pure-Rust mirror of `python/compile/kernels/` — it
//! is the scalar "CPU core" backend of the paper's comparison (the role
//! GPy's NumPy code plays in the paper), and doubles as the independent
//! oracle the XLA path is integration-tested against.

pub mod rbf;

pub use rbf::RbfArd;

/// Hyperparameters travel as `log_hyp = [log σ², log ℓ_1, …, log ℓ_Q]` —
/// identical packing to the Python side (compile/kernels/ref.py).
pub fn log_hyp_dim(q: usize) -> usize {
    q + 1
}
