//! RBF-ARD kernel: exact covariances, psi statistics, and their analytic
//! gradients (the Rust form of the paper's Table 1 + Table 2 loops).
//!
//!   k(x, x′) = σ² exp(−½ Σ_q α_q (x_q − x′_q)²),  α_q = ℓ_q⁻²
//!
//! All formulas match `python/compile/kernels/ref.py` exactly (including
//! the jitter convention in `kuu`), so the two implementations agree to
//! rounding error — asserted by `rust/tests/xla_vs_rust.rs`.

use crate::linalg::simd::{self, SimdLevel};
use crate::linalg::Mat;
use std::cell::RefCell;

thread_local! {
    // Per-thread α scratch for the allocation-free serving hot path
    // (`k_row_into` computes α once per call, not once per inducing point).
    static ALPHA_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// RBF-ARD kernel hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct RbfArd {
    /// Signal variance σ².
    pub variance: f64,
    /// Per-dimension lengthscales ℓ_q.
    pub lengthscales: Vec<f64>,
}

impl RbfArd {
    /// Construct from variance σ² and per-dimension lengthscales ℓ_q
    /// (all strictly positive).
    pub fn new(variance: f64, lengthscales: Vec<f64>) -> Self {
        assert!(variance > 0.0);
        assert!(lengthscales.iter().all(|&l| l > 0.0));
        RbfArd { variance, lengthscales }
    }

    /// Isotropic constructor.
    pub fn iso(variance: f64, lengthscale: f64, q: usize) -> Self {
        RbfArd::new(variance, vec![lengthscale; q])
    }

    /// Input dimensionality Q.
    pub fn q(&self) -> usize {
        self.lengthscales.len()
    }

    /// α_q = ℓ_q⁻².
    pub fn alpha(&self) -> Vec<f64> {
        self.lengthscales.iter().map(|l| 1.0 / (l * l)).collect()
    }

    /// Pack as `[log σ², log ℓ_1, …]` (the wire format shared with L2).
    pub fn to_log_hyp(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.q() + 1);
        v.push(self.variance.ln());
        v.extend(self.lengthscales.iter().map(|l| l.ln()));
        v
    }

    /// Inverse of [`RbfArd::to_log_hyp`].
    pub fn from_log_hyp(log_hyp: &[f64]) -> Self {
        RbfArd {
            variance: log_hyp[0].exp(),
            lengthscales: log_hyp[1..].iter().map(|l| l.exp()).collect(),
        }
    }

    // -----------------------------------------------------------------
    // exact covariances
    // -----------------------------------------------------------------

    /// Cross-covariance `K(a, b)`, `a: n×Q`, `b: m×Q` → `n×m`. The
    /// exponent is the fused SIMD `wsq_diff` primitive (weights α); its
    /// `off` tier is exactly the pre-SIMD ascending-q scalar loop.
    pub fn k(&self, a: &Mat, b: &Mat) -> Mat {
        self.k_at(simd::active(), a, b)
    }

    fn k_at(&self, level: SimdLevel, a: &Mat, b: &Mat) -> Mat {
        let alpha = self.alpha();
        let q = self.q();
        assert_eq!(a.cols(), q);
        assert_eq!(b.cols(), q);
        Mat::from_fn(a.rows(), b.rows(), |i, j| {
            let r2 = simd::wsq_diff_at(level, &alpha, a.row(i), b.row(j));
            self.variance * (-0.5 * r2).exp()
        })
    }

    /// `K_uu` with the shared jitter convention (must match ref.kuu).
    pub fn kuu(&self, z: &Mat) -> Mat {
        let mut k = self.k(z, z);
        k.add_diag(1e-8 * self.variance + 1e-12);
        k
    }

    /// Diagonal of `K(x, x)` — constant σ² for RBF.
    pub fn kdiag(&self, n: usize) -> Vec<f64> {
        vec![self.variance; n]
    }

    /// `k(x, x)` for a single input row — the constant σ² for this
    /// stationary kernel. The predictive equations route `k**` through
    /// here (rather than reading `variance` at the call site) so a
    /// future non-stationary kernel cannot silently miscompute the
    /// predictive variance.
    pub fn kdiag_at(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.q());
        self.variance
    }

    /// One row of `K(x, Z)` written into `out` (length = Z rows) without
    /// allocating — the serving hot path's kernel evaluation. α is
    /// computed once per call into a thread-local scratch (one division
    /// per dimension, not per inducing point); each output element then
    /// runs the same fused SIMD `wsq_diff` exponent as [`RbfArd::k`] at
    /// the same dispatch level, so the two agree bit for bit at every
    /// tier.
    pub fn k_row_into(&self, x: &[f64], z: &Mat, out: &mut [f64]) {
        self.k_row_into_at(simd::active(), x, z, out)
    }

    fn k_row_into_at(&self, level: SimdLevel, x: &[f64], z: &Mat, out: &mut [f64]) {
        let q = self.q();
        assert_eq!(x.len(), q, "input row Q mismatch");
        assert_eq!(z.cols(), q, "Z Q mismatch");
        assert_eq!(out.len(), z.rows(), "output length");
        ALPHA_SCRATCH.with(|cell| {
            let mut alpha = cell.borrow_mut();
            alpha.clear();
            alpha.extend(self.lengthscales.iter().map(|l| 1.0 / (l * l)));
            for (j, o) in out.iter_mut().enumerate() {
                let r2 = simd::wsq_diff_at(level, &alpha, x, z.row(j));
                *o = self.variance * (-0.5 * r2).exp();
            }
        });
    }

    // -----------------------------------------------------------------
    // psi statistics (forward)
    // -----------------------------------------------------------------

    /// ψ0 = Σ_n w_n σ².
    pub fn psi0(&self, w: &[f64]) -> f64 {
        self.variance * w.iter().sum::<f64>()
    }

    /// Ψ1 `n×m`: ⟨K_fu⟩ under q(X) = N(μ, diag S).
    ///
    /// At the `off` SIMD tier the exponent runs the original per-term
    /// `α d²/(αS+1)` loop bit-for-bit; at `scalar`/`native` the
    /// denominators are hoisted per point (`invd_q = α_q/(α_q S_q + 1)`,
    /// one division per dimension instead of per inducing point) and the
    /// exponent becomes the fused `wsq_diff` primitive — tight-ulp, not
    /// bitwise, against `off`.
    pub fn psi1(&self, mu: &Mat, s: &Mat, z: &Mat) -> Mat {
        self.psi1_at(simd::active(), mu, s, z)
    }

    fn psi1_at(&self, level: SimdLevel, mu: &Mat, s: &Mat, z: &Mat) -> Mat {
        let alpha = self.alpha();
        let q = self.q();
        let (n, m) = (mu.rows(), z.rows());
        let mut out = Mat::zeros(n, m);
        let mut invd = vec![0.0; q];
        for i in 0..n {
            let (mr, sr) = (mu.row(i), s.row(i));
            // per-point coefficient σ² Π_q (α S + 1)^{-1/2}
            let mut logcoef = self.variance.ln();
            for qq in 0..q {
                logcoef -= 0.5 * (alpha[qq] * sr[qq] + 1.0).ln();
            }
            if level != SimdLevel::Off {
                for qq in 0..q {
                    invd[qq] = alpha[qq] / (alpha[qq] * sr[qq] + 1.0);
                }
            }
            for j in 0..m {
                let zr = z.row(j);
                let expo = if level == SimdLevel::Off {
                    let mut expo = 0.0;
                    for qq in 0..q {
                        let dnm = alpha[qq] * sr[qq] + 1.0;
                        let diff = mr[qq] - zr[qq];
                        expo += alpha[qq] * diff * diff / dnm;
                    }
                    expo
                } else {
                    simd::wsq_diff_at(level, &invd, mr, zr)
                };
                out[(i, j)] = (logcoef - 0.5 * expo).exp();
            }
        }
        out
    }

    /// Ψ2 `m×m`: Σ_n w_n ⟨(K_fu)_nᵀ(K_fu)_n⟩.
    ///
    /// At the `off` SIMD tier the exponent runs the original interleaved
    /// `¼α dz² + α g²/e` loop bit-for-bit; at `scalar`/`native` it splits
    /// into two fused reductions — `wsq_diff` with weights ¼α over the
    /// inducing pair, plus `wsq_mid_diff` with weights α/e against the
    /// pair midpoint — hoisting the per-point divisions out of the m²
    /// pair loop. Tight-ulp, not bitwise, against `off`.
    pub fn psi2(&self, mu: &Mat, s: &Mat, w: &[f64], z: &Mat) -> Mat {
        self.psi2_at(simd::active(), mu, s, w, z)
    }

    fn psi2_at(&self, level: SimdLevel, mu: &Mat, s: &Mat, w: &[f64], z: &Mat) -> Mat {
        let alpha = self.alpha();
        let q = self.q();
        let (n, m) = (mu.rows(), z.rows());
        assert_eq!(w.len(), n);
        let sigma4 = self.variance * self.variance;

        // ¼α is exact (power-of-two scale); α/e is refreshed per point.
        let qa: Vec<f64> = alpha.iter().map(|a| 0.25 * a).collect();
        let mut ae = vec![0.0; q];
        let mut out = Mat::zeros(m, m);
        for i in 0..n {
            if w[i] == 0.0 {
                continue;
            }
            let (mr, sr) = (mu.row(i), s.row(i));
            let mut coef = sigma4 * w[i];
            for qq in 0..q {
                coef /= (2.0 * alpha[qq] * sr[qq] + 1.0).sqrt();
            }
            if level != SimdLevel::Off {
                for qq in 0..q {
                    ae[qq] = alpha[qq] / (2.0 * alpha[qq] * sr[qq] + 1.0);
                }
            }
            for m1 in 0..m {
                let z1 = z.row(m1);
                // symmetric: fill upper triangle then mirror
                for m2 in m1..m {
                    let z2 = z.row(m2);
                    let expo = if level == SimdLevel::Off {
                        let mut expo = 0.0;
                        for qq in 0..q {
                            let e = 2.0 * alpha[qq] * sr[qq] + 1.0;
                            let dz = z1[qq] - z2[qq];
                            let g = mr[qq] - 0.5 * (z1[qq] + z2[qq]);
                            expo += 0.25 * alpha[qq] * dz * dz + alpha[qq] * g * g / e;
                        }
                        expo
                    } else {
                        simd::wsq_diff_at(level, &qa, z1, z2)
                            + simd::wsq_mid_diff_at(level, &ae, mr, z1, z2)
                    };
                    let v = coef * (-expo).exp();
                    out[(m1, m2)] += v;
                    if m1 != m2 {
                        out[(m2, m1)] += v;
                    }
                }
            }
        }
        out
    }

    // -----------------------------------------------------------------
    // psi statistics (VJP) — the Table-2 gradient loops
    // -----------------------------------------------------------------

    /// Pull a cotangent `ct` (n×m) of Ψ1 back to (dμ, dS, dZ, d log_hyp).
    pub fn psi1_vjp(&self, mu: &Mat, s: &Mat, z: &Mat, ct: &Mat)
                    -> (Mat, Mat, Mat, Vec<f64>) {
        let p1 = self.psi1(mu, s, z);
        self.psi1_vjp_with(mu, s, z, ct, &p1)
    }

    /// [`psi1_vjp`](RbfArd::psi1_vjp) with the forward Ψ1 supplied — the
    /// fwd→vjp cache path. `p1` must equal `psi1(mu, s, z)` for these
    /// inputs (its S = 0 limit `k(mu, z)` is the supervised case).
    ///
    /// The per-dimension loop here stays scalar at every SIMD tier: Q is
    /// 1–3 in every model in this repo, below the 4-wide lane width, so
    /// the lane primitives would degenerate to the same sequential tail.
    /// The O(N·M·D) cotangent build feeding this VJP *is* vectorized — it
    /// rides the SIMD `dot` in `math::stats`.
    pub fn psi1_vjp_with(&self, mu: &Mat, s: &Mat, z: &Mat, ct: &Mat, p1: &Mat)
                         -> (Mat, Mat, Mat, Vec<f64>) {
        let alpha = self.alpha();
        let q = self.q();
        let (n, m) = (mu.rows(), z.rows());
        assert_eq!((ct.rows(), ct.cols()), (n, m));
        assert_eq!((p1.rows(), p1.cols()), (n, m));
        let mut dmu = Mat::zeros(n, q);
        let mut ds = Mat::zeros(n, q);
        let mut dz = Mat::zeros(m, q);
        let mut dlogvar = 0.0;
        let mut dalpha = vec![0.0; q];

        for i in 0..n {
            let (mr, sr) = (mu.row(i), s.row(i));
            for j in 0..m {
                let c = ct[(i, j)] * p1[(i, j)];
                if c == 0.0 {
                    continue;
                }
                dlogvar += c; // ∂Ψ1/∂logσ² = Ψ1
                let zr = z.row(j);
                for qq in 0..q {
                    let a = alpha[qq];
                    let d = a * sr[qq] + 1.0;
                    let diff = mr[qq] - zr[qq];
                    let gmu = -a * diff / d;
                    dmu[(i, qq)] += c * gmu;
                    dz[(j, qq)] -= c * gmu;
                    ds[(i, qq)] += c * (-0.5 * a / d + 0.5 * a * a * diff * diff / (d * d));
                    dalpha[qq] += c * (-0.5 * sr[qq] / d - 0.5 * diff * diff / (d * d));
                }
            }
        }
        let mut dhyp = vec![0.0; q + 1];
        dhyp[0] = dlogvar;
        for qq in 0..q {
            dhyp[1 + qq] = -2.0 * alpha[qq] * dalpha[qq]; // dα/dlogℓ = −2α
        }
        (dmu, ds, dz, dhyp)
    }

    /// Pull a cotangent `ct` (m×m, not assumed symmetric) of Ψ2 back to
    /// (dμ, dS, dZ, d log_hyp). Detects a symmetric cotangent (the case
    /// the leader always produces) and dispatches to the half-loop fast
    /// path — a measured ~1.9x on the worker VJP (EXPERIMENTS.md §Perf).
    pub fn psi2_vjp(&self, mu: &Mat, s: &Mat, w: &[f64], z: &Mat, ct: &Mat)
                    -> (Mat, Mat, Mat, Vec<f64>) {
        let m = z.rows();
        let mut symmetric = true;
        'outer: for i in 0..m {
            for j in (i + 1)..m {
                if ct[(i, j)] != ct[(j, i)] {
                    symmetric = false;
                    break 'outer;
                }
            }
        }
        if symmetric {
            self.psi2_vjp_sym(mu, s, w, z, ct)
        } else {
            self.psi2_vjp_general(mu, s, w, z, ct)
        }
    }

    /// General (dense-pair) VJP loop; reference implementation. The
    /// per-pair exponent recompute rides the same fused SIMD reductions
    /// as [`RbfArd::psi2`] (with the original interleaved loop as the
    /// `off` tier); the per-dimension gradient accumulation stays scalar
    /// — Q is 1–3 in every model here, below the 4-wide lane width.
    pub fn psi2_vjp_general(&self, mu: &Mat, s: &Mat, w: &[f64], z: &Mat, ct: &Mat)
                            -> (Mat, Mat, Mat, Vec<f64>) {
        self.psi2_vjp_general_at(simd::active(), mu, s, w, z, ct)
    }

    fn psi2_vjp_general_at(&self, level: SimdLevel, mu: &Mat, s: &Mat, w: &[f64],
                           z: &Mat, ct: &Mat) -> (Mat, Mat, Mat, Vec<f64>) {
        let alpha = self.alpha();
        let q = self.q();
        let (n, m) = (mu.rows(), z.rows());
        let sigma4 = self.variance * self.variance;

        let qa: Vec<f64> = alpha.iter().map(|a| 0.25 * a).collect();
        let mut ae = vec![0.0; q];
        let mut dmu = Mat::zeros(n, q);
        let mut ds = Mat::zeros(n, q);
        let mut dz = Mat::zeros(m, q);
        let mut dlogvar = 0.0;
        let mut dalpha = vec![0.0; q];

        for i in 0..n {
            if w[i] == 0.0 {
                continue;
            }
            let (mr, sr) = (mu.row(i), s.row(i));
            let mut coef = sigma4 * w[i];
            for qq in 0..q {
                coef /= (2.0 * alpha[qq] * sr[qq] + 1.0).sqrt();
            }
            if level != SimdLevel::Off {
                for qq in 0..q {
                    ae[qq] = alpha[qq] / (2.0 * alpha[qq] * sr[qq] + 1.0);
                }
            }
            for m1 in 0..m {
                let z1 = z.row(m1);
                for m2 in 0..m {
                    let cij = ct[(m1, m2)];
                    if cij == 0.0 {
                        continue;
                    }
                    let z2 = z.row(m2);
                    let expo = if level == SimdLevel::Off {
                        let mut expo = 0.0;
                        for qq in 0..q {
                            let e = 2.0 * alpha[qq] * sr[qq] + 1.0;
                            let dzq = z1[qq] - z2[qq];
                            let g = mr[qq] - 0.5 * (z1[qq] + z2[qq]);
                            expo += 0.25 * alpha[qq] * dzq * dzq + alpha[qq] * g * g / e;
                        }
                        expo
                    } else {
                        simd::wsq_diff_at(level, &qa, z1, z2)
                            + simd::wsq_mid_diff_at(level, &ae, mr, z1, z2)
                    };
                    let t = coef * (-expo).exp();
                    let c = cij * t;
                    dlogvar += 2.0 * c; // ∂Ψ2/∂logσ² = 2Ψ2
                    for qq in 0..q {
                        let a = alpha[qq];
                        let e = 2.0 * a * sr[qq] + 1.0;
                        let dzq = z1[qq] - z2[qq];
                        let g = mr[qq] - 0.5 * (z1[qq] + z2[qq]);
                        dmu[(i, qq)] += c * (-2.0 * a * g / e);
                        ds[(i, qq)] += c * (-a / e + 2.0 * a * a * g * g / (e * e));
                        dz[(m1, qq)] += c * (-0.5 * a * dzq + a * g / e);
                        dz[(m2, qq)] += c * (0.5 * a * dzq + a * g / e);
                        dalpha[qq] += c * (-sr[qq] / e - 0.25 * dzq * dzq - g * g / (e * e));
                    }
                }
            }
        }
        let mut dhyp = vec![0.0; q + 1];
        dhyp[0] = dlogvar;
        for qq in 0..q {
            dhyp[1 + qq] = -2.0 * alpha[qq] * dalpha[qq];
        }
        (dmu, ds, dz, dhyp)
    }

    /// Symmetric-cotangent VJP: visits each unordered inducing pair once.
    /// For ct = ct^T the two orientations of a pair contribute identical
    /// (dmu, ds, dalpha) terms and mirrored dZ terms, so one visit with a
    /// factor of 2 (1 on the diagonal) is exact — verified against
    /// `psi2_vjp_general` by property test.
    pub fn psi2_vjp_sym(&self, mu: &Mat, s: &Mat, w: &[f64], z: &Mat, ct: &Mat)
                        -> (Mat, Mat, Mat, Vec<f64>) {
        self.psi2_vjp_sym_at(simd::active(), mu, s, w, z, ct)
    }

    fn psi2_vjp_sym_at(&self, level: SimdLevel, mu: &Mat, s: &Mat, w: &[f64],
                       z: &Mat, ct: &Mat) -> (Mat, Mat, Mat, Vec<f64>) {
        let alpha = self.alpha();
        let q = self.q();
        let (n, m) = (mu.rows(), z.rows());
        let sigma4 = self.variance * self.variance;

        let qa: Vec<f64> = alpha.iter().map(|a| 0.25 * a).collect();
        let mut ae = vec![0.0; q];
        let mut dmu = Mat::zeros(n, q);
        let mut ds = Mat::zeros(n, q);
        let mut dz = Mat::zeros(m, q);
        let mut dlogvar = 0.0;
        let mut dalpha = vec![0.0; q];

        for i in 0..n {
            if w[i] == 0.0 {
                continue;
            }
            let (mr, sr) = (mu.row(i), s.row(i));
            let mut coef = sigma4 * w[i];
            for qq in 0..q {
                coef /= (2.0 * alpha[qq] * sr[qq] + 1.0).sqrt();
            }
            if level != SimdLevel::Off {
                for qq in 0..q {
                    ae[qq] = alpha[qq] / (2.0 * alpha[qq] * sr[qq] + 1.0);
                }
            }
            for m1 in 0..m {
                let z1 = z.row(m1);
                for m2 in m1..m {
                    let factor = if m1 == m2 { 1.0 } else { 2.0 };
                    let cij = ct[(m1, m2)] * factor;
                    if cij == 0.0 {
                        continue;
                    }
                    let z2 = z.row(m2);
                    let expo = if level == SimdLevel::Off {
                        let mut expo = 0.0;
                        for qq in 0..q {
                            let e = 2.0 * alpha[qq] * sr[qq] + 1.0;
                            let dzq = z1[qq] - z2[qq];
                            let g = mr[qq] - 0.5 * (z1[qq] + z2[qq]);
                            expo += 0.25 * alpha[qq] * dzq * dzq + alpha[qq] * g * g / e;
                        }
                        expo
                    } else {
                        simd::wsq_diff_at(level, &qa, z1, z2)
                            + simd::wsq_mid_diff_at(level, &ae, mr, z1, z2)
                    };
                    let c = cij * coef * (-expo).exp();
                    dlogvar += 2.0 * c;
                    for qq in 0..q {
                        let a = alpha[qq];
                        let e = 2.0 * a * sr[qq] + 1.0;
                        let dzq = z1[qq] - z2[qq];
                        let g = mr[qq] - 0.5 * (z1[qq] + z2[qq]);
                        dmu[(i, qq)] += c * (-2.0 * a * g / e);
                        ds[(i, qq)] += c * (-a / e + 2.0 * a * a * g * g / (e * e));
                        dz[(m1, qq)] += c * (-0.5 * a * dzq + a * g / e);
                        dz[(m2, qq)] += c * (0.5 * a * dzq + a * g / e);
                        dalpha[qq] += c * (-sr[qq] / e - 0.25 * dzq * dzq - g * g / (e * e));
                    }
                }
            }
        }
        let mut dhyp = vec![0.0; q + 1];
        dhyp[0] = dlogvar;
        for qq in 0..q {
            dhyp[1 + qq] = -2.0 * alpha[qq] * dalpha[qq];
        }
        (dmu, ds, dz, dhyp)
    }

    /// Pull a cotangent of `K_uu` (m×m) back to (dZ, d log_hyp); includes
    /// the jitter term's σ² dependence, matching `ref.kuu`.
    pub fn kuu_vjp(&self, z: &Mat, ct: &Mat) -> (Mat, Vec<f64>) {
        let alpha = self.alpha();
        let q = self.q();
        let m = z.rows();
        let mut dz = Mat::zeros(m, q);
        let mut dlogvar = 0.0;
        let mut dalpha = vec![0.0; q];
        for m1 in 0..m {
            let z1 = z.row(m1);
            for m2 in 0..m {
                let c0 = ct[(m1, m2)];
                if c0 == 0.0 {
                    continue;
                }
                let z2 = z.row(m2);
                let r2 = simd::wsq_diff(&alpha, z1, z2);
                let k = self.variance * (-0.5 * r2).exp();
                let c = c0 * k;
                dlogvar += c;
                for qq in 0..q {
                    let d = z1[qq] - z2[qq];
                    let g = -alpha[qq] * d; // ∂k/∂z1 = k·(−α d)
                    dz[(m1, qq)] += c * g;
                    dz[(m2, qq)] -= c * g;
                    dalpha[qq] += c * (-0.5 * d * d);
                }
            }
            // jitter: (1e-8 σ²) on the diagonal, σ²-dependent
            dlogvar += ct[(m1, m1)] * 1e-8 * self.variance;
        }
        let mut dhyp = vec![0.0; q + 1];
        dhyp[0] = dlogvar;
        for qq in 0..q {
            dhyp[1 + qq] = -2.0 * alpha[qq] * dalpha[qq];
        }
        (dz, dhyp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fd::{assert_grad_close, grad_fd};
    use crate::testutil::prop::{Prop, Rng64};

    fn setup(rng: &mut Rng64, n: usize, m: usize, q: usize)
             -> (RbfArd, Mat, Mat, Vec<f64>, Mat) {
        let kern = RbfArd::new(
            rng.uniform_range(0.3, 2.0),
            (0..q).map(|_| rng.uniform_range(0.5, 2.0)).collect(),
        );
        let mu = Mat::from_fn(n, q, |_, _| rng.normal());
        let s = Mat::from_fn(n, q, |_, _| rng.uniform_range(0.1, 1.5));
        let w: Vec<f64> = (0..n).map(|_| if rng.uniform() < 0.8 { 1.0 } else { 0.0 }).collect();
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        (kern, mu, s, w, z)
    }

    #[test]
    fn log_hyp_roundtrip() {
        let k = RbfArd::new(1.7, vec![0.5, 2.0]);
        let k2 = RbfArd::from_log_hyp(&k.to_log_hyp());
        assert!((k.variance - k2.variance).abs() < 1e-15);
        assert!((k.lengthscales[1] - k2.lengthscales[1]).abs() < 1e-15);
    }

    #[test]
    fn prop_s_zero_collapses_to_exact_kernel() {
        // Ψ1(S=0) == K_fu and Ψ2(S=0) == K_ufᵀ diag(w) K_fu.
        Prop::new("psi_s0_limit").cases(20).run(|rng| {
            let (kern, mu, _, w, z) = setup(rng, 12, 5, 2);
            let s0 = Mat::zeros(12, 2);
            let kfu = kern.k(&mu, &z);
            assert!(kern.psi1(&mu, &s0, &z).max_abs_diff(&kfu) < 1e-12);
            let mut kw = kfu.clone();
            for i in 0..12 {
                for j in 0..5 {
                    kw[(i, j)] *= w[i];
                }
            }
            let want = kw.t_matmul(&kfu);
            assert!(kern.psi2(&mu, &s0, &w, &z).max_abs_diff(&want) < 1e-11);
        });
    }

    #[test]
    fn prop_psi2_symmetric() {
        Prop::new("psi2_symmetry").cases(20).run(|rng| {
            let (kern, mu, s, w, z) = setup(rng, 10, 6, 2);
            let p2 = kern.psi2(&mu, &s, &w, &z);
            assert!(p2.max_abs_diff(&p2.t()) < 1e-14);
        });
    }

    /// The allocation-free row kernel must agree with the full `k`
    /// matrix bit for bit, and `kdiag_at` with `kdiag`.
    #[test]
    fn prop_k_row_into_matches_k() {
        Prop::new("k_row_into").cases(15).run(|rng| {
            let (kern, mu, _, _, z) = setup(rng, 9, 5, 2);
            let full = kern.k(&mu, &z);
            let mut row = vec![0.0; 5];
            for i in 0..9 {
                kern.k_row_into(mu.row(i), &z, &mut row);
                for j in 0..5 {
                    assert!(row[j] == full[(i, j)], "row {i} col {j}");
                }
                assert_eq!(kern.kdiag_at(mu.row(i)), kern.kdiag(1)[0]);
            }
        });
    }

    #[test]
    fn psi0_is_weighted_variance() {
        let k = RbfArd::iso(2.5, 1.0, 1);
        assert!((k.psi0(&[1.0, 0.0, 1.0]) - 5.0).abs() < 1e-15);
    }

    /// Finite-difference check of the full psi1 VJP through a random
    /// cotangent projection, w.r.t. every parameter group.
    #[test]
    fn psi1_vjp_finite_difference() {
        let mut rng = Rng64::new(21);
        let (kern, mu, s, _, z) = setup(&mut rng, 7, 4, 2);
        let ct = Mat::from_fn(7, 4, |_, _| rng.normal());

        let (dmu, ds, dz, dhyp) = kern.psi1_vjp(&mu, &s, &z, &ct);

        // d/dmu
        let f_mu = |x: &[f64]| {
            let m = Mat::from_vec(7, 2, x.to_vec());
            kern.psi1(&m, &s, &z).dot(&ct)
        };
        assert_grad_close(dmu.as_slice(), &grad_fd(f_mu, mu.as_slice(), 1e-6),
                          1e-6, 1e-8, "psi1/dmu");
        // d/ds
        let f_s = |x: &[f64]| {
            let m = Mat::from_vec(7, 2, x.to_vec());
            kern.psi1(&mu, &m, &z).dot(&ct)
        };
        assert_grad_close(ds.as_slice(), &grad_fd(f_s, s.as_slice(), 1e-6),
                          1e-6, 1e-8, "psi1/ds");
        // d/dz
        let f_z = |x: &[f64]| {
            let m = Mat::from_vec(4, 2, x.to_vec());
            kern.psi1(&mu, &s, &m).dot(&ct)
        };
        assert_grad_close(dz.as_slice(), &grad_fd(f_z, z.as_slice(), 1e-6),
                          1e-6, 1e-8, "psi1/dz");
        // d/dlog_hyp
        let lh = kern.to_log_hyp();
        let f_h = |x: &[f64]| {
            RbfArd::from_log_hyp(x).psi1(&mu, &s, &z).dot(&ct)
        };
        assert_grad_close(&dhyp, &grad_fd(f_h, &lh, 1e-6), 1e-6, 1e-8, "psi1/dhyp");
    }

    #[test]
    fn psi2_vjp_finite_difference() {
        let mut rng = Rng64::new(22);
        let (kern, mu, s, w, z) = setup(&mut rng, 6, 4, 2);
        let ct = Mat::from_fn(4, 4, |_, _| rng.normal()); // NOT symmetric

        let (dmu, ds, dz, dhyp) = kern.psi2_vjp(&mu, &s, &w, &z, &ct);

        let f_mu = |x: &[f64]| {
            let m = Mat::from_vec(6, 2, x.to_vec());
            kern.psi2(&m, &s, &w, &z).dot(&ct)
        };
        assert_grad_close(dmu.as_slice(), &grad_fd(f_mu, mu.as_slice(), 1e-6),
                          1e-6, 1e-8, "psi2/dmu");
        let f_s = |x: &[f64]| {
            let m = Mat::from_vec(6, 2, x.to_vec());
            kern.psi2(&mu, &m, &w, &z).dot(&ct)
        };
        assert_grad_close(ds.as_slice(), &grad_fd(f_s, s.as_slice(), 1e-6),
                          1e-6, 1e-8, "psi2/ds");
        let f_z = |x: &[f64]| {
            let m = Mat::from_vec(4, 2, x.to_vec());
            kern.psi2(&mu, &s, &w, &m).dot(&ct)
        };
        assert_grad_close(dz.as_slice(), &grad_fd(f_z, z.as_slice(), 1e-6),
                          1e-6, 1e-8, "psi2/dz");
        let lh = kern.to_log_hyp();
        let f_h = |x: &[f64]| {
            RbfArd::from_log_hyp(x).psi2(&mu, &s, &w, &z).dot(&ct)
        };
        assert_grad_close(&dhyp, &grad_fd(f_h, &lh, 1e-6), 1e-6, 1e-8, "psi2/dhyp");
    }

    #[test]
    fn kuu_vjp_finite_difference() {
        let mut rng = Rng64::new(23);
        let (kern, _, _, _, z) = setup(&mut rng, 3, 5, 2);
        let ct = Mat::from_fn(5, 5, |_, _| rng.normal());
        let (dz, dhyp) = kern.kuu_vjp(&z, &ct);

        let f_z = |x: &[f64]| {
            let m = Mat::from_vec(5, 2, x.to_vec());
            kern.kuu(&m).dot(&ct)
        };
        assert_grad_close(dz.as_slice(), &grad_fd(f_z, z.as_slice(), 1e-6),
                          1e-6, 1e-8, "kuu/dz");
        let lh = kern.to_log_hyp();
        let f_h = |x: &[f64]| RbfArd::from_log_hyp(x).kuu(&z).dot(&ct);
        assert_grad_close(&dhyp, &grad_fd(f_h, &lh, 1e-6), 1e-6, 1e-8, "kuu/dhyp");
    }

    /// Feeding the forward Ψ1 back into the VJP (the fwd→vjp cache path)
    /// must be bit-identical to the recomputing entry point, and the S=0
    /// exact-kernel form must agree to rounding error.
    #[test]
    fn prop_psi1_vjp_with_matches_recompute() {
        Prop::new("psi1_vjp_cached").cases(10).run(|rng| {
            let (kern, mu, s, _, z) = setup(rng, 8, 4, 2);
            let ct = Mat::from_fn(8, 4, |_, _| rng.normal());
            let a = kern.psi1_vjp(&mu, &s, &z, &ct);
            let p1 = kern.psi1(&mu, &s, &z);
            let b = kern.psi1_vjp_with(&mu, &s, &z, &ct, &p1);
            assert!(a.0.max_abs_diff(&b.0) == 0.0, "dmu");
            assert!(a.1.max_abs_diff(&b.1) == 0.0, "ds");
            assert!(a.2.max_abs_diff(&b.2) == 0.0, "dz");
            assert_eq!(a.3, b.3, "dhyp");

            // supervised limit: k(x, z) is a valid Ψ1(S = 0) cache
            let s0 = Mat::zeros(8, 2);
            let a = kern.psi1_vjp(&mu, &s0, &z, &ct);
            let b = kern.psi1_vjp_with(&mu, &s0, &z, &ct, &kern.k(&mu, &z));
            assert!(a.2.max_abs_diff(&b.2) < 1e-12, "dz (S=0)");
            for (x, y) in a.3.iter().zip(&b.3) {
                assert!((x - y).abs() < 1e-12 * (1.0 + x.abs()), "dhyp (S=0)");
            }
        });
    }

    #[test]
    fn prop_sym_fast_path_matches_general() {
        Prop::new("psi2_vjp_sym").cases(15).run(|rng| {
            let (kern, mu, s, w, z) = setup(rng, 9, 5, 2);
            let half = Mat::from_fn(5, 5, |_, _| rng.normal());
            let mut ct = half.clone();
            ct.axpy(1.0, &half.t()); // symmetric
            let a = kern.psi2_vjp_general(&mu, &s, &w, &z, &ct);
            let b = kern.psi2_vjp_sym(&mu, &s, &w, &z, &ct);
            assert!(a.0.max_abs_diff(&b.0) < 1e-12, "dmu");
            assert!(a.1.max_abs_diff(&b.1) < 1e-12, "ds");
            assert!(a.2.max_abs_diff(&b.2) < 1e-12, "dz");
            for (x, y) in a.3.iter().zip(&b.3) {
                assert!((x - y).abs() < 1e-12, "dhyp");
            }
            // and the dispatcher picks the same answer
            let c = kern.psi2_vjp(&mu, &s, &w, &z, &ct);
            assert!(c.2.max_abs_diff(&b.2) < 1e-15);
        });
    }

    /// Every SIMD-rewritten kernel × every dispatch level vs the `off`
    /// tier (the exact pre-SIMD scalar order), over ragged Q up to 9 —
    /// past the 4-wide lane boundary with non-multiple tails. The psi
    /// outputs pass through `exp`, which amplifies the exponent's ulp
    /// error by its magnitude, hence the generous ulp budget backed by a
    /// tiny absolute-tolerance escape for the deep tails; the VJP sums
    /// can cancel, hence their absolute escape.
    #[test]
    fn prop_simd_kernels_match_off_reference() {
        use crate::testutil::ulp::{assert_close_ulps, assert_mat_close_ulps};
        Prop::new("rbf_kernels_vs_off").cases(12).run(|rng| {
            let q = 1 + (rng.next_u64() % 9) as usize;
            let n = 1 + (rng.next_u64() % 8) as usize;
            let m = 1 + (rng.next_u64() % 7) as usize;
            let (kern, mu, s, w, z) = setup(rng, n, m, q);
            let ct = Mat::from_fn(m, m, |_, _| rng.normal());
            let off = SimdLevel::Off;
            let k_off = kern.k_at(off, &mu, &z);
            let p1_off = kern.psi1_at(off, &mu, &s, &z);
            let p2_off = kern.psi2_at(off, &mu, &s, &w, &z);
            let vjp_off = kern.psi2_vjp_general_at(off, &mu, &s, &w, &z, &ct);
            let sym_off = kern.psi2_vjp_sym_at(off, &mu, &s, &w, &z, &ct);
            let mut row = vec![0.0; m];
            for level in SimdLevel::ALL {
                let tag = level.name();
                assert_mat_close_ulps(&kern.k_at(level, &mu, &z), &k_off,
                                      4096, 1e-12, &format!("k {tag}"));
                // k_row_into must stay bit-for-bit with k at its own level
                let full = kern.k_at(level, &mu, &z);
                for i in 0..n {
                    kern.k_row_into_at(level, mu.row(i), &z, &mut row);
                    for j in 0..m {
                        assert!(row[j] == full[(i, j)],
                                "k_row_into {tag} row {i} col {j}");
                    }
                }
                assert_mat_close_ulps(&kern.psi1_at(level, &mu, &s, &z), &p1_off,
                                      4096, 1e-12, &format!("psi1 {tag}"));
                assert_mat_close_ulps(&kern.psi2_at(level, &mu, &s, &w, &z), &p2_off,
                                      4096, 1e-12, &format!("psi2 {tag}"));
                for (got, want, what) in [
                    (kern.psi2_vjp_general_at(level, &mu, &s, &w, &z, &ct), &vjp_off,
                     "psi2_vjp_general"),
                    (kern.psi2_vjp_sym_at(level, &mu, &s, &w, &z, &ct), &sym_off,
                     "psi2_vjp_sym"),
                ] {
                    assert_mat_close_ulps(&got.0, &want.0, 4096, 1e-9,
                                          &format!("{what}/dmu {tag}"));
                    assert_mat_close_ulps(&got.1, &want.1, 4096, 1e-9,
                                          &format!("{what}/ds {tag}"));
                    assert_mat_close_ulps(&got.2, &want.2, 4096, 1e-9,
                                          &format!("{what}/dz {tag}"));
                    for (g, w_) in got.3.iter().zip(&want.3) {
                        assert_close_ulps(*g, *w_, 4096, 1e-9,
                                          &format!("{what}/dhyp {tag}"));
                    }
                }
            }
        });
    }

    #[test]
    fn prop_masked_points_have_zero_gradients() {
        Prop::new("psi2_mask_grads").cases(10).run(|rng| {
            let (kern, mu, s, _, z) = setup(rng, 8, 4, 2);
            let mut w = vec![1.0; 8];
            w[3] = 0.0;
            w[6] = 0.0;
            let ct = Mat::from_fn(4, 4, |_, _| rng.normal());
            let (dmu, ds, _, _) = kern.psi2_vjp(&mu, &s, &w, &z, &ct);
            for qq in 0..2 {
                assert_eq!(dmu[(3, qq)], 0.0);
                assert_eq!(dmu[(6, qq)], 0.0);
                assert_eq!(ds[(3, qq)], 0.0);
            }
        });
    }
}
