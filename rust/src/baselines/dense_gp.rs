//! Exact (dense) GP regression — the O(N³) baseline.
//!
//! Marginal likelihood, analytic gradients, and predictions, all through
//! one N×N Cholesky. Used by the `micro` bench to locate the N where the
//! sparse distributed method overtakes the exact one, and by tests as an
//! oracle for the sparse bound (which is tight at Z = X).

use crate::kern::RbfArd;
use crate::linalg::{Chol, Mat};
use crate::math::bound::LOG2PI;
use crate::math::predict::MIN_PREDICTIVE_VARIANCE;
use crate::optim::{Lbfgs, Optimizer};
use anyhow::{Context, Result};

/// A dense GP regressor with RBF-ARD kernel.
pub struct DenseGp {
    /// Fitted (or fixed) kernel.
    pub kern: RbfArd,
    /// Noise precision β.
    pub beta: f64,
    x: Mat,
    /// K + β⁻¹I factor.
    chol: Chol,
    /// (K + β⁻¹I)⁻¹ Y.
    alpha: Mat,
}

impl DenseGp {
    /// Exact log marginal likelihood Σ_d log N(y_d | 0, K + β⁻¹I) and its
    /// gradients w.r.t. [log σ², log ℓ…, log β].
    pub fn lml_and_grads(kern: &RbfArd, log_beta: f64, x: &Mat, y: &Mat)
                         -> Result<(f64, Vec<f64>)> {
        let n = x.rows();
        let d = y.cols() as f64;
        let beta = log_beta.exp();
        let mut c = kern.k(x, x);
        c.add_diag(1.0 / beta + 1e-10);
        let (l, _) = Chol::new_with_jitter(&c, 6).context("K + noise")?;
        let alpha = l.solve(y); // N × D

        let lml = -0.5 * d * (n as f64) * LOG2PI - d * 0.5 * l.logdet()
            - 0.5 * y.dot(&alpha);

        // dL/dC = ½(α αᵀ·scaled − D·C⁻¹) ; trace form per output dim.
        let cinv = l.inverse();
        let mut df_dc = alpha.matmul_t(&alpha); // Σ_d α_d α_dᵀ
        df_dc.axpy(-d, &cinv);
        df_dc.scale_mut(0.5);

        // kernel part via kuu_vjp-style pullback on K(x,x): reuse kuu_vjp
        // minus its jitter convention by calling the plain kernel VJP.
        let (_, mut dhyp) = kern.kuu_vjp(x, &df_dc);
        // kuu_vjp includes d(jitter·σ²)/dlogσ² for its own 1e-8 jitter; the
        // dense model used add_diag (β-only), so subtract that spurious term.
        let spurious: f64 = (0..n).map(|i| df_dc[(i, i)]).sum::<f64>() * 1e-8 * kern.variance;
        dhyp[0] -= spurious;

        // noise: dC/dβ = −β⁻²I ⇒ dL/dlogβ = −β⁻¹ tr(dL/dC).
        let dlog_beta = -df_dc.trace() / beta;

        let mut g = dhyp;
        g.push(dlog_beta);
        Ok((lml, g))
    }

    /// Fit hyperparameters by L-BFGS on the exact marginal likelihood.
    pub fn fit(x: &Mat, y: &Mat, kern0: RbfArd, beta0: f64, max_iters: usize)
               -> Result<DenseGp> {
        let mut x0 = kern0.to_log_hyp();
        x0.push(beta0.ln());
        let opt = Lbfgs { max_iters, ..Default::default() };
        let mut obj = |p: &[f64]| -> (f64, Vec<f64>) {
            let kern = RbfArd::from_log_hyp(&p[..p.len() - 1]);
            match Self::lml_and_grads(&kern, p[p.len() - 1], x, y) {
                Ok((f, g)) => (-f, g.iter().map(|v| -v).collect()),
                Err(_) => (f64::INFINITY, vec![0.0; p.len()]),
            }
        };
        let r = opt.minimize(&mut obj, x0);
        let kern = RbfArd::from_log_hyp(&r.x[..r.x.len() - 1]);
        let beta = r.x[r.x.len() - 1].exp();
        Self::with_params(x.clone(), y, kern, beta)
    }

    /// Build the predictor at fixed hyperparameters.
    pub fn with_params(x: Mat, y: &Mat, kern: RbfArd, beta: f64) -> Result<DenseGp> {
        let mut c = kern.k(&x, &x);
        c.add_diag(1.0 / beta + 1e-10);
        let (chol, _) = Chol::new_with_jitter(&c, 6)?;
        let alpha = chol.solve(y);
        Ok(DenseGp { kern, beta, x, chol, alpha })
    }

    /// Predictive mean and variance (with noise) at test inputs.
    pub fn predict(&self, xstar: &Mat) -> (Mat, Vec<f64>) {
        let ks = self.kern.k(xstar, &self.x); // Nt × N
        let mean = ks.matmul(&self.alpha);
        let v = self.chol.solve_l(&ks.t()); // N × Nt
        let var: Vec<f64> = (0..xstar.rows())
            .map(|i| {
                let col: f64 = (0..self.x.rows()).map(|r| v[(r, i)] * v[(r, i)]).sum();
                (self.kern.kdiag_at(xstar.row(i)) - col + 1.0 / self.beta)
                    .max(MIN_PREDICTIVE_VARIANCE)
            })
            .collect();
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fd::{assert_grad_close, grad_fd};
    use crate::testutil::prop::Rng64;

    #[test]
    fn lml_grads_match_fd() {
        let mut rng = Rng64::new(71);
        let x = Mat::from_fn(12, 2, |_, _| rng.normal());
        let y = Mat::from_fn(12, 2, |_, _| rng.normal());
        let kern = RbfArd::new(1.2, vec![0.8, 1.4]);
        let lb = 0.4;
        let (_, g) = DenseGp::lml_and_grads(&kern, lb, &x, &y).unwrap();
        let mut p0 = kern.to_log_hyp();
        p0.push(lb);
        let f = |p: &[f64]| {
            let k = RbfArd::from_log_hyp(&p[..3]);
            DenseGp::lml_and_grads(&k, p[3], &x, &y).unwrap().0
        };
        assert_grad_close(&g, &grad_fd(f, &p0, 1e-6), 1e-5, 1e-8, "dense lml");
    }

    #[test]
    fn interpolates_smooth_function() {
        let n = 40;
        let x = Mat::from_fn(n, 1, |i, _| i as f64 / (n as f64) * 6.0 - 3.0);
        let y = Mat::from_fn(n, 1, |i, _| (x[(i, 0)]).sin());
        let gp = DenseGp::fit(&x, &y, RbfArd::iso(1.0, 1.0, 1), 100.0, 40).unwrap();
        let probe = Mat::from_vec(3, 1, vec![-1.5, 0.25, 2.0]);
        let (mean, _) = gp.predict(&probe);
        for i in 0..3 {
            assert!((mean[(i, 0)] - probe[(i, 0)].sin()).abs() < 0.05,
                    "{} vs {}", mean[(i, 0)], probe[(i, 0)].sin());
        }
    }

    #[test]
    fn recovers_noise_level() {
        let mut rng = Rng64::new(72);
        let n = 120;
        let x = Mat::from_fn(n, 1, |_, _| rng.uniform_range(-3.0, 3.0));
        let noise_sd = 0.1;
        let y = Mat::from_fn(n, 1, |i, _| (1.5 * x[(i, 0)]).sin() + noise_sd * rng.normal());
        let gp = DenseGp::fit(&x, &y, RbfArd::iso(1.0, 1.0, 1), 10.0, 60).unwrap();
        let learned_sd = (1.0 / gp.beta).sqrt();
        assert!(learned_sd > 0.05 && learned_sd < 0.2, "noise sd {learned_sd}");
    }
}
