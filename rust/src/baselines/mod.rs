//! Baselines the paper's method is measured against.
//!
//! The headline comparison inside the paper is CPU-vs-GPU within the
//! same sparse method (our two backends); the implicit baseline of the
//! whole sparse-GP literature is the dense O(N³) GP, implemented here to
//! regenerate the sparse-vs-dense crossover bench.

pub mod dense_gp;

pub use dense_gp::DenseGp;
