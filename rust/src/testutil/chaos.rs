//! Deterministic fault-injection chaos harness for the cluster
//! protocols.
//!
//! The four [`Scenario`]s reproduce, end to end, one run of each wire
//! protocol the engine speaks: a training cycle (`eval`), a STATS-only
//! round (`stats_pass`), a streamed serving session (`predict_stream`)
//! and a micro-batching front-end session ([`ServingFrontend`]). Each
//! scenario is fully self-seeding — the same inputs are rebuilt from
//! constants on every call — so a run is a pure function of the
//! injected [`FaultPlan`], and `rust/tests/chaos_test.rs` can sweep the
//! fault point across **every message index** of every rank and assert:
//!
//! 1. the run terminates (watchdog — no deadlock),
//! 2. no rank panics (panics are caught and counted),
//! 3. every rank surfaces a sticky error or a clean result,
//! 4. the outcome is bit-identical when replayed from the same plan,
//! 5. a [`FaultKind::Delay`]-only plan is bit-identical to the
//!    fault-free run (reordering inside the transport's FIFO contract
//!    must be invisible).
//!
//! A failing case prints its [`case_id`]; replay it alone with
//! `GPPAR_CHAOS_SEED=<id> cargo test --test chaos_test` (see
//! `docs/TESTING.md`).

use std::time::Duration;

use crate::collectives::{Cluster, Comm, FaultKind, FaultPlan, FaultyTransport,
                         InMemoryTransport, Topology, Transport};
use crate::config::BackendKind;
use crate::coordinator::engine::serve::{worker_serve, DistributedPosterior};
use crate::coordinator::{DistributedEvaluator, EngineConfig, FrontendConfig,
                         OptChoice, Partition, Problem, RustCpuBackend,
                         ServingFrontend};
use crate::data::synthetic::{generate_supervised, SyntheticSpec};
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::predict::PosteriorCore;
use crate::math::stats::sgpr_stats_fwd;
use crate::models::SparseGpRegression;
use crate::optim::Lbfgs;
use crate::testutil::prop::Rng64;

/// Cluster size every scenario runs at. Three ranks is the smallest
/// cluster where the binomial tree differs from a star (the root talks
/// to two children) while keeping the sweep (every rank × every message
/// index × every fault kind) affordable.
pub const CLUSTER: usize = 3;

/// One protocol run to put under fault injection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// One full training cycle: broadcast parameters, forward + VJP
    /// reductions, objective and gradient back on the leader.
    TrainCycle,
    /// One STATS-only round (`stats_pass`): the distributed statistics
    /// rebuild behind posterior refits.
    StatsRound,
    /// One streamed serving session: three ragged batches through
    /// `predict_stream` (batch k+1 issued before batch k's gather).
    ServeStream,
    /// One front-end session: a client thread pushing three requests
    /// through the micro-batcher over a sharded serving session.
    Frontend,
}

impl Scenario {
    /// Every scenario, in sweep order.
    pub const ALL: [Scenario; 4] = [Scenario::TrainCycle, Scenario::StatsRound,
                                    Scenario::ServeStream, Scenario::Frontend];

    /// Stable name used in [`case_id`] strings.
    pub fn name(self) -> &'static str {
        match self {
            Scenario::TrainCycle => "train_cycle",
            Scenario::StatsRound => "stats_round",
            Scenario::ServeStream => "serve_stream",
            Scenario::Frontend => "frontend",
        }
    }

    /// Inverse of [`Scenario::name`].
    pub fn parse(s: &str) -> Option<Scenario> {
        Scenario::ALL.into_iter().find(|sc| sc.name() == s)
    }
}

/// What one rank produced: how many protocol messages it sent (the
/// fault-index space for that rank) and either a result digest or the
/// rendered error it surfaced.
#[derive(Clone, Debug)]
pub struct RankOutcome {
    /// Protocol messages this rank sent (hangup markers excluded);
    /// zero when the rank errored before its counters were reachable.
    pub sent: u64,
    /// Flattened result values on success, the error chain otherwise.
    pub result: Result<Vec<f64>, String>,
}

/// The outcome of one whole scenario run across the cluster.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Per-rank outcomes, indexed by rank.
    pub ranks: Vec<RankOutcome>,
    /// Ranks whose thread panicked (the sweep asserts this stays 0; a
    /// panicked rank's outcome is `Err("PANIC: …")`).
    pub panics: usize,
}

impl RunOutcome {
    /// True when every rank finished without error or panic.
    pub fn all_ok(&self) -> bool {
        self.panics == 0 && self.ranks.iter().all(|r| r.result.is_ok())
    }
}

/// Bitwise outcome equality: per-rank send counts, error strings, and
/// result digests compared via `f64::to_bits` — corrupt floats (NaN)
/// can legitimately flow into digests, and NaN != NaN would make every
/// replay comparison vacuous.
pub fn outcomes_bitwise_equal(a: &RunOutcome, b: &RunOutcome) -> bool {
    a.panics == b.panics
        && a.ranks.len() == b.ranks.len()
        && a.ranks.iter().zip(&b.ranks).all(|(x, y)| {
            x.sent == y.sent
                && match (&x.result, &y.result) {
                    (Ok(u), Ok(v)) => u.len() == v.len()
                        && u.iter().zip(v).all(|(p, q)| p.to_bits() == q.to_bits()),
                    (Err(u), Err(v)) => u == v,
                    _ => false,
                }
        })
}

/// The replayable identity of one sweep case:
/// `scenario:rank:index:kind:seed` (the `GPPAR_CHAOS_SEED` wire format).
pub fn case_id(scenario: Scenario, plan: &FaultPlan) -> String {
    format!("{}:{}:{}:{}:{}", scenario.name(), plan.rank, plan.index,
            plan.kind.name(), plan.seed)
}

/// Inverse of [`case_id`]; `None` on any malformed field.
pub fn parse_case(s: &str) -> Option<(Scenario, FaultPlan)> {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() != 5 {
        return None;
    }
    let scenario = Scenario::parse(parts[0])?;
    let rank = parts[1].parse().ok()?;
    let index = parts[2].parse().ok()?;
    let kind = FaultKind::parse(parts[3])?;
    let seed = parts[4].parse().ok()?;
    Some((scenario, FaultPlan { rank, index, kind, seed }))
}

/// Run one scenario on a fresh [`CLUSTER`]-rank in-memory mesh, with
/// `plan`'s rank (if any) behind a [`FaultyTransport`]. Rank panics are
/// caught by the scoped cluster runner and folded into the outcome.
pub fn run_scenario(scenario: Scenario, plan: Option<FaultPlan>) -> RunOutcome {
    let transports: Vec<Box<dyn Transport>> = InMemoryTransport::mesh(CLUSTER)
        .into_iter()
        .enumerate()
        .map(|(r, t)| match plan {
            Some(p) if p.rank == r => {
                Box::new(FaultyTransport::new(Box::new(t), p)) as Box<dyn Transport>
            }
            _ => Box::new(t) as Box<dyn Transport>,
        })
        .collect();
    let results = Cluster::try_run_on(transports, Topology::Tree,
                                      &|comm| drive(scenario, comm));
    let mut panics = 0;
    let ranks = results
        .into_iter()
        .map(|r| match r {
            Ok(outcome) => outcome,
            Err(payload) => {
                panics += 1;
                let what = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                RankOutcome { sent: 0, result: Err(format!("PANIC: {what}")) }
            }
        })
        .collect();
    RunOutcome { ranks, panics }
}

/// [`run_scenario`] under a deadlock watchdog: the run executes on a
/// detached thread and must report within `timeout`, else this panics
/// with the case `label` (the hung threads are leaked — the test is
/// already failing, and tearing them down cleanly is impossible by
/// construction).
pub fn run_scenario_watchdog(scenario: Scenario, plan: Option<FaultPlan>,
                             timeout: Duration, label: &str) -> RunOutcome {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(run_scenario(scenario, plan));
    });
    match rx.recv_timeout(timeout) {
        Ok(out) => out,
        Err(_) => panic!(
            "chaos case {label}: no result within {timeout:?} — deadlock"),
    }
}

// ---------------------------------------------------------------------
// scenario drivers (all inputs rebuilt from constants: a run is a pure
// function of the fault plan)
// ---------------------------------------------------------------------

fn chaos_problem() -> Problem {
    let spec = SyntheticSpec { n: 18, q: 2, d: 2, ..Default::default() };
    let ds = generate_supervised(&spec, 97);
    let x = ds.x().expect("supervised dataset has X");
    SparseGpRegression::problem(&x, &ds.y(), 4, "test", 97)
}

fn chaos_cfg() -> EngineConfig {
    EngineConfig {
        workers: CLUSTER,
        chunk: 4,
        backend: BackendKind::RustCpu,
        artifacts_dir: "artifacts".into(),
        opt: OptChoice::Lbfgs(Lbfgs::default()),
        pipeline: true,
        verbose: false,
        simd: None,
    }
}

fn chaos_core() -> PosteriorCore {
    let (n, m, q, d) = (24usize, 6usize, 2usize, 3usize);
    let mut rng = Rng64::new(55);
    let x = Mat::from_fn(n, q, |_, _| rng.normal());
    let y = Mat::from_fn(n, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::iso(1.2, 1.1, q);
    let w = vec![1.0; n];
    let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
    PosteriorCore::new(kern, z, 15.0, &st).expect("chaos posterior core")
}

fn drive(scenario: Scenario, comm: Comm) -> RankOutcome {
    match scenario {
        Scenario::TrainCycle => drive_eval(comm, false),
        Scenario::StatsRound => drive_eval(comm, true),
        Scenario::ServeStream => drive_serve(comm),
        Scenario::Frontend => drive_frontend(comm),
    }
}

/// One training cycle (or one STATS round when `stats`): the leader
/// digests the objective+gradient (or the reduced statistics) and
/// always attempts the shutdown broadcast, faulted or not, so workers
/// never deadlock waiting for a command that cannot come.
fn drive_eval(comm: Comm, stats: bool) -> RankOutcome {
    let problem = chaos_problem();
    let cfg = chaos_cfg();
    let part = Partition::new(problem.n(), cfg.chunk, CLUSTER);
    let x0 = problem.initial_params();
    let mut ev = match DistributedEvaluator::new(&problem, &cfg, &part, comm) {
        Ok(ev) => ev,
        Err(e) => return RankOutcome { sent: 0, result: Err(format!("{e:#}")) },
    };
    let result = if ev.rank() == 0 {
        let r = if stats {
            ev.stats_pass(&x0).map(|st| {
                let mut d = vec![st.psi0, st.tryy, st.kl, st.n_eff];
                d.extend_from_slice(st.p.as_slice());
                d.extend_from_slice(st.psi2.as_slice());
                d
            })
        } else {
            ev.eval(&x0).map(|(f, g)| {
                let mut d = vec![f];
                d.extend(g);
                d
            })
        };
        let _ = ev.finish(); // best-effort close even after an error
        r.map_err(|e| format!("{e:#}"))
    } else {
        ev.serve().map(|()| Vec::new()).map_err(|e| format!("{e:#}"))
    };
    RankOutcome { sent: ev.local_messages_sent(), result }
}

/// One streamed serving session: three ragged batches through
/// `predict_stream`, digesting every mean and variance. The leader
/// always attempts `finish`, faulted or not.
fn drive_serve(mut comm: Comm) -> RankOutcome {
    let mut backend = RustCpuBackend;
    if comm.rank() == 0 {
        let mut rng = Rng64::new(777);
        let batches: Vec<Mat> = [7usize, 3, 6]
            .iter()
            .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
            .collect();
        let result = (|| -> Result<Vec<f64>, String> {
            let mut dp = DistributedPosterior::leader(chaos_core(), 2, &mut comm)
                .map_err(|e| format!("{e:#}"))?;
            let stream = dp.predict_stream(&mut comm, &mut backend, &batches);
            let _ = dp.finish(&mut comm); // release workers on every path
            let outs = stream.map_err(|e| format!("{e:#}"))?;
            let mut digest = Vec::new();
            for (mean, var) in &outs {
                digest.extend_from_slice(mean.as_slice());
                digest.extend_from_slice(var);
            }
            Ok(digest)
        })();
        RankOutcome { sent: comm.local_messages_sent(), result }
    } else {
        let result = worker_serve(&mut comm, &mut backend)
            .map(|()| Vec::new())
            .map_err(|e| format!("{e:#}"));
        RankOutcome { sent: comm.local_messages_sent(), result }
    }
}

/// One front-end session: a single client thread pushes three requests
/// through the micro-batcher (sequentially — each blocks on its reply —
/// so batch composition and the message schedule are deterministic). A
/// failed request contributes a `-inf` sentinel to the digest in place
/// of its rows, keeping the digest's shape a pure function of the plan.
fn drive_frontend(mut comm: Comm) -> RankOutcome {
    let mut backend = RustCpuBackend;
    if comm.rank() == 0 {
        let result = (|| -> Result<Vec<f64>, String> {
            let mut dp = DistributedPosterior::leader(chaos_core(), 2, &mut comm)
                .map_err(|e| format!("{e:#}"))?;
            let fe = ServingFrontend::new(
                FrontendConfig {
                    max_batch_rows: 8,
                    max_wait: Duration::from_micros(50),
                    queue_rows: 64,
                    dump_every: None,
                },
                2, 3);
            let h = fe.handle();
            let digest = std::thread::scope(|s| {
                let client = s.spawn(move || {
                    let mut out = Vec::new();
                    let mut rng = Rng64::new(4242);
                    for &rows in &[3usize, 2, 4] {
                        let x = Mat::from_fn(rows, 2, |_, _| rng.normal());
                        match h.predict(x) {
                            Ok((mean, var)) => {
                                out.extend_from_slice(mean.as_slice());
                                out.extend_from_slice(&var);
                            }
                            Err(_) => out.push(f64::NEG_INFINITY),
                        }
                    }
                    h.close();
                    out
                });
                let _report = fe.run(&mut dp, &mut comm, &mut backend);
                client.join()
            });
            let digest = digest.map_err(|_| "frontend client panicked".to_string())?;
            let _ = dp.finish(&mut comm); // release workers on every path
            Ok(digest)
        })();
        RankOutcome { sent: comm.local_messages_sent(), result }
    } else {
        let result = worker_serve(&mut comm, &mut backend)
            .map(|()| Vec::new())
            .map_err(|e| format!("{e:#}"));
        RankOutcome { sent: comm.local_messages_sent(), result }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fault-free run of every scenario is clean, counts messages on
    /// every rank, and replays bit-identically (the baseline the sweep
    /// in `tests/chaos_test.rs` compares against).
    #[test]
    fn fault_free_runs_are_clean_and_deterministic() {
        for scenario in Scenario::ALL {
            let a = run_scenario(scenario, None);
            let b = run_scenario(scenario, None);
            assert!(a.all_ok(), "{}: {:?}", scenario.name(), a);
            assert!(a.ranks.iter().all(|r| r.sent > 0),
                    "{}: every rank participates", scenario.name());
            assert!(outcomes_bitwise_equal(&a, &b),
                    "{}: fault-free replay diverged", scenario.name());
        }
    }

    /// `case_id` round-trips through `parse_case`.
    #[test]
    fn case_id_round_trips() {
        for scenario in Scenario::ALL {
            for kind in FaultKind::ALL {
                let plan = FaultPlan { rank: 2, index: 17, kind, seed: 0xC0FFEE };
                let id = case_id(scenario, &plan);
                let (s2, p2) = parse_case(&id).expect("parse back");
                assert_eq!(s2, scenario);
                assert_eq!(p2.rank, plan.rank);
                assert_eq!(p2.index, plan.index);
                assert_eq!(p2.kind, plan.kind);
                assert_eq!(p2.seed, plan.seed);
            }
        }
    }

    #[test]
    fn parse_case_rejects_malformed() {
        for bad in ["", "train_cycle", "train_cycle:0:0:delay",
                    "nope:0:0:delay:1", "train_cycle:x:0:delay:1",
                    "train_cycle:0:0:meteor:1", "a:b:c:d:e:f"] {
            assert!(parse_case(bad).is_none(), "{bad:?} must not parse");
        }
    }
}
