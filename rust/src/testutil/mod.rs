//! Test-support substrate: a small property-testing framework and
//! finite-difference gradient checking.
//!
//! `proptest` is unavailable in this offline environment (see DESIGN.md),
//! so `prop` provides the subset we need: seeded random case generation
//! with reproducible failure reporting.

pub mod chaos;
pub mod fd;
pub mod prop;
pub mod ulp;
