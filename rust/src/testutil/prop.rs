//! Minimal property-testing framework (offline stand-in for `proptest`).
//!
//! Usage:
//! ```no_run
//! use gpparallel::testutil::prop::Prop;
//! Prop::new("sum_commutes").cases(100).run(|rng| {
//!     let a = rng.normal();
//!     let b = rng.normal();
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! Each case gets a deterministic per-case seed derived from the property
//! name, so failures print a seed that reproduces the exact case via
//! `Prop::replay`.

pub use crate::data::rng::Rng64;

/// A named property with a case budget.
pub struct Prop {
    name: String,
    cases: usize,
    base_seed: u64,
}

impl Prop {
    /// Name the property (the name seeds its deterministic case stream).
    pub fn new(name: &str) -> Self {
        // FNV-1a over the name: stable per-property seed stream.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Prop { name: name.to_string(), cases: 64, base_seed: h }
    }

    /// Override the case budget (default 64).
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run the property across all cases; panics (with the reproducing
    /// seed) on the first failing case.
    pub fn run(&self, mut f: impl FnMut(&mut Rng64)) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng64::new(seed);
                f(&mut rng);
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "property `{}` failed at case {}/{} (replay seed {:#x}): {}",
                    self.name, case, self.cases, seed, msg
                );
            }
        }
    }

    /// Re-run a single failing case by seed (debugging aid).
    pub fn replay(seed: u64, mut f: impl FnMut(&mut Rng64)) {
        let mut rng = Rng64::new(seed);
        f(&mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new("trivial").cases(10).run(|rng| {
            let x = rng.normal();
            assert!(x.is_finite());
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            Prop::new("always_fails").cases(3).run(|_| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        Prop::new("det").cases(5).run(|rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        Prop::new("det").cases(5).run(|rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
