//! Central finite-difference gradient checking — used by every analytic
//! gradient in `kern/` and `math/` (the Rust mirror of the paper's
//! Table 2 derivatives).

/// Central finite difference of a scalar function at `x`, one coordinate
/// at a time.
pub fn grad_fd(mut f: impl FnMut(&[f64]) -> f64, x: &[f64], eps: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let h = eps * (1.0 + x[i].abs());
        xp[i] = x[i] + h;
        let fp = f(&xp);
        xp[i] = x[i] - h;
        let fm = f(&xp);
        xp[i] = x[i];
        g[i] = (fp - fm) / (2.0 * h);
    }
    g
}

/// Assert that an analytic gradient matches finite differences within a
/// mixed relative/absolute tolerance; panics with the worst coordinate.
pub fn assert_grad_close(analytic: &[f64], numeric: &[f64], rtol: f64, atol: f64, what: &str) {
    assert_eq!(analytic.len(), numeric.len(), "{what}: length mismatch");
    let mut worst = (0usize, 0.0f64, 0.0f64, 0.0f64);
    for (i, (&a, &n)) in analytic.iter().zip(numeric).enumerate() {
        let err = (a - n).abs();
        let tol = atol + rtol * n.abs().max(a.abs());
        let ratio = err / tol;
        if ratio > worst.1 {
            worst = (i, ratio, a, n);
        }
    }
    assert!(
        worst.1 <= 1.0,
        "{what}: gradient mismatch at [{}]: analytic={:.10e} numeric={:.10e} (ratio {:.2})",
        worst.0, worst.2, worst.3, worst.1
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fd_of_quadratic() {
        let f = |x: &[f64]| x.iter().map(|v| v * v).sum::<f64>();
        let x = [1.0, -2.0, 0.5];
        let g = grad_fd(f, &x, 1e-6);
        assert_grad_close(&[2.0, -4.0, 1.0], &g, 1e-6, 1e-9, "quadratic");
    }

    #[test]
    #[should_panic(expected = "gradient mismatch")]
    fn detects_wrong_gradient() {
        assert_grad_close(&[1.0], &[2.0], 1e-6, 1e-9, "bad");
    }
}
