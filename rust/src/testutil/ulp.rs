//! Ulp-distance assertions for SIMD-vs-scalar property tests.
//!
//! SIMD tiers reorder reductions and fuse multiply-adds, so rewritten
//! kernels agree with their scalar references only to within a few units
//! in the last place — "tight-ulp", not bitwise. `ulp_distance` counts
//! representable doubles between two values; `assert_close_ulps` adds an
//! absolute-tolerance escape for the two places where ulp counting is the
//! wrong lens: cancellation in mixed-sign sums (tiny absolute error, huge
//! relative error) and the deep tails of `exp` (ditto).

/// Number of representable `f64` values strictly between `a` and `b`
/// (0 when equal, including `+0.0` vs `-0.0`). NaNs and values straddling
/// a sign change map to distances large enough to fail any sane bound.
pub fn ulp_distance(a: f64, b: f64) -> u64 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    ordered(a).abs_diff(ordered(b))
}

/// Monotone map from f64 to i64: preserves ordering, adjacent floats map
/// to adjacent integers, and ±0.0 both map to 0.
fn ordered(x: f64) -> i64 {
    let bits = x.to_bits() as i64;
    if bits < 0 { i64::MIN.wrapping_sub(bits) } else { bits }
}

/// Assert `got` is within `max_ulps` of `want`, or within `abs_tol`
/// absolutely (pass `abs_tol = 0.0` to disable the escape). Panics with
/// both distances on failure.
pub fn assert_close_ulps(got: f64, want: f64, max_ulps: u64, abs_tol: f64, what: &str) {
    let ulps = ulp_distance(got, want);
    if ulps <= max_ulps {
        return;
    }
    let abs = (got - want).abs();
    if abs <= abs_tol {
        return;
    }
    panic!(
        "{what}: got {got:e}, want {want:e} — {ulps} ulps apart (max {max_ulps}), \
         |diff| = {abs:e} (abs_tol {abs_tol:e})"
    );
}

/// [`assert_close_ulps`] over every element of two equal-shape matrices.
pub fn assert_mat_close_ulps(got: &crate::linalg::Mat, want: &crate::linalg::Mat,
                             max_ulps: u64, abs_tol: f64, what: &str) {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()), "{what}: shape");
    for i in 0..got.rows() {
        for j in 0..got.cols() {
            assert_close_ulps(got[(i, j)], want[(i, j)], max_ulps, abs_tol,
                              &format!("{what}[{i},{j}]"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_values_are_zero_ulps() {
        assert_eq!(ulp_distance(1.5, 1.5), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(f64::INFINITY, f64::INFINITY), 0);
    }

    #[test]
    fn adjacent_floats_are_one_ulp() {
        let x = 1.0f64;
        let next = f64::from_bits(x.to_bits() + 1);
        assert_eq!(ulp_distance(x, next), 1);
        let neg = -2.5f64;
        let neg_next = f64::from_bits(neg.to_bits() + 1); // toward -inf
        assert_eq!(ulp_distance(neg, neg_next), 1);
    }

    #[test]
    fn distance_is_symmetric_and_monotone() {
        assert_eq!(ulp_distance(1.0, 2.0), ulp_distance(2.0, 1.0));
        assert!(ulp_distance(1.0, 1.0000000001) < ulp_distance(1.0, 1.1));
    }

    #[test]
    fn sign_crossing_counts_through_zero() {
        // -min_subnormal .. +min_subnormal is 2 ulps (one step to ±0).
        let tiny = f64::from_bits(1);
        assert_eq!(ulp_distance(-tiny, tiny), 2);
        assert!(ulp_distance(-1.0, 1.0) > u64::MAX / 4);
    }

    #[test]
    fn nan_is_maximally_far() {
        assert_eq!(ulp_distance(f64::NAN, 1.0), u64::MAX);
        assert_eq!(ulp_distance(1.0, f64::NAN), u64::MAX);
    }

    #[test]
    fn assert_close_ulps_accepts_within_bounds() {
        let x = 1.0f64;
        let next = f64::from_bits(x.to_bits() + 2);
        assert_close_ulps(x, next, 2, 0.0, "two ulps");
        // Cancellation escape: far in ulps, close absolutely.
        assert_close_ulps(1e-30, -1e-30, 0, 1e-12, "abs escape");
    }

    #[test]
    #[should_panic]
    fn assert_close_ulps_rejects_out_of_bounds() {
        assert_close_ulps(1.0, 1.1, 4, 1e-6, "must fail");
    }
}
