//! Data substrate: deterministic RNG, dataset container, the paper's
//! synthetic GP-sampled dataset, and CSV import/export.

pub mod csv;
pub mod dataset;
pub mod rng;
pub mod synthetic;

pub use dataset::Dataset;
pub use rng::Rng64;
