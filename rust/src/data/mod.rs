//! Data substrate: deterministic RNG, the chunk-store data layer,
//! dataset views over it, the paper's synthetic GP-sampled dataset,
//! and CSV import/export (including the streaming `ingest` path).

pub mod csv;
pub mod dataset;
pub mod rng;
pub mod store;
pub mod synthetic;

pub use dataset::Dataset;
pub use rng::Rng64;
pub use store::{ChunkReader, ChunkScratch, ChunkSource, FileStore, ResidentStore,
                StoreManifest, StoreWriter};
