//! The paper's synthetic benchmark dataset (§4): sample N latent points in
//! 1-D (generally Q-D), map them into D-dimensional observations by
//! *sampling from a GP* with an RBF kernel, and add Gaussian noise.
//!
//! Exact GP sampling needs an N×N Cholesky, which is the very O(N³) cost
//! the paper is escaping — so for large N we sample from the GP using a
//! random-Fourier-feature (RFF) approximation of the RBF kernel, which is
//! exact in distribution as the feature count grows and costs O(N·F).
//! Small-N exactness of the RFF sampler is property-tested against the
//! exact Cholesky sampler's covariance.

use crate::data::dataset::Dataset;
use crate::data::rng::Rng64;
use crate::data::store::{StoreManifest, StoreWriter};
use crate::linalg::{Chol, Mat};
use anyhow::Result;
use std::path::Path;

/// Parameters for the synthetic GP dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Dataset size N.
    pub n: usize,
    /// Latent dimensionality (paper: 1).
    pub q: usize,
    /// Observed dimensionality (paper: 3).
    pub d: usize,
    /// RBF lengthscale of the generating GP.
    pub lengthscale: f64,
    /// RBF signal variance of the generating GP.
    pub variance: f64,
    /// Observation noise variance.
    pub noise: f64,
    /// Number of random Fourier features for the large-N sampler.
    pub rff_features: usize,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            n: 1024,
            q: 1,
            d: 3,
            lengthscale: 1.0,
            variance: 1.0,
            noise: 1e-2,
            rff_features: 512,
        }
    }
}

/// Sample the latent inputs: uniform in [-2, 2]^Q (matches the paper's
/// "randomly sampling 1D datapoints").
pub fn sample_latents(spec: &SyntheticSpec, rng: &mut Rng64) -> Mat {
    Mat::from_fn(spec.n, spec.q, |_, _| rng.uniform_range(-2.0, 2.0))
}

/// Exact GP draw: f ~ N(0, K(X,X)) per output dimension, via Cholesky.
/// O(N³) — only sensible for N ≲ 4k; used as the oracle for the RFF path.
pub fn gp_sample_exact(x: &Mat, spec: &SyntheticSpec, rng: &mut Rng64) -> Mat {
    let n = x.rows();
    let mut k = Mat::from_fn(n, n, |i, j| {
        let mut r2 = 0.0;
        for q in 0..x.cols() {
            let d = x[(i, q)] - x[(j, q)];
            r2 += d * d;
        }
        spec.variance * (-0.5 * r2 / (spec.lengthscale * spec.lengthscale)).exp()
    });
    k.add_diag(1e-8 * spec.variance + 1e-12);
    let (chol, _) = Chol::new_with_jitter(&k, 10).expect("kernel matrix PSD");
    let mut f = Mat::zeros(n, spec.d);
    for d in 0..spec.d {
        let z = Mat::col_vec(&rng.normal_vec(n));
        let fd = chol.l().matmul(&z);
        for i in 0..n {
            f[(i, d)] = fd[(i, 0)];
        }
    }
    f
}

/// Random-Fourier-feature GP draw: f(x) = sqrt(2 σ²/F) Σ_f cos(ω_fᵀx + b_f) γ_f
/// with ω ~ N(0, ℓ⁻² I), b ~ U[0, 2π), γ ~ N(0, 1). Covariance converges to
/// the RBF kernel as F → ∞ (Rahimi & Recht 2007). O(N·F·Q).
pub fn gp_sample_rff(x: &Mat, spec: &SyntheticSpec, rng: &mut Rng64) -> Mat {
    let n = x.rows();
    let q = x.cols();
    let f_count = spec.rff_features;
    let scale = (2.0 * spec.variance / f_count as f64).sqrt();
    let mut out = Mat::zeros(n, spec.d);
    for d in 0..spec.d {
        // Fresh features per output dim -> independent draws.
        let omega: Vec<f64> = (0..f_count * q)
            .map(|_| rng.normal() / spec.lengthscale)
            .collect();
        let bias: Vec<f64> = (0..f_count)
            .map(|_| rng.uniform_range(0.0, 2.0 * std::f64::consts::PI))
            .collect();
        let gamma: Vec<f64> = rng.normal_vec(f_count);
        for i in 0..n {
            let xi = x.row(i);
            let mut acc = 0.0;
            for f in 0..f_count {
                let mut dot = bias[f];
                let w = &omega[f * q..(f + 1) * q];
                for qq in 0..q {
                    dot += w[qq] * xi[qq];
                }
                acc += dot.cos() * gamma[f];
            }
            out[(i, d)] = scale * acc;
        }
    }
    out
}

/// Generate the full synthetic dataset: latents -> GP map -> noise.
/// Uses the exact sampler for N ≤ 2048, RFF above.
pub fn generate(spec: &SyntheticSpec, seed: u64) -> Dataset {
    let mut rng = Rng64::new(seed);
    let x = sample_latents(spec, &mut rng);
    let f = if spec.n <= 2048 {
        gp_sample_exact(&x, spec, &mut rng)
    } else {
        gp_sample_rff(&x, spec, &mut rng)
    };
    let noise_sd = spec.noise.sqrt();
    let y = Mat::from_fn(spec.n, spec.d, |i, j| f[(i, j)] + noise_sd * rng.normal());
    Dataset::unsupervised(y).with_latent_truth(x)
}

/// A supervised variant: observe the inputs too (for SGPR examples and
/// hyperparameter-recovery tests).
pub fn generate_supervised(spec: &SyntheticSpec, seed: u64) -> Dataset {
    let ds = generate(spec, seed);
    let x = ds.latent_truth().expect("synthetic truth").clone();
    Dataset::supervised(x.clone(), ds.y()).with_latent_truth(x)
}

/// Generate a supervised synthetic dataset **straight to an on-disk
/// chunk store** in O(chunk) memory: the RFF features are drawn up
/// front (O(D·F·Q)), then each chunk's latents, GP values and noise
/// are sampled and flushed before the next chunk is touched. This is
/// how the N=10⁶ scaling stores are built — no point along the way
/// holds the dataset resident.
///
/// Deterministic in `seed` via split RNG streams (features / latents /
/// noise); by construction **not** bit-equal to the resident
/// [`generate_supervised`] path, which interleaves its draws globally.
pub fn generate_supervised_to_store(spec: &SyntheticSpec, seed: u64, dir: &Path,
                                    chunk_rows: usize) -> Result<StoreManifest> {
    let mut root = Rng64::new(seed);
    let mut feat_rng = root.split(1);
    let mut lat_rng = root.split(2);
    let mut noise_rng = root.split(3);
    let (q, d, fc) = (spec.q, spec.d, spec.rff_features);

    // per-output-dim RFF features, same law as `gp_sample_rff`
    struct Feats {
        omega: Vec<f64>,
        bias: Vec<f64>,
        gamma: Vec<f64>,
    }
    let feats: Vec<Feats> = (0..d)
        .map(|_| Feats {
            omega: (0..fc * q).map(|_| feat_rng.normal() / spec.lengthscale).collect(),
            bias: (0..fc)
                .map(|_| feat_rng.uniform_range(0.0, 2.0 * std::f64::consts::PI))
                .collect(),
            gamma: feat_rng.normal_vec(fc),
        })
        .collect();
    let scale = (2.0 * spec.variance / fc as f64).sqrt();
    let noise_sd = spec.noise.sqrt();

    let mut w = StoreWriter::create(dir, q, d, chunk_rows)?;
    let mut x = vec![0.0; chunk_rows * q];
    let mut y = vec![0.0; chunk_rows * d];
    for start in (0..spec.n).step_by(chunk_rows) {
        let rows = chunk_rows.min(spec.n - start);
        for v in x[..rows * q].iter_mut() {
            *v = lat_rng.uniform_range(-2.0, 2.0);
        }
        for r in 0..rows {
            let xr = &x[r * q..(r + 1) * q];
            for (j, ft) in feats.iter().enumerate() {
                let mut acc = 0.0;
                for f in 0..fc {
                    let mut dot = ft.bias[f];
                    let wv = &ft.omega[f * q..(f + 1) * q];
                    for qq in 0..q {
                        dot += wv[qq] * xr[qq];
                    }
                    acc += dot.cos() * ft.gamma[f];
                }
                y[r * d + j] = scale * acc + noise_sd * noise_rng.normal();
            }
        }
        w.push_chunk(&x[..rows * q], &y[..rows * d])?;
    }
    w.finish(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mean;

    #[test]
    fn shapes_and_determinism() {
        let spec = SyntheticSpec { n: 64, ..Default::default() };
        let a = generate(&spec, 9);
        let b = generate(&spec, 9);
        assert_eq!(a.n(), 64);
        assert_eq!(a.d(), 3);
        assert_eq!(a.latent_truth().unwrap().cols(), 1);
        assert!(a.y().max_abs_diff(&b.y()) == 0.0, "same seed, same data");
        let c = generate(&spec, 10);
        assert!(a.y().max_abs_diff(&c.y()) > 1e-3, "different seed, different data");
    }

    #[test]
    fn rff_covariance_approximates_rbf() {
        // Empirical covariance of many RFF draws at a pair of points must
        // approach the RBF kernel value.
        let spec = SyntheticSpec {
            n: 2, d: 1, rff_features: 4096, noise: 0.0, ..Default::default()
        };
        let x = Mat::from_vec(2, 1, vec![0.0, 0.7]);
        let mut rng = Rng64::new(11);
        let reps = 3000;
        let (mut c00, mut c01) = (vec![], vec![]);
        for _ in 0..reps {
            let f = gp_sample_rff(&x, &spec, &mut rng);
            c00.push(f[(0, 0)] * f[(0, 0)]);
            c01.push(f[(0, 0)] * f[(1, 0)]);
        }
        let k01 = (-0.5_f64 * 0.49).exp();
        assert!((mean(&c00) - 1.0).abs() < 0.08, "var {}", mean(&c00));
        assert!((mean(&c01) - k01).abs() < 0.08, "cov {} vs {}", mean(&c01), k01);
    }

    #[test]
    fn exact_sampler_has_unit_marginal_variance() {
        let spec = SyntheticSpec { n: 400, d: 1, noise: 0.0, ..Default::default() };
        let mut rng = Rng64::new(13);
        let x = sample_latents(&spec, &mut rng);
        let f = gp_sample_exact(&x, &spec, &mut rng);
        let var = (0..400).map(|i| f[(i, 0)] * f[(i, 0)]).sum::<f64>() / 400.0;
        // Single GP draw: marginal variance is noisy but should be O(1).
        assert!(var > 0.1 && var < 4.0, "var {var}");
    }

    #[test]
    fn supervised_exposes_inputs() {
        let spec = SyntheticSpec { n: 32, ..Default::default() };
        let ds = generate_supervised(&spec, 3);
        assert!(ds.x().is_some());
        assert_eq!(ds.x().unwrap().rows(), 32);
    }

    #[test]
    fn store_generator_is_deterministic_and_chunk_sized() {
        let dir = std::env::temp_dir().join(format!(
            "gpp_synth_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = SyntheticSpec { n: 50, rff_features: 32, ..Default::default() };
        let man = generate_supervised_to_store(&spec, 5, &dir.join("a"), 16).unwrap();
        assert_eq!((man.n, man.q, man.d, man.num_chunks()), (50, 1, 3, 4));
        let _ = generate_supervised_to_store(&spec, 5, &dir.join("b"), 16).unwrap();
        let a = Dataset::open(&dir.join("a")).unwrap();
        let b = Dataset::open(&dir.join("b")).unwrap();
        assert!(a.y().max_abs_diff(&b.y()) == 0.0, "same seed, same store");
        assert!(a.x().unwrap().max_abs_diff(&b.x().unwrap()) == 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
