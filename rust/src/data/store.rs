//! Out-of-core chunked dataset layer: a dataset is a sequence of
//! fixed-size row chunks in one binary file, described by a JSON
//! manifest — not a resident matrix.
//!
//! ## On-disk format (`gpp-chunks-v1`)
//!
//! A store directory holds two files:
//!
//! - `chunks.bin` — an 8-byte magic (`GPCHNK1\0`) followed by the chunk
//!   payloads back to back. Chunk k's payload is `rows_k · q` latent
//!   inputs then `rows_k · d` outputs, row-major f64 little-endian
//!   (`q = 0` for unsupervised data — no x block).
//! - `manifest.json` — the shape (`n`, `d`, `q`, `chunk_rows`), the
//!   column means of Y, a `center` flag, and one record per chunk: row
//!   count, byte offset into `chunks.bin`, an FNV-1a 64 checksum of the
//!   payload bytes (hex string), and per-column summary statistics
//!   (mean/min/max) for the x and y blocks.
//!
//! Every chunk except the last holds exactly `chunk_rows` rows, so
//! chunk ids map to row ranges arithmetically — the same grid
//! [`Partition`](crate::coordinator::Partition) deals to ranks.
//!
//! ## Sources and views
//!
//! Two implementations sit behind the [`ChunkSource`] trait:
//!
//! - [`ResidentStore`] — resident `Mat`s presented through the chunk
//!   interface (the test substrate; bit-identical to the historical
//!   in-memory data model).
//! - [`FileStore`] — sequential whole-payload reads into a reusable
//!   buffer, checksum-verified per chunk; the steady-state read path is
//!   allocation-free (`// lint: no-alloc`).
//!
//! Transforms are **views**, not copies: [`CenteredSource`] subtracts
//! the manifest's `y_mean` per chunk on read, and [`TakeSource`]
//! exposes a row prefix as a chunk-range view (one O(chunk) read to
//! restate the boundary chunk's statistics). View manifests inherit
//! the inner checksums as provenance metadata; bytes are verified by
//! the layer that owns them ([`FileStore`] / [`ResidentStore`]).

use crate::config::Json;
use crate::linalg::Mat;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Manifest `format` field of the current chunk-store layout.
pub const STORE_FORMAT: &str = "gpp-chunks-v1";

/// Magic prefix of `chunks.bin`.
pub const DATA_MAGIC: [u8; 8] = *b"GPCHNK1\0";

/// Default rows per chunk for stores built from resident matrices.
pub const DEFAULT_CHUNK_ROWS: usize = 1024;

const MANIFEST_FILE: &str = "manifest.json";
const DATA_FILE: &str = "chunks.bin";

// ---------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------

/// Per-column summary statistics of one block of one chunk.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColStats {
    /// Column mean over the chunk's rows.
    pub mean: f64,
    /// Column minimum.
    pub min: f64,
    /// Column maximum.
    pub max: f64,
}

/// One chunk's manifest record.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkMeta {
    /// Rows in this chunk (`chunk_rows` for all but the last).
    pub rows: usize,
    /// Byte offset of the payload in the data file.
    pub offset: u64,
    /// FNV-1a 64 checksum of the payload bytes.
    pub checksum: u64,
    /// Per-column stats of the x block (`q` entries).
    pub x_cols: Vec<ColStats>,
    /// Per-column stats of the y block (`d` entries).
    pub y_cols: Vec<ColStats>,
}

/// The JSON manifest describing a chunk store.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreManifest {
    /// Total datapoint count N.
    pub n: usize,
    /// Output dimensionality D.
    pub d: usize,
    /// Latent-input dimensionality Q (0 = unsupervised, no x block).
    pub q: usize,
    /// Rows per full chunk.
    pub chunk_rows: usize,
    /// Apply `y_mean` on read (centering as a manifest-level transform).
    pub center: bool,
    /// Column means of Y over the whole store (the centering
    /// subtractor when `center` is set; informational otherwise).
    pub y_mean: Vec<f64>,
    /// Data file name within the store directory.
    pub data_file: String,
    /// Per-chunk records, in row order.
    pub chunks: Vec<ChunkMeta>,
}

impl StoreManifest {
    /// Chunk count.
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Payload byte length of chunk `k`.
    pub fn payload_len(&self, k: usize) -> usize {
        self.chunks[k].rows * (self.q + self.d) * 8
    }

    /// Global row index where chunk `k` starts.
    pub fn chunk_start(&self, k: usize) -> usize {
        self.chunks[..k].iter().map(|c| c.rows).sum()
    }

    /// Structural validation: shape consistency, exactly-sequential
    /// non-overlapping offsets, full-chunk discipline (every chunk but
    /// the last holds `chunk_rows` rows), and finite summary statistics
    /// with `min <= max`. Checksums are verified at read time, not here.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.d == 0 || self.chunk_rows == 0 {
            bail!("manifest: n, d and chunk_rows must all be positive \
                   (n={}, d={}, chunk_rows={})", self.n, self.d, self.chunk_rows);
        }
        if self.y_mean.len() != self.d {
            bail!("manifest: y_mean has {} entries, expected d={}",
                  self.y_mean.len(), self.d);
        }
        if self.y_mean.iter().any(|v| !v.is_finite()) {
            bail!("manifest: non-finite y_mean");
        }
        if self.chunks.is_empty() {
            bail!("manifest: no chunks");
        }
        let mut total = 0usize;
        let mut expect_offset = DATA_MAGIC.len() as u64;
        for (k, c) in self.chunks.iter().enumerate() {
            if c.rows > self.chunk_rows {
                bail!("chunk {k}: {} rows exceeds chunk_rows={}", c.rows,
                      self.chunk_rows);
            }
            if c.rows < self.chunk_rows && k + 1 != self.chunks.len() {
                bail!("chunk {k}: partial chunk ({} rows) before the last", c.rows);
            }
            if c.offset != expect_offset {
                bail!("chunk {k}: offset {} overlaps or leaves a gap \
                       (expected {expect_offset})", c.offset);
            }
            expect_offset += self.payload_len(k) as u64;
            if c.x_cols.len() != self.q || c.y_cols.len() != self.d {
                bail!("chunk {k}: stats arity mismatch ({} x cols, {} y cols; \
                       expected q={}, d={})",
                      c.x_cols.len(), c.y_cols.len(), self.q, self.d);
            }
            for s in c.x_cols.iter().chain(&c.y_cols) {
                if !(s.mean.is_finite() && s.min.is_finite() && s.max.is_finite()) {
                    bail!("chunk {k}: non-finite summary statistics");
                }
                if s.min > s.max {
                    bail!("chunk {k}: min > max in summary statistics");
                }
            }
            total += c.rows;
        }
        if total != self.n {
            bail!("manifest: chunk rows sum to {total}, expected n={}", self.n);
        }
        Ok(())
    }

    /// Serialise to the manifest JSON document.
    pub fn to_json(&self) -> Json {
        let col = |s: &ColStats| {
            let mut m = BTreeMap::new();
            m.insert("mean".to_string(), Json::Num(s.mean));
            m.insert("min".to_string(), Json::Num(s.min));
            m.insert("max".to_string(), Json::Num(s.max));
            Json::Obj(m)
        };
        let chunks = self.chunks.iter().map(|c| {
            let mut m = BTreeMap::new();
            m.insert("rows".to_string(), Json::Num(c.rows as f64));
            m.insert("offset".to_string(), Json::Num(c.offset as f64));
            // u64 does not survive the f64 number type; hex string instead
            m.insert("checksum".to_string(), Json::Str(format!("{:016x}", c.checksum)));
            m.insert("x_cols".to_string(), Json::Arr(c.x_cols.iter().map(col).collect()));
            m.insert("y_cols".to_string(), Json::Arr(c.y_cols.iter().map(col).collect()));
            Json::Obj(m)
        }).collect();
        let mut m = BTreeMap::new();
        m.insert("format".to_string(), Json::Str(STORE_FORMAT.to_string()));
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("d".to_string(), Json::Num(self.d as f64));
        m.insert("q".to_string(), Json::Num(self.q as f64));
        m.insert("chunk_rows".to_string(), Json::Num(self.chunk_rows as f64));
        m.insert("center".to_string(), Json::Bool(self.center));
        m.insert("y_mean".to_string(),
                 Json::Arr(self.y_mean.iter().map(|&v| Json::Num(v)).collect()));
        m.insert("data_file".to_string(), Json::Str(self.data_file.clone()));
        m.insert("chunks".to_string(), Json::Arr(chunks));
        Json::Obj(m)
    }

    /// Parse and validate a manifest JSON document.
    pub fn from_json(j: &Json) -> Result<StoreManifest> {
        if j.get("format").and_then(Json::as_str) != Some(STORE_FORMAT) {
            bail!("manifest format must be {STORE_FORMAT:?} (got {:?})",
                  j.get("format").and_then(Json::as_str));
        }
        let field = |k: &str| j.get(k).and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing or non-integer {k:?}"));
        let num = |v: &Json, what: &str| v.as_f64()
            .ok_or_else(|| anyhow!("manifest: non-numeric {what}"));
        let col = |v: &Json, what: &str| -> Result<ColStats> {
            Ok(ColStats {
                mean: num(v.get("mean").unwrap_or(&Json::Null), what)?,
                min: num(v.get("min").unwrap_or(&Json::Null), what)?,
                max: num(v.get("max").unwrap_or(&Json::Null), what)?,
            })
        };
        let mut chunks = Vec::new();
        for (k, c) in j.get("chunks").and_then(Json::as_arr).unwrap_or(&[]).iter()
                       .enumerate() {
            let rows = c.get("rows").and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("chunk {k}: missing rows"))?;
            let offset = c.get("offset").and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("chunk {k}: missing offset"))? as u64;
            let sum = c.get("checksum").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("chunk {k}: missing checksum"))?;
            let checksum = u64::from_str_radix(sum, 16)
                .with_context(|| format!("chunk {k}: malformed checksum {sum:?}"))?;
            let stats = |key: &str| -> Result<Vec<ColStats>> {
                c.get(key).and_then(Json::as_arr).unwrap_or(&[]).iter()
                    .map(|v| col(v, key)).collect()
            };
            chunks.push(ChunkMeta {
                rows, offset, checksum,
                x_cols: stats("x_cols")?,
                y_cols: stats("y_cols")?,
            });
        }
        let y_mean = j.get("y_mean").and_then(Json::as_arr).unwrap_or(&[]).iter()
            .map(|v| num(v, "y_mean"))
            .collect::<Result<Vec<f64>>>()?;
        let man = StoreManifest {
            n: field("n")?,
            d: field("d")?,
            q: field("q")?,
            chunk_rows: field("chunk_rows")?,
            center: j.get("center") == Some(&Json::Bool(true)),
            y_mean,
            data_file: j.get("data_file").and_then(Json::as_str)
                .unwrap_or(DATA_FILE).to_string(),
            chunks,
        };
        man.validate()?;
        Ok(man)
    }
}

// ---------------------------------------------------------------------
// the source/reader traits
// ---------------------------------------------------------------------

/// A chunked dataset: a manifest plus the ability to open readers.
/// Implementations are shared across ranks behind an `Arc`, so the
/// trait is `Send + Sync`; per-rank mutable read state lives in the
/// [`ChunkReader`] each rank opens for itself.
pub trait ChunkSource: Send + Sync {
    /// The store's manifest.
    fn manifest(&self) -> &StoreManifest;

    /// Open an independent reader (own file handle / scratch buffer).
    fn open_reader(&self) -> Result<Box<dyn ChunkReader>>;
}

/// A stateful reader over one [`ChunkSource`]. `read_chunk` fills the
/// caller's buffers with chunk `k`'s decoded (and, if the manifest says
/// `center`, centered) payload: the first `rows·q` elements of `x_out`
/// and the first `rows·d` elements of `y_out`, row-major.
pub trait ChunkReader: Send {
    /// Read chunk `k`. `x_out` / `y_out` must hold at least `rows·q` /
    /// `rows·d` elements; anything past that prefix is left untouched.
    fn read_chunk(&mut self, k: usize, x_out: &mut [f64], y_out: &mut [f64])
                  -> Result<()>;
}

fn check_out_lens(man: &StoreManifest, k: usize, x_len: usize, y_len: usize)
                  -> Result<usize> {
    let meta = man.chunks.get(k)
        .ok_or_else(|| anyhow!("chunk {k} out of range ({} chunks)",
                               man.chunks.len()))?;
    if x_len < meta.rows * man.q || y_len < meta.rows * man.d {
        bail!("chunk {k}: output buffers too small ({x_len}/{y_len} for \
               {} rows x q={} d={})", meta.rows, man.q, man.d);
    }
    Ok(meta.rows)
}

// ---------------------------------------------------------------------
// checksum + payload codec
// ---------------------------------------------------------------------

/// FNV-1a 64 over a byte slice (the per-chunk payload checksum).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn read_f64_le(b: &[u8]) -> f64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(b);
    f64::from_le_bytes(a)
}

fn encode_payload(enc: &mut Vec<u8>, x: &[f64], y: &[f64]) {
    enc.clear();
    enc.reserve(8 * (x.len() + y.len()));
    for v in x.iter().chain(y) {
        enc.extend_from_slice(&v.to_le_bytes());
    }
}

fn col_stats(data: &[f64], rows: usize, cols: usize) -> Vec<ColStats> {
    let mut out = vec![ColStats { mean: 0.0, min: f64::INFINITY,
                                  max: f64::NEG_INFINITY }; cols];
    for r in 0..rows {
        for (j, s) in out.iter_mut().enumerate() {
            let v = data[r * cols + j];
            s.mean += v;
            s.min = s.min.min(v);
            s.max = s.max.max(v);
        }
    }
    for s in &mut out {
        s.mean /= rows as f64;
    }
    out
}

// ---------------------------------------------------------------------
// manifest builder (shared by StoreWriter and ResidentStore)
// ---------------------------------------------------------------------

struct ManifestBuilder {
    q: usize,
    d: usize,
    chunk_rows: usize,
    n: usize,
    offset: u64,
    chunks: Vec<ChunkMeta>,
    /// Per-column running sums of Y, accumulated in row order across
    /// chunks — bit-identical to the resident column-mean loop (each
    /// accumulator sees the same operands in the same order).
    y_sum: Vec<f64>,
}

impl ManifestBuilder {
    fn new(q: usize, d: usize, chunk_rows: usize) -> ManifestBuilder {
        ManifestBuilder {
            q, d, chunk_rows,
            n: 0,
            offset: DATA_MAGIC.len() as u64,
            chunks: Vec::new(),
            y_sum: vec![0.0; d],
        }
    }

    fn add_chunk(&mut self, x: &[f64], y: &[f64], payload: &[u8]) -> Result<()> {
        let rows = y.len() / self.d;
        if rows == 0 || rows > self.chunk_rows {
            bail!("chunk of {rows} rows (need 1..={})", self.chunk_rows);
        }
        if y.len() != rows * self.d || x.len() != rows * self.q {
            bail!("chunk payload shape mismatch");
        }
        if let Some(last) = self.chunks.last() {
            if last.rows != self.chunk_rows {
                bail!("only the last chunk may be partial");
            }
        }
        for r in 0..rows {
            for (j, s) in self.y_sum.iter_mut().enumerate() {
                *s += y[r * self.d + j];
            }
        }
        self.chunks.push(ChunkMeta {
            rows,
            offset: self.offset,
            checksum: fnv1a(payload),
            x_cols: col_stats(x, rows, self.q),
            y_cols: col_stats(y, rows, self.d),
        });
        self.offset += payload.len() as u64;
        self.n += rows;
        Ok(())
    }

    fn finish(self, center: bool) -> Result<StoreManifest> {
        if self.n == 0 {
            bail!("empty store: push at least one chunk");
        }
        let n = self.n as f64;
        let man = StoreManifest {
            n: self.n,
            d: self.d,
            q: self.q,
            chunk_rows: self.chunk_rows,
            center,
            y_mean: self.y_sum.iter().map(|s| s / n).collect(),
            data_file: DATA_FILE.to_string(),
            chunks: self.chunks,
        };
        man.validate()?;
        Ok(man)
    }
}

// ---------------------------------------------------------------------
// StoreWriter: build a store on disk chunk by chunk
// ---------------------------------------------------------------------

/// Incremental writer of an on-disk chunk store: push chunks in row
/// order (O(chunk) memory), then `finish` writes the manifest. Rejects
/// non-finite values — a store is validated data by construction.
pub struct StoreWriter {
    dir: PathBuf,
    file: BufWriter<File>,
    builder: ManifestBuilder,
    enc: Vec<u8>,
}

impl StoreWriter {
    /// Create `<dir>/chunks.bin` (and the directory) and write the magic.
    pub fn create(dir: &Path, q: usize, d: usize, chunk_rows: usize)
                  -> Result<StoreWriter> {
        if d == 0 || chunk_rows == 0 {
            bail!("store needs d >= 1 and chunk_rows >= 1");
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create store dir {}", dir.display()))?;
        let path = dir.join(DATA_FILE);
        let mut file = BufWriter::new(File::create(&path)
            .with_context(|| format!("create {}", path.display()))?);
        file.write_all(&DATA_MAGIC)?;
        Ok(StoreWriter {
            dir: dir.to_path_buf(),
            file,
            builder: ManifestBuilder::new(q, d, chunk_rows),
            enc: Vec::new(),
        })
    }

    /// Append one chunk (`rows` inferred from `y.len() / d`; all chunks
    /// but the final one must hold exactly `chunk_rows` rows).
    pub fn push_chunk(&mut self, x: &[f64], y: &[f64]) -> Result<()> {
        if x.iter().chain(y).any(|v| !v.is_finite()) {
            bail!("non-finite value in chunk {} — refusing to write",
                  self.builder.chunks.len());
        }
        encode_payload(&mut self.enc, x, y);
        self.builder.add_chunk(x, y, &self.enc)?;
        self.file.write_all(&self.enc)?;
        Ok(())
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> usize {
        self.builder.n
    }

    /// Flush the data file and write `manifest.json`. With `center`
    /// set, readers will subtract the manifest's `y_mean` per chunk —
    /// centering as metadata, no second pass over the data.
    pub fn finish(mut self, center: bool) -> Result<StoreManifest> {
        self.file.flush()?;
        let man = self.builder.finish(center)?;
        let path = self.dir.join(MANIFEST_FILE);
        std::fs::write(&path, man.to_json().to_string_pretty())
            .with_context(|| format!("write {}", path.display()))?;
        Ok(man)
    }
}

// ---------------------------------------------------------------------
// FileStore
// ---------------------------------------------------------------------

/// An on-disk chunk store opened for reading. Opening validates the
/// manifest structurally and checks the data file's magic and exact
/// size; per-chunk checksums are verified as chunks are read.
pub struct FileStore {
    manifest: Arc<StoreManifest>,
    data_path: PathBuf,
}

impl FileStore {
    /// Open `<dir>/manifest.json` + data file, rejecting malformed or
    /// inconsistent stores.
    pub fn open(dir: &Path) -> Result<FileStore> {
        let mpath = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {}", mpath.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parse {}", mpath.display()))?;
        let manifest = StoreManifest::from_json(&j)
            .with_context(|| format!("validate {}", mpath.display()))?;
        let data_path = dir.join(&manifest.data_file);
        let mut f = File::open(&data_path)
            .with_context(|| format!("open {}", data_path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).context("read data-file magic")?;
        if magic != DATA_MAGIC {
            bail!("{}: bad magic (not a {STORE_FORMAT} data file)",
                  data_path.display());
        }
        let want = DATA_MAGIC.len() as u64
            + (0..manifest.num_chunks()).map(|k| manifest.payload_len(k) as u64)
                                        .sum::<u64>();
        let got = f.metadata()?.len();
        if got != want {
            bail!("{}: {got} bytes on disk, manifest describes {want}",
                  data_path.display());
        }
        Ok(FileStore { manifest: Arc::new(manifest), data_path })
    }
}

impl ChunkSource for FileStore {
    fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    fn open_reader(&self) -> Result<Box<dyn ChunkReader>> {
        let file = File::open(&self.data_path)
            .with_context(|| format!("open {}", self.data_path.display()))?;
        let cap = self.manifest.chunk_rows * (self.manifest.q + self.manifest.d) * 8;
        Ok(Box::new(FileStoreReader {
            manifest: Arc::clone(&self.manifest),
            file,
            pos: 0,
            buf: Vec::with_capacity(cap),
        }))
    }
}

/// Reader over a [`FileStore`]: one file handle plus one reusable byte
/// buffer sized for a full chunk — sequential reads never reallocate.
struct FileStoreReader {
    manifest: Arc<StoreManifest>,
    file: File,
    /// Current file position (skip the seek when reads are sequential).
    pos: u64,
    buf: Vec<u8>,
}

impl ChunkReader for FileStoreReader {
    // The steady-state read path: the byte buffer is preallocated at
    // open for a full chunk, so `resize` never reallocates here.
    // lint: no-alloc
    fn read_chunk(&mut self, k: usize, x_out: &mut [f64], y_out: &mut [f64])
                  -> Result<()> {
        let man = &self.manifest;
        let rows = check_out_lens(man, k, x_out.len(), y_out.len())?;
        let meta = &man.chunks[k];
        let want = man.payload_len(k);
        if self.pos != meta.offset {
            self.file.seek(SeekFrom::Start(meta.offset))?;
        }
        self.buf.resize(want, 0);
        self.file.read_exact(&mut self.buf)
            .with_context(|| format!("read chunk {k} payload"))?;
        self.pos = meta.offset + want as u64;
        let sum = fnv1a(&self.buf);
        if sum != meta.checksum {
            bail!("chunk {k}: checksum mismatch (stored {:016x}, read {sum:016x})",
                  meta.checksum);
        }
        let (xb, yb) = self.buf.split_at(rows * man.q * 8);
        for (dst, src) in x_out[..rows * man.q].iter_mut().zip(xb.chunks_exact(8)) {
            *dst = read_f64_le(src);
        }
        for (dst, src) in y_out[..rows * man.d].iter_mut().zip(yb.chunks_exact(8)) {
            *dst = read_f64_le(src);
        }
        if man.center {
            for r in 0..rows {
                for (j, m) in man.y_mean.iter().enumerate() {
                    y_out[r * man.d + j] -= m;
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// ResidentStore
// ---------------------------------------------------------------------

/// Resident matrices presented through the chunk interface — the test
/// substrate, bit-identical to the historical in-memory data model
/// (reads are row-range copies out of the backing `Mat`s).
pub struct ResidentStore {
    manifest: Arc<StoreManifest>,
    x: Arc<Mat>,
    y: Arc<Mat>,
}

impl ResidentStore {
    /// Wrap resident matrices (x may be `None` for unsupervised data)
    /// on the `chunk_rows` grid, computing the manifest (stats,
    /// checksums, y means) in one pass.
    pub fn from_mats(x: Option<Mat>, y: Mat, chunk_rows: usize)
                     -> Result<ResidentStore> {
        let (n, d) = (y.rows(), y.cols());
        let q = x.as_ref().map(|m| m.cols()).unwrap_or(0);
        if let Some(xm) = &x {
            if xm.rows() != n {
                bail!("X has {} rows, Y has {n}", xm.rows());
            }
        }
        if n == 0 {
            bail!("empty dataset");
        }
        let mut b = ManifestBuilder::new(q, d, chunk_rows);
        let mut enc = Vec::new();
        let empty = Mat::zeros(0, 0);
        let xm = x.as_ref().unwrap_or(&empty);
        for start in (0..n).step_by(chunk_rows) {
            let rows = chunk_rows.min(n - start);
            let xs = &xm.as_slice()[start * q..(start + rows) * q];
            let ys = &y.as_slice()[start * d..(start + rows) * d];
            encode_payload(&mut enc, xs, ys);
            b.add_chunk(xs, ys, &enc)?;
        }
        Ok(ResidentStore {
            manifest: Arc::new(b.finish(false)?),
            x: Arc::new(x.unwrap_or(empty)),
            y: Arc::new(y),
        })
    }
}

impl ChunkSource for ResidentStore {
    fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    fn open_reader(&self) -> Result<Box<dyn ChunkReader>> {
        Ok(Box::new(ResidentReader {
            manifest: Arc::clone(&self.manifest),
            x: Arc::clone(&self.x),
            y: Arc::clone(&self.y),
        }))
    }
}

struct ResidentReader {
    manifest: Arc<StoreManifest>,
    x: Arc<Mat>,
    y: Arc<Mat>,
}

impl ChunkReader for ResidentReader {
    // lint: no-alloc
    fn read_chunk(&mut self, k: usize, x_out: &mut [f64], y_out: &mut [f64])
                  -> Result<()> {
        let man = &self.manifest;
        let rows = check_out_lens(man, k, x_out.len(), y_out.len())?;
        // every chunk but the last is full, so the grid is arithmetic
        let start = k * man.chunk_rows;
        if man.q > 0 {
            x_out[..rows * man.q].copy_from_slice(
                &self.x.as_slice()[start * man.q..(start + rows) * man.q]);
        }
        y_out[..rows * man.d].copy_from_slice(
            &self.y.as_slice()[start * man.d..(start + rows) * man.d]);
        if man.center {
            for r in 0..rows {
                for (j, m) in man.y_mean.iter().enumerate() {
                    y_out[r * man.d + j] -= m;
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// view sources: centering and row-prefix takes without copies
// ---------------------------------------------------------------------

/// A centered view over another source: the manifest records the inner
/// data's column means and sets `center`; readers subtract them per
/// chunk on read. O(1) memory — centering is metadata, not a copy.
pub struct CenteredSource {
    inner: Arc<dyn ChunkSource>,
    manifest: Arc<StoreManifest>,
}

impl CenteredSource {
    /// Wrap `inner`, computing its column means with one streaming pass
    /// (row-order accumulation — bit-identical to the resident loop).
    /// Returns the view and the means it will subtract.
    pub fn new(inner: Arc<dyn ChunkSource>) -> Result<(CenteredSource, Vec<f64>)> {
        let mean = stream_y_mean(inner.as_ref())?;
        let mut man = inner.manifest().clone();
        for c in &mut man.chunks {
            for (j, s) in c.y_cols.iter_mut().enumerate() {
                s.mean -= mean[j];
                s.min -= mean[j];
                s.max -= mean[j];
            }
        }
        man.center = true;
        man.y_mean = mean.clone();
        Ok((CenteredSource { inner, manifest: Arc::new(man) }, mean))
    }
}

impl ChunkSource for CenteredSource {
    fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    fn open_reader(&self) -> Result<Box<dyn ChunkReader>> {
        Ok(Box::new(CenteredReader {
            inner: self.inner.open_reader()?,
            manifest: Arc::clone(&self.manifest),
        }))
    }
}

struct CenteredReader {
    inner: Box<dyn ChunkReader>,
    manifest: Arc<StoreManifest>,
}

impl ChunkReader for CenteredReader {
    // lint: no-alloc
    fn read_chunk(&mut self, k: usize, x_out: &mut [f64], y_out: &mut [f64])
                  -> Result<()> {
        self.inner.read_chunk(k, x_out, y_out)?;
        let man = &self.manifest;
        let rows = man.chunks[k].rows;
        for r in 0..rows {
            for (j, m) in man.y_mean.iter().enumerate() {
                y_out[r * man.d + j] -= m;
            }
        }
        Ok(())
    }
}

/// A row-prefix view over another source (the paper's 1k..64k size
/// sweeps out of one master dataset): whole chunks pass through, the
/// boundary chunk is exposed truncated. Construction does one O(chunk)
/// read to restate the boundary chunk's statistics and checksum in
/// terms of the logical (truncated) payload.
pub struct TakeSource {
    inner: Arc<dyn ChunkSource>,
    manifest: Arc<StoreManifest>,
    /// Rows the boundary chunk holds in the *inner* store.
    boundary_full_rows: usize,
}

impl TakeSource {
    /// View of the first `k` rows (`1 <= k <= n`).
    pub fn new(inner: Arc<dyn ChunkSource>, k: usize) -> Result<TakeSource> {
        let im = inner.manifest();
        if k == 0 || k > im.n {
            bail!("take({k}) out of range for n={}", im.n);
        }
        let mut man = im.clone();
        man.n = k;
        man.chunks.clear();
        let mut start = 0usize;
        let mut boundary_full_rows = 0;
        for c in &im.chunks {
            if start >= k {
                break;
            }
            let mut meta = c.clone();
            if start + c.rows > k {
                meta.rows = k - start;
                boundary_full_rows = c.rows;
            }
            start += c.rows;
            man.chunks.push(meta);
        }
        if boundary_full_rows > 0 {
            // restate the boundary chunk's stats/checksum for the
            // truncated logical payload (one O(chunk) read)
            let b = man.chunks.len() - 1;
            let rows = man.chunks[b].rows;
            let mut x = vec![0.0; boundary_full_rows * im.q];
            let mut y = vec![0.0; boundary_full_rows * im.d];
            inner.open_reader()?.read_chunk(b, &mut x, &mut y)?;
            x.truncate(rows * im.q);
            y.truncate(rows * im.d);
            let mut enc = Vec::new();
            encode_payload(&mut enc, &x, &y);
            let meta = &mut man.chunks[b];
            meta.checksum = fnv1a(&enc);
            meta.x_cols = col_stats(&x, rows, im.q);
            meta.y_cols = col_stats(&y, rows, im.d);
        }
        Ok(TakeSource { inner, manifest: Arc::new(man), boundary_full_rows })
    }
}

impl ChunkSource for TakeSource {
    fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    fn open_reader(&self) -> Result<Box<dyn ChunkReader>> {
        let man = &self.manifest;
        let (xcap, ycap) = if self.boundary_full_rows > 0 {
            (self.boundary_full_rows * man.q, self.boundary_full_rows * man.d)
        } else {
            (0, 0)
        };
        Ok(Box::new(TakeReader {
            inner: self.inner.open_reader()?,
            manifest: Arc::clone(man),
            xbuf: vec![0.0; xcap],
            ybuf: vec![0.0; ycap],
        }))
    }
}

struct TakeReader {
    inner: Box<dyn ChunkReader>,
    manifest: Arc<StoreManifest>,
    /// Full-size staging for the truncated boundary chunk (preallocated
    /// at open; empty when the take lands on a chunk boundary).
    xbuf: Vec<f64>,
    ybuf: Vec<f64>,
}

impl ChunkReader for TakeReader {
    // lint: no-alloc
    fn read_chunk(&mut self, k: usize, x_out: &mut [f64], y_out: &mut [f64])
                  -> Result<()> {
        let man = &self.manifest;
        let rows = check_out_lens(man, k, x_out.len(), y_out.len())?;
        // `ybuf` is non-empty exactly when the view truncates its last
        // chunk (d >= 1 always; q may be 0, so xbuf is no sentinel)
        if k + 1 == man.chunks.len() && !self.ybuf.is_empty() {
            // boundary chunk: stage the inner (longer) payload, expose
            // the prefix
            self.inner.read_chunk(k, &mut self.xbuf, &mut self.ybuf)?;
            x_out[..rows * man.q].copy_from_slice(&self.xbuf[..rows * man.q]);
            y_out[..rows * man.d].copy_from_slice(&self.ybuf[..rows * man.d]);
        } else {
            self.inner.read_chunk(k, x_out, y_out)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// ChunkScratch + streaming helpers
// ---------------------------------------------------------------------

/// One decoded chunk in a [`ChunkScratch`] slot.
pub struct ChunkBuf {
    /// Manifest chunk id.
    pub chunk: usize,
    /// Global row index of the first row.
    pub start: usize,
    /// Rows held.
    pub rows: usize,
    /// Decoded x block (`rows · q`).
    pub x: Vec<f64>,
    /// Decoded y block (`rows · d`).
    pub y: Vec<f64>,
}

/// A reusable double-buffered decode scratch: chunk `k` lands in slot
/// `k % 2`, so a consumer can hold a window of two chunks live while
/// streaming a store in O(chunk) memory. Buffers are preallocated for
/// a full chunk at construction; `fill` never allocates.
pub struct ChunkScratch {
    slots: [ChunkBuf; 2],
}

impl ChunkScratch {
    /// Scratch sized for `man`'s chunk grid.
    pub fn new(man: &StoreManifest) -> ChunkScratch {
        let buf = || ChunkBuf {
            chunk: usize::MAX,
            start: 0,
            rows: 0,
            x: Vec::with_capacity(man.chunk_rows * man.q),
            y: Vec::with_capacity(man.chunk_rows * man.d),
        };
        ChunkScratch { slots: [buf(), buf()] }
    }

    /// Read chunk `k` into slot `k % 2` and return it.
    // lint: no-alloc
    pub fn fill(&mut self, reader: &mut dyn ChunkReader,
                man: &StoreManifest, k: usize) -> Result<&ChunkBuf> {
        let rows = man.chunks.get(k)
            .ok_or_else(|| anyhow!("chunk {k} out of range"))?.rows;
        let slot = &mut self.slots[k % 2];
        slot.x.resize(rows * man.q, 0.0);
        slot.y.resize(rows * man.d, 0.0);
        reader.read_chunk(k, &mut slot.x, &mut slot.y)?;
        slot.chunk = k;
        slot.start = k * man.chunk_rows;
        slot.rows = rows;
        Ok(&self.slots[k % 2])
    }

    /// Both slots (slot 0, slot 1) — for consumers holding a two-chunk
    /// window live at once.
    pub fn slots(&self) -> (&ChunkBuf, &ChunkBuf) {
        (&self.slots[0], &self.slots[1])
    }
}

/// Column means of Y computed with one streaming pass in row order —
/// bit-identical to the historical resident loop (each per-column
/// accumulator sees the same operands in the same order).
pub fn stream_y_mean(src: &dyn ChunkSource) -> Result<Vec<f64>> {
    let man = src.manifest();
    let mut reader = src.open_reader()?;
    let mut scratch = ChunkScratch::new(man);
    let mut sum = vec![0.0; man.d];
    for k in 0..man.num_chunks() {
        let buf = scratch.fill(reader.as_mut(), man, k)?;
        for r in 0..buf.rows {
            for (j, s) in sum.iter_mut().enumerate() {
                *s += buf.y[r * man.d + j];
            }
        }
    }
    for s in &mut sum {
        *s /= man.n as f64;
    }
    Ok(sum)
}

/// Materialize a source into resident matrices (`x` is `None` for
/// unsupervised stores) — the compatibility bridge for consumers that
/// still want the whole dataset in RAM.
pub fn materialize(src: &dyn ChunkSource) -> Result<(Option<Mat>, Mat)> {
    let man = src.manifest();
    let mut reader = src.open_reader()?;
    let mut x = Mat::zeros(man.n, man.q);
    let mut y = Mat::zeros(man.n, man.d);
    for k in 0..man.num_chunks() {
        let rows = man.chunks[k].rows;
        let start = k * man.chunk_rows;
        let xs = &mut x.as_mut_slice()[start * man.q..(start + rows) * man.q];
        let ys = &mut y.as_mut_slice()[start * man.d..(start + rows) * man.d];
        reader.read_chunk(k, xs, ys)?;
    }
    Ok((if man.q > 0 { Some(x) } else { None }, y))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("gpp_store_unit_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn demo_mats(n: usize, q: usize, d: usize) -> (Mat, Mat) {
        (Mat::from_fn(n, q, |i, j| (i * q + j) as f64 * 0.25 - 3.0),
         Mat::from_fn(n, d, |i, j| ((i * d + j) as f64).sin()))
    }

    #[test]
    fn fnv1a_is_stable() {
        // pinned so manifests stay comparable across builds
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn resident_roundtrip_is_bit_identical() {
        let (x, y) = demo_mats(37, 2, 3);
        let store = ResidentStore::from_mats(Some(x.clone()), y.clone(), 16).unwrap();
        assert_eq!(store.manifest().num_chunks(), 3);
        let (rx, ry) = materialize(&store).unwrap();
        assert!(rx.unwrap().max_abs_diff(&x) == 0.0);
        assert!(ry.max_abs_diff(&y) == 0.0);
    }

    #[test]
    fn file_roundtrip_is_bit_identical() {
        let dir = tmp_dir("roundtrip");
        let (x, y) = demo_mats(37, 2, 3);
        let mut w = StoreWriter::create(&dir, 2, 3, 16).unwrap();
        for start in (0..37).step_by(16) {
            let rows = 16.min(37 - start);
            w.push_chunk(&x.as_slice()[start * 2..(start + rows) * 2],
                         &y.as_slice()[start * 3..(start + rows) * 3]).unwrap();
        }
        let man = w.finish(false).unwrap();
        let fs = FileStore::open(&dir).unwrap();
        assert_eq!(fs.manifest(), &man);
        let (rx, ry) = materialize(&fs).unwrap();
        assert!(rx.unwrap().max_abs_diff(&x) == 0.0);
        assert!(ry.max_abs_diff(&y) == 0.0);
        // manifest agrees bit-for-bit with the resident substrate
        let rs = ResidentStore::from_mats(Some(x), y, 16).unwrap();
        assert_eq!(rs.manifest(), &man);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn centered_view_matches_resident_centering() {
        let (_, y) = demo_mats(29, 0, 4);
        let src: Arc<dyn ChunkSource> =
            Arc::new(ResidentStore::from_mats(None, y.clone(), 8).unwrap());
        let (cs, mean) = CenteredSource::new(Arc::clone(&src)).unwrap();
        // resident reference: subtract column means computed row-order
        let mut want = y.clone();
        for i in 0..want.rows() {
            for j in 0..want.cols() {
                want[(i, j)] -= mean[j];
            }
        }
        let (_, got) = materialize(&cs).unwrap();
        assert!(got.max_abs_diff(&want) == 0.0);
    }

    #[test]
    fn take_view_is_a_row_prefix() {
        let (x, y) = demo_mats(37, 2, 3);
        let src: Arc<dyn ChunkSource> =
            Arc::new(ResidentStore::from_mats(Some(x.clone()), y.clone(), 16).unwrap());
        for k in [1, 15, 16, 17, 36, 37] {
            let t = TakeSource::new(Arc::clone(&src), k).unwrap();
            t.manifest().validate().unwrap();
            assert_eq!(t.manifest().n, k);
            let (tx, ty) = materialize(&t).unwrap();
            assert_eq!(ty.rows(), k);
            assert!(tx.unwrap().as_slice() == &x.as_slice()[..k * 2]);
            assert!(ty.as_slice() == &y.as_slice()[..k * 3]);
        }
        assert!(TakeSource::new(Arc::clone(&src), 0).is_err());
        assert!(TakeSource::new(src, 38).is_err());
    }

    #[test]
    fn manifest_json_roundtrips() {
        let (x, y) = demo_mats(37, 2, 3);
        let man = ResidentStore::from_mats(Some(x), y, 16).unwrap()
            .manifest().clone();
        let j = man.to_json().to_string_pretty();
        let back = StoreManifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(man, back);
    }

    #[test]
    fn writer_rejects_bad_chunks() {
        let dir = tmp_dir("badpush");
        let mut w = StoreWriter::create(&dir, 1, 2, 4).unwrap();
        // non-finite data
        assert!(w.push_chunk(&[0.0], &[1.0, f64::NAN]).is_err());
        // shape mismatch
        assert!(w.push_chunk(&[0.0, 1.0], &[1.0, 2.0]).is_err());
        // partial chunk, then another push
        w.push_chunk(&[0.0, 1.0], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!(w.push_chunk(&[0.0], &[1.0, 2.0]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
