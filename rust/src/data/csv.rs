//! Tiny CSV reader/writer for matrices (dataset import/export and the
//! bench harness's result files). No quoting/escaping — numeric data only.

use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Write a matrix as CSV with an optional header row.
pub fn write_matrix(path: &Path, m: &Mat, header: Option<&[&str]>) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    if let Some(h) = header {
        assert_eq!(h.len(), m.cols());
        writeln!(f, "{}", h.join(","))?;
    }
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v:.17e}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a numeric CSV into a matrix; `skip_header` drops the first line.
pub fn read_matrix(path: &Path, skip_header: bool) -> Result<Mat> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && skip_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>> = line
            .split(',')
            .map(|tok| {
                tok.trim()
                    .parse::<f64>()
                    .with_context(|| format!("line {}: bad number {tok:?}", lineno + 1))
            })
            .collect();
        rows.push(vals?);
    }
    if rows.is_empty() {
        bail!("empty CSV {}", path.display());
    }
    let cols = rows[0].len();
    for (i, r) in rows.iter().enumerate() {
        if r.len() != cols {
            bail!("ragged CSV {} at data row {i}", path.display());
        }
    }
    let data: Vec<f64> = rows.into_iter().flatten().collect();
    Ok(Mat::from_vec(data.len() / cols, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let m = Mat::from_fn(5, 3, |i, j| (i as f64) * 1.5 - (j as f64) / 3.0);
        let dir = std::env::temp_dir().join("gpparallel_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        write_matrix(&p, &m, Some(&["a", "b", "c"])).unwrap();
        let back = read_matrix(&p, true).unwrap();
        assert!(m.max_abs_diff(&back) < 1e-15);
    }

    #[test]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("gpparallel_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_matrix(&p, false).is_err());
    }
}
