//! Tiny CSV reader/writer for matrices (dataset import/export and the
//! bench harness's result files), plus the streaming CSV → chunk-store
//! ingester behind `gpparallel ingest`. No quoting/escaping — numeric
//! data only.

use crate::data::store::{StoreManifest, StoreWriter};
use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};
use std::path::Path;

/// Write a matrix as CSV with an optional header row.
pub fn write_matrix(path: &Path, m: &Mat, header: Option<&[&str]>) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    if let Some(h) = header {
        assert_eq!(h.len(), m.cols());
        writeln!(f, "{}", h.join(","))?;
    }
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v:.17e}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a numeric CSV into a matrix; `skip_header` drops the first line.
pub fn read_matrix(path: &Path, skip_header: bool) -> Result<Mat> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && skip_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>> = line
            .split(',')
            .map(|tok| {
                tok.trim()
                    .parse::<f64>()
                    .with_context(|| format!("line {}: bad number {tok:?}", lineno + 1))
            })
            .collect();
        rows.push(vals?);
    }
    if rows.is_empty() {
        bail!("empty CSV {}", path.display());
    }
    let cols = rows[0].len();
    for (i, r) in rows.iter().enumerate() {
        if r.len() != cols {
            bail!("ragged CSV {} at data row {i}", path.display());
        }
    }
    let data: Vec<f64> = rows.into_iter().flatten().collect();
    Ok(Mat::from_vec(data.len() / cols, cols, data))
}

/// Stream a CSV into an on-disk chunk store in O(chunk) memory: the
/// first `q` columns become the x block, the remaining `d = cols − q`
/// columns the y block. Tokens are parsed exactly like
/// [`read_matrix`], so training from the store is bit-identical to
/// training from the resident CSV path. With `center` set, the
/// manifest records the column means of Y and readers subtract them
/// per chunk.
pub fn ingest_csv(csv: &Path, q: usize, out: &Path, chunk_rows: usize,
                  center: bool, skip_header: bool) -> Result<StoreManifest> {
    let f = std::fs::File::open(csv)
        .with_context(|| format!("read {}", csv.display()))?;
    let mut writer: Option<StoreWriter> = None;
    let mut cols = 0usize;
    let mut xbuf: Vec<f64> = Vec::new();
    let mut ybuf: Vec<f64> = Vec::new();
    let mut row: Vec<f64> = Vec::new();
    for (lineno, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line.with_context(|| format!("read {}", csv.display()))?;
        if lineno == 0 && skip_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        row.clear();
        for tok in line.split(',') {
            row.push(tok.trim().parse::<f64>().with_context(
                || format!("line {}: bad number {tok:?}", lineno + 1))?);
        }
        let w = match writer.as_mut() {
            Some(w) => w,
            None => {
                cols = row.len();
                if cols <= q {
                    bail!("{}: {cols} columns, need more than q={q}",
                          csv.display());
                }
                writer = Some(StoreWriter::create(out, q, cols - q, chunk_rows)?);
                xbuf.reserve(chunk_rows * q);
                ybuf.reserve(chunk_rows * (cols - q));
                writer.as_mut().expect("just set")
            }
        };
        if row.len() != cols {
            bail!("ragged CSV {} at line {}", csv.display(), lineno + 1);
        }
        xbuf.extend_from_slice(&row[..q]);
        ybuf.extend_from_slice(&row[q..]);
        if ybuf.len() == chunk_rows * (cols - q) {
            w.push_chunk(&xbuf, &ybuf)?;
            xbuf.clear();
            ybuf.clear();
        }
    }
    let mut w = writer.ok_or_else(|| anyhow::anyhow!("empty CSV {}", csv.display()))?;
    if !ybuf.is_empty() {
        w.push_chunk(&xbuf, &ybuf)?;
    }
    w.finish(center)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let m = Mat::from_fn(5, 3, |i, j| (i as f64) * 1.5 - (j as f64) / 3.0);
        let dir = std::env::temp_dir().join("gpparallel_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        write_matrix(&p, &m, Some(&["a", "b", "c"])).unwrap();
        let back = read_matrix(&p, true).unwrap();
        assert!(m.max_abs_diff(&back) < 1e-15);
    }

    #[test]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("gpparallel_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_matrix(&p, false).is_err());
        assert!(ingest_csv(&p, 1, &dir.join("store"), 4, false, false).is_err());
    }

    #[test]
    fn ingest_matches_resident_read() {
        use crate::data::store::{materialize, FileStore};
        let dir = std::env::temp_dir().join(format!(
            "gpparallel_csv_ingest_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = Mat::from_fn(11, 3, |i, j| ((i * 3 + j) as f64).cos() * 7.5);
        let p = dir.join("m.csv");
        write_matrix(&p, &m, None).unwrap();
        let man = ingest_csv(&p, 1, &dir.join("store"), 4, false, false).unwrap();
        assert_eq!((man.n, man.q, man.d), (11, 1, 2));
        let fs = FileStore::open(&dir.join("store")).unwrap();
        let (x, y) = materialize(&fs).unwrap();
        // bit-identical split of the resident parse
        let resident = read_matrix(&p, false).unwrap();
        let x = x.unwrap();
        for i in 0..11 {
            assert!(x[(i, 0)] == resident[(i, 0)]);
            assert!(y[(i, 0)] == resident[(i, 1)] && y[(i, 1)] == resident[(i, 2)]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
