//! In-memory dataset: `N × D` outputs (plus optional `N × Q` inputs for
//! supervised models), row-major like everything else in the crate.

use crate::linalg::Mat;

/// A dataset. For supervised (SGPR) problems `x` is `Some`; for
/// unsupervised (BGP-LVM / MRD) problems only `y` is observed.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Observed inputs, `N × Q` (supervised only).
    pub x: Option<Mat>,
    /// Observed outputs, `N × D`.
    pub y: Mat,
    /// Ground-truth latents, if the data is synthetic (for evaluation
    /// only — never visible to inference).
    pub latent_truth: Option<Mat>,
}

impl Dataset {
    /// Outputs only (BGP-LVM / MRD input).
    pub fn unsupervised(y: Mat) -> Self {
        Dataset { x: None, y, latent_truth: None }
    }

    /// Inputs + outputs (SGPR input).
    pub fn supervised(x: Mat, y: Mat) -> Self {
        assert_eq!(x.rows(), y.rows(), "X and Y row count mismatch");
        Dataset { x: Some(x), y, latent_truth: None }
    }

    /// Datapoint count N.
    pub fn n(&self) -> usize { self.y.rows() }
    /// Output dimensionality D.
    pub fn d(&self) -> usize { self.y.cols() }

    /// Column means of Y.
    pub fn y_mean(&self) -> Vec<f64> {
        let (n, d) = (self.n(), self.d());
        let mut m = vec![0.0; d];
        for i in 0..n {
            for j in 0..d {
                m[j] += self.y[(i, j)];
            }
        }
        for v in &mut m { *v /= n as f64; }
        m
    }

    /// Return a copy with Y centred (zero column means) — the usual
    /// preprocessing before GP-LVM fitting; the means are returned so
    /// predictions can be un-centred.
    pub fn centered(&self) -> (Dataset, Vec<f64>) {
        let m = self.y_mean();
        let mut y = self.y.clone();
        for i in 0..y.rows() {
            for j in 0..y.cols() {
                y[(i, j)] -= m[j];
            }
        }
        (Dataset { x: self.x.clone(), y, latent_truth: self.latent_truth.clone() }, m)
    }

    /// First `k` rows as a new dataset (for building size sweeps out of
    /// one master dataset, exactly like the paper's 1k..64k slices).
    pub fn take(&self, k: usize) -> Dataset {
        assert!(k <= self.n());
        let slice = |m: &Mat| {
            Mat::from_vec(k, m.cols(), m.as_slice()[..k * m.cols()].to_vec())
        };
        Dataset {
            x: self.x.as_ref().map(&slice),
            y: slice(&self.y),
            latent_truth: self.latent_truth.as_ref().map(&slice),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centering_zeroes_means() {
        let y = Mat::from_fn(10, 3, |i, j| (i * 3 + j) as f64);
        let ds = Dataset::unsupervised(y);
        let (c, means) = ds.centered();
        for j in 0..3 {
            let col_mean: f64 = (0..10).map(|i| c.y[(i, j)]).sum::<f64>() / 10.0;
            assert!(col_mean.abs() < 1e-12);
            assert!(means[j] > 0.0);
        }
    }

    #[test]
    fn take_slices_rows() {
        let y = Mat::from_fn(10, 2, |i, j| (i * 2 + j) as f64);
        let ds = Dataset::unsupervised(y.clone());
        let t = ds.take(4);
        assert_eq!(t.n(), 4);
        assert_eq!(t.y[(3, 1)], y[(3, 1)]);
    }

    #[test]
    #[should_panic]
    fn supervised_mismatch_panics() {
        let _ = Dataset::supervised(Mat::zeros(3, 1), Mat::zeros(4, 1));
    }
}
