//! A dataset is a **view over a chunk store** — `N × D` outputs (plus
//! optional `N × Q` inputs for supervised models) behind the
//! [`ChunkSource`] trait, resident or on disk. Consumers that want the
//! historical resident matrices materialize them through [`Dataset::y`]
//! / [`Dataset::x`]; streaming consumers go straight to the source.

use crate::data::store::{
    materialize, stream_y_mean, CenteredSource, ChunkSource, FileStore,
    ResidentStore, StoreManifest, TakeSource, DEFAULT_CHUNK_ROWS,
};
use crate::linalg::Mat;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// A dataset: a chunk-store view plus optional synthetic ground truth.
/// For supervised (SGPR) problems the store carries an x block
/// (`q() > 0`); for unsupervised (BGP-LVM / MRD) problems only Y is
/// observed.
#[derive(Clone)]
pub struct Dataset {
    source: Arc<dyn ChunkSource>,
    latent_truth: Option<Mat>,
}

impl std::fmt::Debug for Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let m = self.manifest();
        f.debug_struct("Dataset")
            .field("n", &m.n)
            .field("d", &m.d)
            .field("q", &m.q)
            .field("chunks", &m.num_chunks())
            .field("latent_truth", &self.latent_truth.is_some())
            .finish()
    }
}

impl Dataset {
    /// Outputs only (BGP-LVM / MRD input), wrapped in a resident store.
    pub fn unsupervised(y: Mat) -> Self {
        let store = ResidentStore::from_mats(None, y, DEFAULT_CHUNK_ROWS)
            .expect("resident dataset");
        Dataset { source: Arc::new(store), latent_truth: None }
    }

    /// Inputs + outputs (SGPR input), wrapped in a resident store.
    pub fn supervised(x: Mat, y: Mat) -> Self {
        assert_eq!(x.rows(), y.rows(), "X and Y row count mismatch");
        let store = ResidentStore::from_mats(Some(x), y, DEFAULT_CHUNK_ROWS)
            .expect("resident dataset");
        Dataset { source: Arc::new(store), latent_truth: None }
    }

    /// View over an existing chunk source (resident or on-disk).
    pub fn from_store(source: Arc<dyn ChunkSource>) -> Self {
        Dataset { source, latent_truth: None }
    }

    /// Open an on-disk store directory (`manifest.json` + `chunks.bin`).
    pub fn open(dir: &Path) -> Result<Dataset> {
        Ok(Dataset::from_store(Arc::new(FileStore::open(dir)?)))
    }

    /// Attach synthetic ground-truth latents (evaluation only — never
    /// visible to inference).
    pub fn with_latent_truth(mut self, truth: Mat) -> Self {
        assert_eq!(truth.rows(), self.n(), "latent truth row count mismatch");
        self.latent_truth = Some(truth);
        self
    }

    /// The backing chunk source (streaming consumers start here).
    pub fn source(&self) -> &Arc<dyn ChunkSource> {
        &self.source
    }

    /// The store manifest (shape, chunk grid, per-chunk stats).
    pub fn manifest(&self) -> &StoreManifest {
        self.source.manifest()
    }

    /// Datapoint count N.
    pub fn n(&self) -> usize {
        self.manifest().n
    }

    /// Output dimensionality D.
    pub fn d(&self) -> usize {
        self.manifest().d
    }

    /// Latent-input dimensionality Q (0 = unsupervised).
    pub fn q(&self) -> usize {
        self.manifest().q
    }

    /// Ground-truth latents, if the data is synthetic.
    pub fn latent_truth(&self) -> Option<&Mat> {
        self.latent_truth.as_ref()
    }

    /// Materialize the outputs as a resident `N × D` matrix (reads the
    /// whole store through a chunk reader).
    pub fn y(&self) -> Mat {
        let (_, y) = materialize(self.source.as_ref()).expect("read dataset");
        y
    }

    /// Materialize the inputs as a resident `N × Q` matrix (supervised
    /// stores only).
    pub fn x(&self) -> Option<Mat> {
        if self.q() == 0 {
            return None;
        }
        let (x, _) = materialize(self.source.as_ref()).expect("read dataset");
        x
    }

    /// Column means of Y (one streaming pass, O(chunk) memory —
    /// bit-identical to the historical resident loop).
    pub fn y_mean(&self) -> Vec<f64> {
        stream_y_mean(self.source.as_ref()).expect("read dataset")
    }

    /// A centered **view** (zero column means) — the usual
    /// preprocessing before GP-LVM fitting; the means are returned so
    /// predictions can be un-centred. Centering is a manifest-level
    /// transform applied per chunk on read, not a copy.
    pub fn centered(&self) -> (Dataset, Vec<f64>) {
        let (cs, mean) = CenteredSource::new(Arc::clone(&self.source))
            .expect("read dataset");
        (Dataset { source: Arc::new(cs), latent_truth: self.latent_truth.clone() },
         mean)
    }

    /// First `k` rows as a chunk-range **view** (for building size
    /// sweeps out of one master dataset, exactly like the paper's
    /// 1k..64k slices) — O(chunk) work, no row copies.
    pub fn take(&self, k: usize) -> Dataset {
        let t = TakeSource::new(Arc::clone(&self.source), k).expect("take view");
        let slice = |m: &Mat| {
            Mat::from_vec(k, m.cols(), m.as_slice()[..k * m.cols()].to_vec())
        };
        Dataset {
            source: Arc::new(t),
            latent_truth: self.latent_truth.as_ref().map(&slice),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centering_zeroes_means() {
        let y = Mat::from_fn(10, 3, |i, j| (i * 3 + j) as f64);
        let ds = Dataset::unsupervised(y);
        let (c, means) = ds.centered();
        let cy = c.y();
        for j in 0..3 {
            let col_mean: f64 = (0..10).map(|i| cy[(i, j)]).sum::<f64>() / 10.0;
            assert!(col_mean.abs() < 1e-12);
            assert!(means[j] > 0.0);
        }
    }

    #[test]
    fn take_slices_rows() {
        let y = Mat::from_fn(10, 2, |i, j| (i * 2 + j) as f64);
        let ds = Dataset::unsupervised(y.clone());
        let t = ds.take(4);
        assert_eq!(t.n(), 4);
        assert_eq!(t.y()[(3, 1)], y[(3, 1)]);
    }

    #[test]
    #[should_panic]
    fn supervised_mismatch_panics() {
        let _ = Dataset::supervised(Mat::zeros(3, 1), Mat::zeros(4, 1));
    }

    #[test]
    fn supervised_roundtrips_through_the_store() {
        let x = Mat::from_fn(9, 2, |i, j| (i + j) as f64 * 0.5);
        let y = Mat::from_fn(9, 1, |i, _| i as f64 - 4.0);
        let ds = Dataset::supervised(x.clone(), y.clone());
        assert_eq!((ds.n(), ds.d(), ds.q()), (9, 1, 2));
        assert!(ds.x().unwrap().max_abs_diff(&x) == 0.0);
        assert!(ds.y().max_abs_diff(&y) == 0.0);
    }
}
