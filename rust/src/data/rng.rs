//! Deterministic pseudo-random numbers (no external crates): splitmix64
//! seeding + xoshiro256** core, with normal/uniform helpers.
//!
//! Every experiment in EXPERIMENTS.md records its seed; identical seeds
//! reproduce identical datasets, initialisations and partitions across
//! runs and across worker counts.

/// xoshiro256** PRNG with Box–Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng64 {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97f4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Seed the generator (any u64, including 0, is fine — splitmix64
    /// expands it into a full state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng64 { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-chunk RNGs).
    pub fn split(&mut self, stream: u64) -> Rng64 {
        Rng64::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97f4A7C15))
    }

    /// Next raw 64-bit output of the xoshiro256** core.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free-enough for test/data use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.cached_normal = Some(r * s);
            return r * c;
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng64::new(1);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "uniform mean {mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng64::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "normal mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "normal var {var}");
    }

    #[test]
    fn split_streams_differ() {
        let mut base = Rng64::new(3);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng64::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng64::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
