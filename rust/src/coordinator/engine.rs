//! The distributed training engine — the paper's system contribution.
//!
//! SPMD over `collectives::Cluster`: rank 0 is the leader (it also
//! computes, like an MPI root), every rank owns a contiguous run of
//! fixed-shape chunks. One optimiser evaluation is the eight-step cycle
//! of DESIGN.md §4:
//!
//!   bcast params → worker stats_fwd → reduce stats → leader M×M core
//!   → bcast cotangents → worker stats_vjp → reduce/gather grads
//!   → optimiser step
//!
//! The engine is **multi-view** from the start: SGPR is one supervised
//! view, the Bayesian GP-LVM is one unsupervised view, MRD is several
//! unsupervised views sharing q(X). The KL term is counted exactly once
//! (attached to view 0).

use super::backend::{Backend, ChunkData, RustCpuBackend, ViewParams, XlaBackend};
use super::partition::{ChunkRange, Partition};
use crate::collectives::{Cluster, Comm};
use crate::config::BackendKind;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::bound::bound_and_grads;
use crate::math::stats::{Stats, StatsCts};
use crate::metrics::{Phase, PhaseTimer};
use crate::optim::{Adam, Lbfgs, OptResult, Optimizer, Scg, StopReason};
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::time::Instant;

// ---------------------------------------------------------------------
// problem + config types
// ---------------------------------------------------------------------

/// One observed view: outputs plus per-view kernel/noise/inducing state.
#[derive(Clone, Debug)]
pub struct ViewSpec {
    /// N × D_v observations.
    pub y: Mat,
    /// Initial inducing inputs, M × Q.
    pub z0: Mat,
    /// Initial kernel hyperparameters.
    pub kern0: RbfArd,
    /// Initial noise precision β.
    pub beta0: f64,
    /// AOT config name for the XLA backend (e.g. "paper").
    pub aot_config: String,
}

/// The latent-input specification shared by all views.
#[derive(Clone, Debug)]
pub enum LatentSpec {
    /// Supervised: X observed (N × Q).
    Observed(Mat),
    /// Unsupervised: variational q(x_n) = N(μ_n, diag S_n).
    Variational { mu0: Mat, s0: Mat },
}

impl LatentSpec {
    pub fn is_variational(&self) -> bool {
        matches!(self, LatentSpec::Variational { .. })
    }
}

/// A complete inference problem.
#[derive(Clone, Debug)]
pub struct Problem {
    pub latent: LatentSpec,
    pub views: Vec<ViewSpec>,
    pub q: usize,
}

impl Problem {
    pub fn n(&self) -> usize {
        self.views[0].y.rows()
    }

    fn validate(&self) -> Result<()> {
        let n = self.n();
        for (v, view) in self.views.iter().enumerate() {
            if view.y.rows() != n {
                return Err(anyhow!("view {v}: {} rows, expected {n}", view.y.rows()));
            }
            if view.z0.cols() != self.q || view.kern0.q() != self.q {
                return Err(anyhow!("view {v}: Q mismatch"));
            }
        }
        match &self.latent {
            LatentSpec::Observed(x) => {
                if x.rows() != n || x.cols() != self.q {
                    return Err(anyhow!("X shape mismatch"));
                }
            }
            LatentSpec::Variational { mu0, s0 } => {
                if mu0.rows() != n || mu0.cols() != self.q
                    || s0.rows() != n || s0.cols() != self.q {
                    return Err(anyhow!("mu0/s0 shape mismatch"));
                }
            }
        }
        Ok(())
    }
}

/// Optimiser selection.
#[derive(Clone, Debug)]
pub enum OptChoice {
    Lbfgs(Lbfgs),
    Scg(Scg),
    Adam(Adam),
}

impl OptChoice {
    fn as_optimizer(&self) -> Box<dyn Optimizer + '_> {
        match self {
            OptChoice::Lbfgs(o) => Box::new(o.clone()),
            OptChoice::Scg(o) => Box::new(o.clone()),
            OptChoice::Adam(o) => Box::new(o.clone()),
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    /// Fixed chunk size C (must equal the AOT config's C for Xla).
    pub chunk: usize,
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    pub opt: OptChoice,
    pub verbose: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 1,
            chunk: 64,
            backend: BackendKind::RustCpu,
            artifacts_dir: PathBuf::from("artifacts"),
            opt: OptChoice::Lbfgs(Lbfgs { max_iters: 100, ..Default::default() }),
            verbose: false,
        }
    }
}

/// Fitted parameters after training.
#[derive(Clone, Debug)]
pub struct Fitted {
    pub kerns: Vec<RbfArd>,
    pub betas: Vec<f64>,
    pub zs: Vec<Mat>,
    /// Posterior means (variational) or the observed X (supervised).
    pub mu: Mat,
    /// Posterior variances (variational) — empty for supervised.
    pub s: Mat,
}

/// Everything a training run reports.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Final (maximised) bound F.
    pub f: f64,
    /// Bound after each accepted optimiser iteration.
    pub trace: Vec<f64>,
    pub fitted: Fitted,
    pub timing: PhaseTimer,
    pub iterations: usize,
    pub evaluations: usize,
    pub stop: StopReason,
    pub bytes_sent: u64,
    pub messages_sent: u64,
    /// Mean wall-clock per objective evaluation (the paper's
    /// "time per iteration"), seconds.
    pub sec_per_eval: f64,
    /// Per-rank total seconds spent in the distributable phases
    /// (stats_fwd + stats_vjp), indexed by rank.
    pub per_rank_compute: Vec<f64>,
}

impl TrainResult {
    /// Projected wall-clock per iteration on hardware with one core per
    /// rank: the critical path `max_r(distributable_r) + indistributable`.
    ///
    /// This testbed is single-core, so ranks time-share the core and raw
    /// wall-clock cannot exhibit the paper's worker scaling; the per-rank
    /// compute totals *do* divide with workers, and this projection is
    /// the faithful reconstruction of Fig 1a's y-axis (EXPERIMENTS.md
    /// reports both numbers).
    pub fn projected_sec_per_eval(&self) -> f64 {
        if self.evaluations == 0 {
            return 0.0;
        }
        let crit = self.per_rank_compute.iter().cloned().fold(0.0f64, f64::max);
        let leader_total = self.timing.total().as_secs_f64();
        let leader_dist = self.timing.get(Phase::StatsFwd).as_secs_f64()
            + self.timing.get(Phase::StatsVjp).as_secs_f64();
        let indist = (leader_total - leader_dist).max(0.0);
        (crit + indist) / self.evaluations as f64
    }
}

// ---------------------------------------------------------------------
// parameter packing
// ---------------------------------------------------------------------

/// Unpacked view of the optimiser's parameter vector.
struct ParamLayout {
    q: usize,
    m: usize,
    views: usize,
    n: usize,
    variational: bool,
}

impl ParamLayout {
    fn view_len(&self) -> usize {
        (self.q + 1) + 1 + self.m * self.q
    }

    fn len(&self) -> usize {
        self.views * self.view_len()
            + if self.variational { 2 * self.n * self.q } else { 0 }
    }

    /// (log_hyp, log_beta, z) slices of view v.
    fn view_parts<'a>(&self, x: &'a [f64], v: usize) -> (&'a [f64], f64, &'a [f64]) {
        let o = v * self.view_len();
        let h = &x[o..o + self.q + 1];
        let b = x[o + self.q + 1];
        let z = &x[o + self.q + 2..o + self.view_len()];
        (h, b, z)
    }

    fn mu_slice<'a>(&self, x: &'a [f64]) -> &'a [f64] {
        let o = self.views * self.view_len();
        &x[o..o + self.n * self.q]
    }

    fn log_s_slice<'a>(&self, x: &'a [f64]) -> &'a [f64] {
        let o = self.views * self.view_len() + self.n * self.q;
        &x[o..o + self.n * self.q]
    }
}

// ---------------------------------------------------------------------
// worker state
// ---------------------------------------------------------------------

/// Per-rank state: owned chunks (per view) and a backend per view.
struct WorkerState {
    /// chunks[c] carries the mask and the supervised x; per-view Y lives
    /// in `view_y[v][c]`.
    chunks: Vec<ChunkData>,
    view_y: Vec<Vec<Mat>>,
    backends: Vec<Box<dyn Backend>>,
    /// Runtime kept alive for the XLA backends (owns the PJRT client).
    _runtime: Option<Runtime>,
    span: Option<ChunkRange>,
    q: usize,
    variational: bool,
}

impl WorkerState {
    fn build(problem: &Problem, cfg: &EngineConfig, part: &Partition, rank: usize)
             -> Result<WorkerState> {
        let q = problem.q;
        let c = part.chunk;
        let ranges = &part.per_worker[rank];
        let variational = problem.latent.is_variational();

        // chunk skeletons (mask + supervised x)
        let mut chunks = Vec::with_capacity(ranges.len());
        for r in ranges {
            let live = r.len();
            let mut w = vec![0.0; c];
            w[..live].fill(1.0);
            let x = match &problem.latent {
                LatentSpec::Observed(x_all) => {
                    let mut x = Mat::zeros(c, q);
                    for i in 0..live {
                        x.row_mut(i).copy_from_slice(x_all.row(r.start + i));
                    }
                    x
                }
                LatentSpec::Variational { .. } => Mat::zeros(0, 0),
            };
            chunks.push(ChunkData { start: r.start, live, y: Mat::zeros(0, 0), x, w });
        }

        // per-view padded Y tiles
        let mut view_y = Vec::with_capacity(problem.views.len());
        for view in &problem.views {
            let d = view.y.cols();
            let mut tiles = Vec::with_capacity(ranges.len());
            for r in ranges {
                let mut y = Mat::zeros(c, d);
                for i in 0..r.len() {
                    y.row_mut(i).copy_from_slice(view.y.row(r.start + i));
                }
                tiles.push(y);
            }
            view_y.push(tiles);
        }

        // backends
        let mut backends: Vec<Box<dyn Backend>> = Vec::new();
        let mut runtime = None;
        match cfg.backend {
            BackendKind::RustCpu => {
                for _ in &problem.views {
                    backends.push(Box::new(RustCpuBackend));
                }
            }
            BackendKind::Xla => {
                let rt = Runtime::new(&cfg.artifacts_dir)?;
                for view in &problem.views {
                    backends.push(Box::new(XlaBackend::new(&rt, &view.aot_config)?));
                }
                runtime = Some(rt);
            }
        }

        Ok(WorkerState {
            chunks,
            view_y,
            backends,
            _runtime: runtime,
            span: part.worker_span(rank),
            q,
            variational,
        })
    }

    /// Slice this rank's (μ, S) rows for chunk `c` out of the span-local
    /// buffers, padding the tail (μ = 0, S = 1).
    fn chunk_latent(&self, chunk_idx: usize, mu_span: &[f64], s_span: &[f64],
                    c: usize) -> (Mat, Mat) {
        let ch = &self.chunks[chunk_idx];
        let span_start = self.span.unwrap().start;
        let off = (ch.start - span_start) * self.q;
        let live = ch.live * self.q;
        let mut mu = Mat::zeros(c, self.q);
        let mut s = Mat::from_vec(c, self.q, vec![1.0; c * self.q]);
        mu.as_mut_slice()[..live].copy_from_slice(&mu_span[off..off + live]);
        s.as_mut_slice()[..live].copy_from_slice(&s_span[off..off + live]);
        (mu, s)
    }

    /// One full local forward pass: per-view stats summed over chunks.
    fn local_fwd(&mut self, globals: &GlobalParams, mu_span: &[f64], s_span: &[f64],
                 c: usize, m: usize, ds: &[usize]) -> Result<Vec<Stats>> {
        let mut out = Vec::with_capacity(globals.views.len());
        for (v, gv) in globals.views.iter().enumerate() {
            // ds[v] (not the local tile width): ranks with zero chunks must
            // still pack wire vectors of the global shape for the reducer.
            let mut acc = Stats::zeros(m, ds[v]);
            let mut first = true;
            for ci in 0..self.chunks.len() {
                // borrow dance: move Y tile into the chunk for the call
                let mut chunk = self.chunks[ci].clone();
                chunk.y = self.view_y[v][ci].clone();
                let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
                let st = if self.variational {
                    let (mu, s) = self.chunk_latent(ci, mu_span, s_span, c);
                    self.backends[v].stats_fwd(&chunk, Some((&mu, &s)), &vp, v == 0)?
                } else {
                    self.backends[v].stats_fwd(&chunk, None, &vp, false)?
                };
                if first {
                    acc = st;
                    first = false;
                } else {
                    acc.add_assign(&st);
                }
            }
            out.push(acc);
        }
        Ok(out)
    }

    /// One full local VJP pass. Returns (per-view (dz, dhyp) partials,
    /// span-local dμ, span-local d log S).
    #[allow(clippy::too_many_arguments)]
    fn local_vjp(&mut self, globals: &GlobalParams, all_cts: &[StatsCts],
                 mu_span: &[f64], s_span: &[f64], c: usize, m: usize)
                 -> Result<(Vec<(Mat, Vec<f64>)>, Vec<f64>, Vec<f64>)> {
        let span_len = self.span.map(|s| s.len()).unwrap_or(0);
        let mut dmu_span = vec![0.0; span_len * self.q];
        let mut dls_span = vec![0.0; span_len * self.q];
        let mut view_grads = Vec::with_capacity(globals.views.len());

        for (v, gv) in globals.views.iter().enumerate() {
            let mut dz = Mat::zeros(m, self.q);
            let mut dhyp = vec![0.0; self.q + 1];
            for ci in 0..self.chunks.len() {
                let mut chunk = self.chunks[ci].clone();
                chunk.y = self.view_y[v][ci].clone();
                let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
                let g = if self.variational {
                    let (mu, s) = self.chunk_latent(ci, mu_span, s_span, c);
                    let g = self.backends[v].stats_vjp(&chunk, Some((&mu, &s)), &vp,
                                                       &all_cts[v])?;
                    // accumulate local grads (chain dS -> dlogS needs S)
                    let span_start = self.span.unwrap().start;
                    let off = (chunk.start - span_start) * self.q;
                    for i in 0..chunk.live * self.q {
                        dmu_span[off + i] += g.dmu.as_slice()[i];
                        let s_val = s.as_slice()[i];
                        dls_span[off + i] += g.ds.as_slice()[i] * s_val;
                    }
                    g
                } else {
                    self.backends[v].stats_vjp(&chunk, None, &vp, &all_cts[v])?
                };
                dz.axpy(1.0, &g.dz);
                for (a, b) in dhyp.iter_mut().zip(&g.dhyp) {
                    *a += b;
                }
            }
            view_grads.push((dz, dhyp));
        }
        Ok((view_grads, dmu_span, dls_span))
    }
}

/// Per-view globals as unpacked on every rank each evaluation.
struct GlobalView {
    log_hyp: Vec<f64>,
    log_beta: f64,
    z: Mat,
}

struct GlobalParams {
    views: Vec<GlobalView>,
}

fn unpack_globals(layout: &ParamLayout, x: &[f64]) -> GlobalParams {
    let views = (0..layout.views)
        .map(|v| {
            let (h, b, z) = layout.view_parts(x, v);
            GlobalView {
                log_hyp: h.to_vec(),
                log_beta: b,
                z: Mat::from_vec(layout.m, layout.q, z.to_vec()),
            }
        })
        .collect();
    GlobalParams { views }
}

// ---------------------------------------------------------------------
// wire protocol
// ---------------------------------------------------------------------

const CMD_EVAL: f64 = 1.0;
const CMD_STOP: f64 = 0.0;
const TAG_LOCALS: u64 = 100;

fn stats_wire_len(m: usize, ds: &[usize]) -> usize {
    ds.iter().map(|d| 4 + m * d + m * m).sum()
}

fn cts_wire_len(m: usize, ds: &[usize]) -> usize {
    ds.iter().map(|d| 3 + m * d + m * m).sum()
}

// ---------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------

/// Distributed trainer for sparse-GP models.
pub struct Engine {
    pub problem: Problem,
    pub cfg: EngineConfig,
}

enum RunMode {
    /// Full optimisation.
    Optimize,
    /// Evaluate the objective k times at the initial point (benchmark
    /// mode — the paper's "average time per iteration").
    TimeOnly(usize),
}

impl Engine {
    pub fn new(problem: Problem, cfg: EngineConfig) -> Result<Engine> {
        problem.validate()?;
        if problem.views.iter().any(|v| v.z0.rows() != problem.views[0].z0.rows()) {
            return Err(anyhow!("all views must share M (per-view M is future work)"));
        }
        Ok(Engine { problem, cfg })
    }

    /// Train to convergence (or the iteration budget).
    pub fn train(&self) -> Result<TrainResult> {
        self.run(RunMode::Optimize)
    }

    /// Benchmark mode: time `evals` objective evaluations without
    /// optimising (Fig 1a/1b harness).
    pub fn time_iterations(&self, evals: usize) -> Result<TrainResult> {
        self.run(RunMode::TimeOnly(evals))
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout {
            q: self.problem.q,
            m: self.problem.views[0].z0.rows(),
            views: self.problem.views.len(),
            n: self.problem.n(),
            variational: self.problem.latent.is_variational(),
        }
    }

    fn x0(&self) -> Vec<f64> {
        let layout = self.layout();
        let mut x = Vec::with_capacity(layout.len());
        for view in &self.problem.views {
            x.extend(view.kern0.to_log_hyp());
            x.push(view.beta0.ln());
            x.extend_from_slice(view.z0.as_slice());
        }
        if let LatentSpec::Variational { mu0, s0 } = &self.problem.latent {
            x.extend_from_slice(mu0.as_slice());
            x.extend(s0.as_slice().iter().map(|s| s.ln()));
        }
        x
    }

    fn run(&self, mode: RunMode) -> Result<TrainResult> {
        let part = Partition::new(self.problem.n(), self.cfg.chunk, self.cfg.workers);
        let layout = self.layout();
        let ds: Vec<usize> = self.problem.views.iter().map(|v| v.y.cols()).collect();

        let mut results = Cluster::run(self.cfg.workers, |comm| {
            let rank = comm.rank();
            let state = WorkerState::build(&self.problem, &self.cfg, &part, rank);
            match state {
                Err(e) => Err(anyhow!("rank {rank}: {e:#}")),
                Ok(state) => {
                    if rank == 0 {
                        self.leader(comm, state, &part, &layout, &ds, &mode).map(Some)
                    } else {
                        self.worker(comm, state, &layout, &ds).map(|_| None)
                    }
                }
            }
        });
        // propagate worker errors first, then take the leader's result
        for r in &results {
            if let Err(e) = r {
                return Err(anyhow!("{e:#}"));
            }
        }
        results
            .remove(0)
            .map(|o| o.expect("leader returns a result"))
    }

    /// Leader: drives the optimiser; each objective call runs the full
    /// distributed cycle.
    fn leader(&self, mut comm: Comm, mut state: WorkerState, _part: &Partition,
              layout: &ParamLayout, ds: &[usize], mode: &RunMode)
              -> Result<TrainResult> {
        let m = layout.m;
        let c = self.cfg.chunk;
        let n = layout.n;
        let q = layout.q;
        let variational = layout.variational;
        let mut timer = PhaseTimer::new();
        let mut eval_err: Option<anyhow::Error> = None;
        let mut eval_count = 0usize;
        let mut eval_seconds = 0.0f64;
        let leader_compute_cpu = std::cell::Cell::new(0.0f64);

        let spans: Vec<Option<ChunkRange>> = {
            let part = Partition::new(n, c, self.cfg.workers);
            (0..self.cfg.workers).map(|r| part.worker_span(r)).collect()
        };

        // The distributed objective (returns −F, −∇F for minimisation).
        let mut objective = |x: &[f64]| -> (f64, Vec<f64>) {
            let eval_t0 = Instant::now();
            let mut inner = || -> Result<(f64, Vec<f64>)> {
                let globals = unpack_globals(layout, x);

                // 1–3: command + parameter distribution
                let (mu_all, s_all): (Vec<f64>, Vec<f64>) = if variational {
                    let mu = layout.mu_slice(x).to_vec();
                    let s: Vec<f64> = layout.log_s_slice(x).iter().map(|v| v.exp()).collect();
                    (mu, s)
                } else {
                    (Vec::new(), Vec::new())
                };

                timer.time(Phase::Bcast, || {
                    comm.bcast(0, vec![CMD_EVAL]);
                    comm.bcast(0, x[..layout.views * layout.view_len()].to_vec());
                    if variational {
                        for (r, span) in spans.iter().enumerate().skip(1) {
                            if let Some(sp) = span {
                                let lo = sp.start * q;
                                let hi = sp.end * q;
                                let mut msg = Vec::with_capacity(2 * (hi - lo));
                                msg.extend_from_slice(&mu_all[lo..hi]);
                                msg.extend_from_slice(&s_all[lo..hi]);
                                comm.send(r, TAG_LOCALS, &msg);
                            }
                        }
                    }
                });

                let (mu_span, s_span): (&[f64], &[f64]) = if variational {
                    let sp = spans[0].expect("rank0 span");
                    (&mu_all[sp.start * q..sp.end * q], &s_all[sp.start * q..sp.end * q])
                } else {
                    (&[], &[])
                };

                // 4: local fwd + reduce
                let t0 = Instant::now();
                let cpu0 = crate::metrics::thread_cpu_time();
                let local_stats = state.local_fwd(&globals, mu_span, s_span, c, m, ds)?;
                leader_compute_cpu.set(leader_compute_cpu.get()
                    + crate::metrics::thread_cpu_time() - cpu0);
                timer.add(Phase::StatsFwd, t0.elapsed());
                let t0 = Instant::now();
                let mut wire = Vec::with_capacity(stats_wire_len(m, ds));
                for st in &local_stats {
                    wire.extend(st.pack());
                }
                let reduced = comm.reduce_sum(0, &wire).expect("root");
                timer.add(Phase::Reduce, t0.elapsed());

                // 5: the indistributable core
                let t0 = Instant::now();
                let mut f_total = 0.0;
                let mut all_cts = Vec::with_capacity(ds.len());
                let mut direct = Vec::with_capacity(ds.len());
                let mut off = 0;
                for (v, &d) in ds.iter().enumerate() {
                    let len = 4 + m * d + m * m;
                    let stats = Stats::unpack(m, d, &wire_slice(&reduced, off, len));
                    off += len;
                    let kern = RbfArd::from_log_hyp(&globals.views[v].log_hyp);
                    let out = bound_and_grads(&stats, &globals.views[v].z, &kern,
                                              globals.views[v].log_beta)?;
                    f_total += out.f;
                    all_cts.push(out.cts);
                    direct.push((out.dz, out.dhyp, out.dlog_beta));
                }
                timer.add(Phase::BoundCore, t0.elapsed());

                // bcast cotangents
                timer.time(Phase::Bcast, || {
                    let mut wire = Vec::with_capacity(cts_wire_len(m, ds));
                    for cts in &all_cts {
                        wire.extend(cts.pack());
                    }
                    comm.bcast(0, wire);
                });

                // 6: local vjp
                let t0 = Instant::now();
                let cpu0 = crate::metrics::thread_cpu_time();
                let (view_grads, dmu_span, dls_span) =
                    state.local_vjp(&globals, &all_cts, mu_span, s_span, c, m)?;
                leader_compute_cpu.set(leader_compute_cpu.get()
                    + crate::metrics::thread_cpu_time() - cpu0);
                timer.add(Phase::StatsVjp, t0.elapsed());

                // 7: reduce global partials + gather locals
                let t0 = Instant::now();
                let mut gwire = Vec::with_capacity(ds.len() * (m * q + q + 1));
                for (dz, dhyp) in &view_grads {
                    gwire.extend_from_slice(dz.as_slice());
                    gwire.extend_from_slice(dhyp);
                }
                let greduced = comm.reduce_sum(0, &gwire).expect("root");
                let locals = if variational {
                    let mut mine = Vec::with_capacity(dmu_span.len() * 2);
                    mine.extend_from_slice(&dmu_span);
                    mine.extend_from_slice(&dls_span);
                    comm.gather(0, &mine)
                } else {
                    comm.gather(0, &[])
                };
                timer.add(Phase::GatherGrads, t0.elapsed());

                // assemble ∇F
                let t0 = Instant::now();
                let mut grad = vec![0.0; layout.len()];
                let mut goff = 0;
                for (v, (dz_direct, dhyp_direct, dlog_beta)) in direct.iter().enumerate() {
                    let o = v * layout.view_len();
                    let dz_part = &greduced[goff..goff + m * q];
                    goff += m * q;
                    let dhyp_part = &greduced[goff..goff + q + 1];
                    goff += q + 1;
                    for i in 0..q + 1 {
                        grad[o + i] = dhyp_direct[i] + dhyp_part[i];
                    }
                    grad[o + q + 1] = *dlog_beta;
                    for i in 0..m * q {
                        grad[o + q + 2 + i] = dz_direct.as_slice()[i] + dz_part[i];
                    }
                }
                if variational {
                    let locals = locals.expect("root");
                    let base_mu = layout.views * layout.view_len();
                    let base_ls = base_mu + n * q;
                    for (r, piece) in locals.iter().enumerate() {
                        if let Some(sp) = spans[r] {
                            let len = (sp.end - sp.start) * q;
                            debug_assert_eq!(piece.len(), 2 * len);
                            grad[base_mu + sp.start * q..base_mu + sp.end * q]
                                .copy_from_slice(&piece[..len]);
                            grad[base_ls + sp.start * q..base_ls + sp.end * q]
                                .copy_from_slice(&piece[len..]);
                        }
                    }
                }
                timer.add(Phase::GatherGrads, t0.elapsed());

                // minimise −F
                for gi in grad.iter_mut() {
                    *gi = -*gi;
                }
                Ok((-f_total, grad))
            };

            match inner() {
                Ok(pair) => {
                    eval_count += 1;
                    eval_seconds += eval_t0.elapsed().as_secs_f64();
                    timer.note_eval();
                    pair
                }
                Err(e) => {
                    // abort the optimiser with a large value; remember why
                    if eval_err.is_none() {
                        eval_err = Some(e);
                    }
                    (f64::INFINITY, vec![0.0; layout.len()])
                }
            }
        };

        let x0 = self.x0();
        let opt_result: OptResult = match mode {
            RunMode::Optimize => {
                let opt = self.cfg.opt.as_optimizer();
                opt.minimize(&mut objective, x0)
            }
            RunMode::TimeOnly(k) => {
                let mut f_last = 0.0;
                for _ in 0..*k {
                    let (f, _) = objective(&x0);
                    f_last = f;
                }
                OptResult {
                    x: x0,
                    f: f_last,
                    iterations: *k,
                    evaluations: *k,
                    stop: StopReason::MaxIters,
                    trace: vec![f_last],
                }
            }
        };

        // 8. stop the workers and collect their compute-time totals
        comm.bcast(0, vec![CMD_STOP]);
        let leader_compute = leader_compute_cpu.get();
        let per_rank_compute: Vec<f64> = comm
            .gather(0, &[leader_compute])
            .expect("root")
            .into_iter()
            .map(|v| v.first().copied().unwrap_or(0.0))
            .collect();

        if let Some(e) = eval_err {
            return Err(e);
        }

        // unpack fitted parameters
        let x = &opt_result.x;
        let globals = unpack_globals(layout, x);
        let fitted = Fitted {
            kerns: globals.views.iter().map(|v| RbfArd::from_log_hyp(&v.log_hyp)).collect(),
            betas: globals.views.iter().map(|v| v.log_beta.exp()).collect(),
            zs: globals.views.iter().map(|v| v.z.clone()).collect(),
            mu: if variational {
                Mat::from_vec(n, q, layout.mu_slice(x).to_vec())
            } else {
                match &self.problem.latent {
                    LatentSpec::Observed(xobs) => xobs.clone(),
                    _ => unreachable!(),
                }
            },
            s: if variational {
                Mat::from_vec(n, q, layout.log_s_slice(x).iter().map(|v| v.exp()).collect())
            } else {
                Mat::zeros(0, 0)
            },
        };

        if self.cfg.verbose {
            eprintln!("[leader] {}", timer.summary());
        }

        Ok(TrainResult {
            f: -opt_result.f,
            trace: opt_result.trace.iter().map(|v| -v).collect(),
            fitted,
            timing: timer,
            iterations: opt_result.iterations,
            evaluations: opt_result.evaluations,
            stop: opt_result.stop,
            bytes_sent: comm.bytes_sent(),
            messages_sent: comm.messages_sent(),
            sec_per_eval: if eval_count > 0 { eval_seconds / eval_count as f64 } else { 0.0 },
            per_rank_compute,
        })
    }

    /// Worker loop: obey commands until STOP.
    fn worker(&self, mut comm: Comm, mut state: WorkerState, layout: &ParamLayout,
              ds: &[usize]) -> Result<()> {
        let m = layout.m;
        let c = self.cfg.chunk;
        let q = layout.q;
        let variational = layout.variational;
        let mut compute_secs = 0.0f64;
        loop {
            let cmd = comm.bcast(0, Vec::new());
            if cmd.is_empty() || cmd[0] == CMD_STOP {
                let _ = comm.gather(0, &[compute_secs]);
                return Ok(());
            }
            let gx = comm.bcast(0, Vec::new());
            let globals = unpack_globals(layout, &pad_globals(layout, &gx));

            let (mu_span, s_span): (Vec<f64>, Vec<f64>) = if variational {
                if let Some(sp) = state.span {
                    let msg = comm.recv(0, TAG_LOCALS);
                    let len = (sp.end - sp.start) * q;
                    (msg[..len].to_vec(), msg[len..].to_vec())
                } else {
                    (Vec::new(), Vec::new())
                }
            } else {
                (Vec::new(), Vec::new())
            };

            // fwd + reduce
            let t0 = crate::metrics::thread_cpu_time();
            let local_stats = state.local_fwd(&globals, &mu_span, &s_span, c, m, ds)?;
            compute_secs += crate::metrics::thread_cpu_time() - t0;
            let mut wire = Vec::with_capacity(stats_wire_len(m, ds));
            for st in &local_stats {
                wire.extend(st.pack());
            }
            let _ = comm.reduce_sum(0, &wire);

            // cts
            let cwire = comm.bcast(0, Vec::new());
            let mut all_cts = Vec::with_capacity(ds.len());
            let mut off = 0;
            for &d in ds {
                let len = 3 + m * d + m * m;
                all_cts.push(StatsCts::unpack(m, d, &cwire[off..off + len]));
                off += len;
            }

            // vjp + reduce + gather
            let t0 = crate::metrics::thread_cpu_time();
            let (view_grads, dmu_span, dls_span) =
                state.local_vjp(&globals, &all_cts, &mu_span, &s_span, c, m)?;
            compute_secs += crate::metrics::thread_cpu_time() - t0;
            let mut gwire = Vec::with_capacity(ds.len() * (m * q + q + 1));
            for (dz, dhyp) in &view_grads {
                gwire.extend_from_slice(dz.as_slice());
                gwire.extend_from_slice(dhyp);
            }
            let _ = comm.reduce_sum(0, &gwire);
            if variational {
                let mut mine = Vec::with_capacity(dmu_span.len() * 2);
                mine.extend_from_slice(&dmu_span);
                mine.extend_from_slice(&dls_span);
                let _ = comm.gather(0, &mine);
            } else {
                let _ = comm.gather(0, &[]);
            }
        }
    }
}

/// The leader broadcasts only the global prefix of the parameter vector;
/// workers never need μ/logS in packed form, so pad with zeros to reuse
/// `unpack_globals`.
fn pad_globals(layout: &ParamLayout, gx: &[f64]) -> Vec<f64> {
    let mut x = vec![0.0; layout.len()];
    x[..gx.len()].copy_from_slice(gx);
    x
}

fn wire_slice(wire: &[f64], off: usize, len: usize) -> Vec<f64> {
    wire[off..off + len].to_vec()
}
