//! The distributed training engine — the paper's system contribution —
//! split into an execution layer of three submodules:
//!
//! - [`problem`] — the model statement ([`Problem`], [`ViewSpec`],
//!   [`LatentSpec`], validation) and the flat parameter-vector layout
//!   every rank agrees on.
//! - [`cycle`] — the eight-step SPMD evaluation cycle of DESIGN.md §4 as
//!   a reusable [`DistributedEvaluator`]:
//!
//!     bcast params → worker stats_fwd → reduce stats → leader M×M core
//!     → bcast cotangents → worker stats_vjp → reduce/gather grads
//!
//!   By default the cycle runs **pipelined per view** (view v's vjp
//!   overlaps view v+1's in-flight stats reduction and the leader's
//!   core work; `EngineConfig::pipeline = false` restores the
//!   whole-cycle synchronous schedule, bit-identically). Worker compute
//!   goes through the backend factory (rust-cpu, parallel-cpu with
//!   intra-rank chunk fan-out, or xla) — with a per-chunk fwd→vjp
//!   kernel-state cache on the CPU paths — and the collectives run over
//!   binomial trees by default.
//! - [`train`] — the optimiser loop + stopping ([`Engine`],
//!   [`EngineConfig`], [`TrainResult`]): rank 0 is the leader (it also
//!   computes, like an MPI root), every rank owns a contiguous run of
//!   fixed-shape chunks.
//! - [`serve`] — sharded serving: the fitted posterior is broadcast
//!   once and prediction batches are partitioned over the same ranks
//!   ([`DistributedPosterior`], bit-identical to the single-node
//!   posterior), sequentially (`predict_into`) or as a **batch stream**
//!   (`predict_stream`: batch k+1 issued before batch k's gather, so
//!   serving ranks never idle between batches). Entered from a training
//!   cluster via `DistributedEvaluator::begin_serving` or standalone
//!   over a raw `Comm`. The posterior itself is built by a
//!   **distributed stats-only pass** (the STATS verb,
//!   `DistributedEvaluator::stats_pass`/`posterior_core_fresh`) — the
//!   leader does no full-data work — or, at the fitted parameters, for
//!   **free** from the final evaluation's captured statistics
//!   (`posterior_core_at`), and can be **hot-swapped** mid-session at
//!   new parameters (`refit_and_swap`, or a standalone
//!   `DistributedPosterior::rebroadcast`).
//! - [`frontend`] — the concurrent-client serving front-end: N client
//!   handles enqueue prediction rows into a bounded queue
//!   (backpressure), a batcher coalesces them into size-or-deadline
//!   micro-batches through the streamed issue/complete machinery (two
//!   in flight), and replies fan back out to the callers — bit-identical
//!   per request to a direct `predict_into`, with Prometheus-style
//!   latency/throughput metrics ([`ServingFrontend`],
//!   [`FrontendHandle`], `Engine::train_then_serve`, CLI
//!   `predict --serve`).
//!
//! The engine is **multi-view** from the start: SGPR is one supervised
//! view, the Bayesian GP-LVM is one unsupervised view, MRD is several
//! unsupervised views sharing q(X). The KL term is counted exactly once
//! (attached to view 0).
//!
//! This file is a thin facade: the public API (`Engine`, `Problem`, …)
//! is unchanged from the days it was a single 900-line module, so
//! `models::*`, the examples and the tests import exactly as before.

pub mod cycle;
pub mod frontend;
pub mod problem;
pub mod serve;
pub mod train;

pub use cycle::DistributedEvaluator;
pub use frontend::{FrontendConfig, FrontendHandle, ServingFrontend, ServingReport};
pub use problem::{Fitted, LatentSpec, Problem, ViewData, ViewSpec};
pub use serve::{DistributedPosterior, ServeSignal};
pub use train::{Engine, EngineConfig, OptChoice, TrainResult};
