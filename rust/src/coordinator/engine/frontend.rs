//! Concurrent-client serving front-end: a dynamic micro-batching
//! scheduler over the streamed predict pipeline.
//!
//! The streamed serving protocol (`serve.rs`) is single-caller: one
//! leader-side loop issues batches and collects gathers. This module
//! puts a **request scheduler** in front of it so N concurrent clients
//! share one cluster:
//!
//! ```text
//!   client 0 ──┐                           ┌─▸ reply (mean, var) ── client 0
//!   client 1 ──┤  bounded     batcher      │
//!      …       ├─▸ queue ──▸ (size-or-  ──▸┤  sharded cluster round
//!   client N ──┘  (rows)      deadline)    │  (issue/complete, ≤2 in
//!        ▲                      │          │   flight — predict_stream's
//!        └────── backpressure ──┘          └─▸ machinery)   … fan-out
//! ```
//!
//! - **Enqueue.** [`FrontendHandle::predict`] pushes a request's rows
//!   into a bounded queue and blocks until its reply arrives. When the
//!   queued rows would exceed [`FrontendConfig::queue_rows`] the enqueue
//!   itself blocks (backpressure) — the queue never grows unboundedly. A
//!   request larger than the whole capacity is still admitted, but only
//!   once the queue is empty, so it cannot deadlock.
//! - **Batching.** The batcher closes a micro-batch when the queued rows
//!   reach [`FrontendConfig::max_batch_rows`] (size trigger) **or** the
//!   oldest queued request has waited [`FrontendConfig::max_wait`]
//!   (deadline trigger), whichever comes first. Coalescing is pure row
//!   concatenation in arrival order.
//! - **Cluster rounds.** Coalesced batches go through the exact
//!   `issue_batch`/`complete_batch` halves `predict_stream` uses, with
//!   at most two batches in flight; the stream flag is raised only for a
//!   batch whose successor is issued immediately (a dangling flag would
//!   deadlock the worker prefetch against the leader's gather).
//! - **Fan-out.** A completed batch's rows are split back out to the
//!   originating requests in arrival order. Because sharded serving is
//!   bit-identical to the single-node posterior *row by row*, every
//!   reply is **bit-identical** to a direct
//!   [`DistributedPosterior::predict_into`] call on that request alone
//!   (asserted in `rust/tests/frontend_test.rs` for ranks 1–9 × both
//!   CPU backends).
//! - **Controls.** [`FrontendHandle::swap`] / [`FrontendHandle::refit`]
//!   are applied on a **batch boundary**: the batcher drains its
//!   in-flight window first, so no coalesced batch ever mixes two
//!   posteriors, and every reply is entirely pre-swap or entirely
//!   post-swap. The calls block until the control has been applied.
//! - **Failure.** A failed cluster round (poisoned worker, compute
//!   error) fails *that batch's* requests with a clean error and leaves
//!   the session usable — exactly `predict_stream`'s semantics; later
//!   requests succeed again (e.g. after a good swap).
//!
//! Observability rides [`ServingMetrics`] (latency histogram,
//! throughput, batch fill, queue depth, backpressure counters — see
//! [`crate::metrics::serving`]) plus the serve-side [`Phase`] variants
//! on the shared [`PhaseTimer`], and the transport's own message/byte
//! counters; [`FrontendConfig::dump_every`] enables the periodic
//! Prometheus-style dump the CLI's `predict --serve` mode prints.
//!
//! Two ways in, mirroring `serve.rs`: standalone over a raw [`Comm`]
//! via [`ServingFrontend::run`], or from a training cluster via
//! [`DistributedEvaluator::serve_frontend`](super::cycle::DistributedEvaluator::serve_frontend)
//! (which is what [`Engine::train_then_serve`](super::train::Engine::train_then_serve)
//! wires up end to end — there `refit` works too).

use crate::collectives::Comm;
use crate::coordinator::backend::Backend;
use crate::linalg::Mat;
use crate::math::predict::PosteriorCore;
use crate::metrics::serving::{ServingMetrics, ServingSnapshot};
use crate::metrics::{Phase, PhaseTimer};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use super::serve::DistributedPosterior;

/// Knobs of the micro-batching scheduler.
#[derive(Clone, Debug)]
pub struct FrontendConfig {
    /// Size trigger: close a micro-batch once this many rows are queued
    /// (a single larger request still goes through as one batch).
    pub max_batch_rows: usize,
    /// Deadline trigger: close a micro-batch once the oldest queued
    /// request has waited this long, full or not.
    pub max_wait: Duration,
    /// Backpressure bound: enqueues block while the queue already holds
    /// rows and admitting the request would push it past this many.
    pub queue_rows: usize,
    /// Print the Prometheus-style metrics dump (plus the serve-phase
    /// timer summary) to stderr this often; `None` disables it.
    pub dump_every: Option<Duration>,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            max_batch_rows: 256,
            max_wait: Duration::from_micros(200),
            queue_rows: 4096,
            dump_every: None,
        }
    }
}

/// A client's reply channel: the served rows or a displayable error.
/// (`anyhow::Error` is not `Clone`, and one failed batch must error
/// several requests, so the wire type is the rendered message.)
type Reply = std::result::Result<(Mat, Vec<f64>), String>;

/// One queued client request.
struct Request {
    rows: Mat,
    tx: Sender<Reply>,
    enqueued: Instant,
}

/// A control operation the batcher applies on a batch boundary.
pub(crate) enum ControlOp {
    /// Hot-swap the served posterior (standalone and training clusters).
    Swap(Box<PosteriorCore>),
    /// Refit at the given packed parameters through the distributed
    /// stats pass, then swap (training clusters only).
    Refit(Vec<f64>),
}

/// A control operation plus the channel its caller blocks on.
struct ControlMsg {
    op: ControlOp,
    done: Sender<std::result::Result<(), String>>,
}

/// Everything behind the mutex: the request queue (with its row count),
/// pending controls, and the closed flag.
struct QueueState {
    reqs: VecDeque<Request>,
    /// Total rows across `reqs` (the backpressure quantity).
    rows: usize,
    control: VecDeque<ControlMsg>,
    closed: bool,
}

/// State shared between every handle and the batcher.
struct Shared {
    q: Mutex<QueueState>,
    /// Batcher waits here for arrivals/controls/close.
    arrived: Condvar,
    /// Producers wait here for queue space (backpressure).
    space: Condvar,
    cfg: FrontendConfig,
    metrics: ServingMetrics,
    /// Input width Q every request must match.
    q_cols: usize,
    /// Output width D (sizes the empty-request fast path's reply).
    d_cols: usize,
}

/// Lock the queue, tolerating poison. A client thread that panics
/// while holding the lock must not wedge the whole front-end: every
/// critical section below either finishes its multi-field update
/// before any fallible call or only reads, so the state a panicking
/// holder leaves behind is still consistent — recover the guard
/// instead of cascading the panic into every other client.
fn lock_queue(m: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison tolerance as [`lock_queue`].
fn wait_queue<'a>(cv: &Condvar, g: MutexGuard<'a, QueueState>) -> MutexGuard<'a, QueueState> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`] with the same poison tolerance as
/// [`lock_queue`] (the timeout flag is unused: callers re-check their
/// predicate and the deadline on wake).
fn wait_queue_timeout<'a>(
    cv: &Condvar,
    g: MutexGuard<'a, QueueState>,
    dur: Duration,
) -> MutexGuard<'a, QueueState> {
    let (g, _timed_out) = cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner);
    g
}

/// A cloneable client handle onto a [`ServingFrontend`]: enqueue
/// prediction requests, apply posterior controls, read metrics, close
/// the front-end. Safe to use from any thread.
#[derive(Clone)]
pub struct FrontendHandle {
    sh: Arc<Shared>,
}

impl FrontendHandle {
    /// Predict `rows` (an `n × Q` matrix) through the shared cluster.
    /// Blocks until the reply arrives — through backpressure first, if
    /// the queue is full. Row `i` of the reply corresponds to row `i` of
    /// `rows`, bit-identical to a direct `predict_into` of `rows` alone.
    /// An empty request returns empty outputs without a cluster round,
    /// exactly like `predict_into`.
    pub fn predict(&self, rows: Mat) -> Result<(Mat, Vec<f64>)> {
        let sh = &*self.sh;
        if rows.cols() != sh.q_cols {
            return Err(anyhow!("request has Q={}, posterior expects Q={}",
                               rows.cols(), sh.q_cols));
        }
        let n = rows.rows();
        let enqueued = Instant::now();
        if n == 0 {
            sh.metrics.note_unqueued_request();
            sh.metrics.note_finished(true, 0, enqueued.elapsed());
            return Ok((Mat::zeros(0, sh.d_cols), Vec::new()));
        }
        let (tx, rx) = channel();
        {
            let mut q = lock_queue(&sh.q);
            let mut blocked = false;
            // backpressure: wait while the queue holds rows and this
            // request would push it past capacity (an oversized request
            // is admitted alone, once the queue is empty)
            while !q.closed && q.rows > 0 && q.rows + n > sh.cfg.queue_rows {
                blocked = true;
                q = wait_queue(&sh.space, q);
            }
            if q.closed {
                return Err(anyhow!("serving front-end is closed"));
            }
            if blocked {
                sh.metrics.note_blocked(enqueued.elapsed());
            }
            q.rows += n;
            q.reqs.push_back(Request { rows, tx, enqueued });
            sh.metrics.note_enqueued(q.rows);
            sh.arrived.notify_all();
        }
        match rx.recv() {
            Ok(Ok(out)) => Ok(out),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(_) => Err(anyhow!("serving front-end shut down before the reply")),
        }
    }

    /// Hot-swap the served posterior. Applied on a batch boundary: the
    /// batcher drains its in-flight window first, so no coalesced batch
    /// mixes the two posteriors. Blocks until the swap broadcast is out;
    /// every request enqueued after this returns sees the new posterior.
    pub fn swap(&self, core: PosteriorCore) -> Result<()> {
        self.control(ControlOp::Swap(Box::new(core)))
    }

    /// Refit the posterior at packed parameters `x` through the
    /// distributed stats pass, then swap — training clusters only
    /// (a standalone front-end has no training cluster to refit with).
    /// Batch-boundary and blocking semantics as
    /// [`swap`](FrontendHandle::swap); a failed refit leaves the old
    /// posterior serving (the error comes back here).
    pub fn refit(&self, x: &[f64]) -> Result<()> {
        self.control(ControlOp::Refit(x.to_vec()))
    }

    /// Close the front-end: new requests are rejected, queued and
    /// in-flight ones are still served, and the batcher's `run` returns
    /// once drained. Idempotent.
    pub fn close(&self) {
        let sh = &*self.sh;
        let mut q = lock_queue(&sh.q);
        q.closed = true;
        sh.arrived.notify_all();
        sh.space.notify_all();
    }

    /// Point-in-time metrics (no transport counters — those only the
    /// batcher sees; its report and periodic dump include them).
    pub fn metrics(&self) -> ServingSnapshot {
        self.sh.metrics.snapshot(None)
    }

    fn control(&self, op: ControlOp) -> Result<()> {
        let sh = &*self.sh;
        let (done, rx) = channel();
        {
            let mut q = lock_queue(&sh.q);
            if q.closed {
                return Err(anyhow!("serving front-end is closed"));
            }
            q.control.push_back(ControlMsg { op, done });
            sh.arrived.notify_all();
        }
        match rx.recv() {
            Ok(Ok(())) => Ok(()),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(_) => Err(anyhow!("serving front-end shut down before the control")),
        }
    }
}

/// What the batcher needs from the serving substrate: the
/// issue/complete halves of one sharded batch round, control
/// application, and the transport counters. Implemented over a raw
/// `(DistributedPosterior, Comm, Backend)` triple here and over a
/// `DistributedEvaluator` in `cycle.rs` (where `Refit` works).
pub(crate) trait ServeDriver {
    /// Validate a batch and size the output buffers (`prepare_outputs`).
    fn prepare(&mut self, batch: &Mat, mean: &mut Mat, var: &mut Vec<f64>)
               -> Result<()>;
    /// Issue one non-empty batch (`issue_batch`); `stream` promises the
    /// next `issue` follows before this batch's `complete`. An error is
    /// a dead transport: the batch was never issued.
    fn issue(&mut self, batch: &Mat, stream: bool) -> Result<()>;
    /// Complete the oldest issued batch (`complete_batch`). An error
    /// fails the batch, not the session.
    fn complete(&mut self, batch: &Mat, mean: &mut Mat, var: &mut Vec<f64>)
                -> Result<()>;
    /// Apply a control operation (the in-flight window is empty here).
    fn control(&mut self, op: ControlOp) -> Result<()>;
    /// Transport `(bytes_sent, messages_sent)` counters.
    fn comm_counters(&self) -> (u64, u64);
}

/// The standalone driver: a raw serving session over `Comm`.
struct PosteriorDriver<'a> {
    dp: &'a mut DistributedPosterior,
    comm: &'a mut Comm,
    backend: &'a mut dyn Backend,
}

impl ServeDriver for PosteriorDriver<'_> {
    fn prepare(&mut self, batch: &Mat, mean: &mut Mat, var: &mut Vec<f64>)
               -> Result<()> {
        self.dp.prepare_outputs(batch, mean, var)
    }

    fn issue(&mut self, batch: &Mat, stream: bool) -> Result<()> {
        self.dp.issue_batch(self.comm, batch, stream)
    }

    fn complete(&mut self, batch: &Mat, mean: &mut Mat, var: &mut Vec<f64>)
                -> Result<()> {
        self.dp.complete_batch(self.comm, self.backend, batch, mean, var)
    }

    fn control(&mut self, op: ControlOp) -> Result<()> {
        match op {
            ControlOp::Swap(core) => self.dp.rebroadcast(*core, self.comm),
            ControlOp::Refit(_) => Err(anyhow!(
                "refit requires a training cluster (standalone front-end)")),
        }
    }

    fn comm_counters(&self) -> (u64, u64) {
        (self.comm.bytes_sent(), self.comm.messages_sent())
    }
}

/// One coalesced batch: the concatenated rows and, in arrival order,
/// the requests whose rows they are.
struct InFlight {
    batch: Mat,
    members: Vec<Request>,
}

/// Everything the batcher learned over one `run`: the final metrics
/// (including the session's transport counter deltas) and the
/// serve-phase timer.
#[derive(Clone, Debug)]
pub struct ServingReport {
    /// Final metrics snapshot (transport deltas included).
    pub snapshot: ServingSnapshot,
    /// Where the batcher's time went (`Srv*` phases).
    pub timer: PhaseTimer,
}

/// The micro-batching scheduler. Construct once per serving session,
/// hand [`FrontendHandle`]s to client threads, and drive the batcher on
/// the leader rank with [`ServingFrontend::run`] (standalone) or
/// [`DistributedEvaluator::serve_frontend`](super::cycle::DistributedEvaluator::serve_frontend)
/// (training cluster). `run` returns once every handle's work is done
/// and some handle called [`FrontendHandle::close`].
pub struct ServingFrontend {
    sh: Arc<Shared>,
}

impl ServingFrontend {
    /// New front-end for a posterior with input width `q_cols` and
    /// output width `d_cols`.
    pub fn new(cfg: FrontendConfig, q_cols: usize, d_cols: usize) -> ServingFrontend {
        assert!(cfg.max_batch_rows > 0, "max_batch_rows must be positive");
        assert!(cfg.queue_rows > 0, "queue_rows must be positive");
        let metrics = ServingMetrics::new(cfg.max_batch_rows);
        ServingFrontend {
            sh: Arc::new(Shared {
                q: Mutex::new(QueueState {
                    reqs: VecDeque::new(),
                    rows: 0,
                    control: VecDeque::new(),
                    closed: false,
                }),
                arrived: Condvar::new(),
                space: Condvar::new(),
                cfg,
                metrics,
                q_cols,
                d_cols,
            }),
        }
    }

    /// A client handle (cloneable; hand one per client thread).
    pub fn handle(&self) -> FrontendHandle {
        FrontendHandle { sh: Arc::clone(&self.sh) }
    }

    /// Drive the batcher over a standalone serving session (leader rank
    /// only; the `DistributedPosterior` must already be constructed —
    /// its session-open broadcast out). Returns once the front-end is
    /// closed and drained; the session itself stays open (callers still
    /// own `finish`).
    pub fn run(&self, dp: &mut DistributedPosterior, comm: &mut Comm,
               backend: &mut dyn Backend) -> ServingReport {
        let mut drv = PosteriorDriver { dp, comm, backend };
        self.run_driver(&mut drv)
    }

    /// The batcher loop, generic over the serving substrate.
    pub(crate) fn run_driver(&self, drv: &mut dyn ServeDriver) -> ServingReport {
        let sh = &*self.sh;
        let base = drv.comm_counters();
        let mut timer = PhaseTimer::new();
        let mut inflight: VecDeque<InFlight> = VecDeque::new();
        // one reusable output pair: completions happen one at a time
        let mut mean = Mat::zeros(0, 0);
        let mut var: Vec<f64> = Vec::new();
        let mut last_dump = Instant::now();

        loop {
            // controls apply on a batch boundary: drain the in-flight
            // window first, so no coalesced batch mixes two posteriors
            if self.control_pending() {
                while let Some(fl) = inflight.pop_front() {
                    self.complete_one(drv, fl, &mut mean, &mut var, &mut timer);
                }
                for msg in self.take_controls() {
                    let res = drv.control(msg.op).map_err(|e| format!("{e:#}"));
                    let _ = msg.done.send(res);
                }
                continue;
            }

            // top up the ≤2-deep in-flight window; only the first batch
            // may block (on arrivals or the deadline)
            let mut formed: Vec<InFlight> = Vec::new();
            while inflight.len() + formed.len() < 2 {
                let may_block = inflight.is_empty() && formed.is_empty();
                match self.form_batch(may_block, &mut timer) {
                    Some(fl) => formed.push(fl),
                    None => break,
                }
            }
            // issue back to back; the stream flag is raised only when
            // another issue follows immediately (a dangling flag would
            // deadlock the worker prefetch against our gather)
            let k = formed.len();
            for (i, fl) in formed.into_iter().enumerate() {
                let t0 = Instant::now();
                let res = drv.issue(&fl.batch, i + 1 < k);
                timer.add(Phase::SrvClusterRound, t0.elapsed());
                match res {
                    Ok(()) => inflight.push_back(fl),
                    Err(e) => {
                        // a failed issue is a dead transport: the batch
                        // never went out, so there is no gather to
                        // collect — fail exactly these requests and keep
                        // the batcher alive (clients get errors, never
                        // hangs; the caller decides when to close)
                        let msg = format!("{e:#}");
                        let t0 = Instant::now();
                        for m in fl.members {
                            sh.metrics.note_finished(false, m.rows.rows(),
                                                     m.enqueued.elapsed());
                            let _ = m.tx.send(Err(msg.clone()));
                        }
                        timer.add(Phase::SrvFanout, t0.elapsed());
                    }
                }
            }

            // complete the oldest in-flight batch and fan it back out
            match inflight.pop_front() {
                Some(fl) => self.complete_one(drv, fl, &mut mean, &mut var,
                                              &mut timer),
                None => {
                    // nothing in flight and nothing formable: done once
                    // closed and fully drained
                    if self.closed_and_idle() {
                        break;
                    }
                }
            }

            if let Some(every) = sh.cfg.dump_every {
                if last_dump.elapsed() >= every {
                    last_dump = Instant::now();
                    let snap = sh.metrics.snapshot(Some(self.counter_delta(drv, base)));
                    eprint!("{}", snap.render_text());
                    eprintln!("# serve phases: {}", timer.summary());
                }
            }
        }

        // reject anything that slipped in between the last drain and the
        // close (and any controls), so no caller blocks forever
        self.shutdown_pending();
        ServingReport {
            snapshot: sh.metrics.snapshot(Some(self.counter_delta(drv, base))),
            timer,
        }
    }

    /// Transport counters accumulated since the batcher started.
    fn counter_delta(&self, drv: &dyn ServeDriver, base: (u64, u64)) -> (u64, u64) {
        let now = drv.comm_counters();
        (now.0.saturating_sub(base.0), now.1.saturating_sub(base.1))
    }

    fn control_pending(&self) -> bool {
        !lock_queue(&self.sh.q).control.is_empty()
    }

    fn take_controls(&self) -> Vec<ControlMsg> {
        lock_queue(&self.sh.q).control.drain(..).collect()
    }

    fn closed_and_idle(&self) -> bool {
        let q = lock_queue(&self.sh.q);
        q.closed && q.reqs.is_empty() && q.control.is_empty()
    }

    /// Try to close one micro-batch. Returns `None` when no trigger has
    /// fired (and `may_block` is false), when a control is pending, or
    /// when the front-end is closed with an empty queue. With
    /// `may_block`, waits on arrivals up to the oldest request's
    /// deadline.
    fn form_batch(&self, may_block: bool, timer: &mut PhaseTimer) -> Option<InFlight> {
        let sh = &*self.sh;
        let mut members: Vec<Request> = Vec::new();
        let rows;
        {
            let mut q = lock_queue(&sh.q);
            loop {
                if !q.control.is_empty() {
                    return None; // boundary first: let the caller apply it
                }
                match q.reqs.front() {
                    Some(front) => {
                        let deadline = front.enqueued + sh.cfg.max_wait;
                        let now = Instant::now();
                        // size trigger, deadline trigger, or flush-on-close
                        if q.rows >= sh.cfg.max_batch_rows || q.closed
                            || now >= deadline {
                            break;
                        }
                        if !may_block {
                            return None;
                        }
                        let t0 = Instant::now();
                        let g = wait_queue_timeout(&sh.arrived, q, deadline - now);
                        timer.add(Phase::SrvEnqueueWait, t0.elapsed());
                        q = g;
                    }
                    None => {
                        if q.closed || !may_block {
                            return None;
                        }
                        let t0 = Instant::now();
                        q = wait_queue(&sh.arrived, q);
                        timer.add(Phase::SrvEnqueueWait, t0.elapsed());
                    }
                }
            }
            // take whole requests up to the size cap (the first request
            // is always taken, even when alone it exceeds the cap)
            let mut took = 0usize;
            while let Some(r) = q.reqs.pop_front() {
                let n = r.rows.rows();
                if !members.is_empty() && took + n > sh.cfg.max_batch_rows {
                    q.reqs.push_front(r);
                    break;
                }
                took += n;
                members.push(r);
                if took >= sh.cfg.max_batch_rows {
                    break;
                }
            }
            q.rows -= took;
            rows = took;
            sh.metrics.note_batch(rows, q.rows);
            sh.space.notify_all();
        }
        // concatenate outside the lock (arrival order = row order)
        let t0 = Instant::now();
        let mut batch = Mat::zeros(rows, sh.q_cols);
        let mut at = 0usize;
        for m in &members {
            let len = m.rows.rows() * sh.q_cols;
            batch.as_mut_slice()[at..at + len].copy_from_slice(m.rows.as_slice());
            at += len;
        }
        timer.add(Phase::SrvBatchAssembly, t0.elapsed());
        Some(InFlight { batch, members })
    }

    /// Complete one issued batch and fan its rows (or its error) back
    /// out to the member requests.
    fn complete_one(&self, drv: &mut dyn ServeDriver, fl: InFlight, mean: &mut Mat,
                    var: &mut Vec<f64>, timer: &mut PhaseTimer) {
        let sh = &*self.sh;
        let t0 = Instant::now();
        let res = drv.prepare(&fl.batch, mean, var)
            .and_then(|()| drv.complete(&fl.batch, mean, var));
        timer.add(Phase::SrvClusterRound, t0.elapsed());

        let t0 = Instant::now();
        match res {
            Ok(()) => {
                let d = mean.cols();
                let mut row = 0usize;
                for m in fl.members {
                    let n = m.rows.rows();
                    let m_mean = Mat::from_vec(
                        n, d, mean.as_slice()[row * d..(row + n) * d].to_vec());
                    let m_var = var[row..row + n].to_vec();
                    row += n;
                    sh.metrics.note_finished(true, n, m.enqueued.elapsed());
                    let _ = m.tx.send(Ok((m_mean, m_var)));
                }
            }
            Err(e) => {
                // the batch failed, the session did not: fail exactly
                // these requests and keep serving
                let msg = format!("{e:#}");
                for m in fl.members {
                    sh.metrics.note_finished(false, m.rows.rows(),
                                             m.enqueued.elapsed());
                    let _ = m.tx.send(Err(msg.clone()));
                }
            }
        }
        timer.add(Phase::SrvFanout, t0.elapsed());
    }

    /// Terminal sweep: mark the front-end closed and reject whatever is
    /// still queued, so no client blocks on a reply that will never
    /// come.
    fn shutdown_pending(&self) {
        let sh = &*self.sh;
        let mut q = lock_queue(&sh.q);
        q.closed = true;
        q.rows = 0;
        for r in q.reqs.drain(..) {
            sh.metrics.note_finished(false, r.rows.rows(), r.enqueued.elapsed());
            let _ = r.tx.send(Err("serving front-end shut down".into()));
        }
        for c in q.control.drain(..) {
            let _ = c.done.send(Err("serving front-end shut down".into()));
        }
        sh.space.notify_all();
        sh.arrived.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frontend(cfg: FrontendConfig) -> ServingFrontend {
        ServingFrontend::new(cfg, 2, 3)
    }

    /// An empty request replies immediately — no queue, no batcher.
    #[test]
    fn empty_request_short_circuits() {
        let fe = frontend(FrontendConfig::default());
        let (mean, var) = fe.handle().predict(Mat::zeros(0, 2)).unwrap();
        assert_eq!((mean.rows(), mean.cols()), (0, 3));
        assert!(var.is_empty());
        assert_eq!(fe.handle().metrics().completed, 1);
    }

    /// A wrong-width request is rejected at the handle, like
    /// `predict_into`'s validation.
    #[test]
    fn wrong_width_request_is_rejected() {
        let fe = frontend(FrontendConfig::default());
        let err = fe.handle().predict(Mat::zeros(3, 5)).unwrap_err();
        assert!(format!("{err:#}").contains("Q=5"), "{err:#}");
    }

    /// After close, new requests are rejected instead of queued forever.
    #[test]
    fn closed_frontend_rejects_requests_and_controls() {
        let fe = frontend(FrontendConfig::default());
        let h = fe.handle();
        h.close();
        let err = h.predict(Mat::zeros(1, 2)).unwrap_err();
        assert!(format!("{err:#}").contains("closed"), "{err:#}");
        let err = h.refit(&[0.0]).unwrap_err();
        assert!(format!("{err:#}").contains("closed"), "{err:#}");
    }

    /// The batcher coalesces by size and by deadline: queued rows below
    /// the size trigger still form a batch once the oldest request's
    /// deadline expires. (Driven through `form_batch` directly — the
    /// full cluster path is exercised in `tests/frontend_test.rs`.)
    #[test]
    fn form_batch_fires_on_size_or_deadline() {
        let fe = frontend(FrontendConfig {
            max_batch_rows: 4,
            max_wait: Duration::from_millis(5),
            ..FrontendConfig::default()
        });
        let mut timer = PhaseTimer::new();
        // below the size trigger, non-blocking: no batch yet
        let (tx, _rx) = channel();
        fe.sh.q.lock().unwrap().reqs.push_back(Request {
            rows: Mat::zeros(2, 2), tx, enqueued: Instant::now(),
        });
        fe.sh.q.lock().unwrap().rows = 2;
        assert!(fe.form_batch(false, &mut timer).is_none());
        // blocking: the deadline fires and the undersized batch closes
        let fl = fe.form_batch(true, &mut timer).expect("deadline batch");
        assert_eq!(fl.batch.rows(), 2);
        assert!(timer.get(Phase::SrvEnqueueWait) > Duration::ZERO);
        // at the size trigger, non-blocking: closes immediately, split
        // along whole-request boundaries
        for n in [3usize, 1, 2] {
            let (tx, _rx) = channel();
            fe.sh.q.lock().unwrap().reqs.push_back(Request {
                rows: Mat::zeros(n, 2), tx, enqueued: Instant::now(),
            });
        }
        fe.sh.q.lock().unwrap().rows = 6;
        let fl = fe.form_batch(false, &mut timer).expect("size batch");
        assert_eq!(fl.batch.rows(), 4, "3+1 fits, +2 would exceed the cap");
        assert_eq!(fl.members.len(), 2);
        assert_eq!(fe.sh.q.lock().unwrap().rows, 2);
    }

    /// Backpressure math: an enqueue that would overflow the bound waits
    /// for space; an oversized request is admitted once the queue is
    /// empty (never deadlocks).
    #[test]
    fn backpressure_blocks_then_admits() {
        let fe = frontend(FrontendConfig {
            queue_rows: 4,
            ..FrontendConfig::default()
        });
        let h = fe.handle();
        // fill the queue to capacity from a client thread
        let filler = {
            let h = h.clone();
            std::thread::spawn(move || h.predict(Mat::zeros(4, 2)))
        };
        while fe.sh.q.lock().unwrap().rows < 4 {
            std::thread::yield_now();
        }
        // this enqueue must block (4 + 3 > 4) until the batcher drains;
        // an oversized request (6 > 4) must also be admitted then
        let blocked = {
            let h = h.clone();
            std::thread::spawn(move || h.predict(Mat::zeros(6, 2)))
        };
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(fe.sh.q.lock().unwrap().reqs.len(), 1,
                   "second request must still be waiting for space");
        // drain one batch's worth by hand (no cluster in a unit test):
        // form_batch frees the rows and signals `space`
        let mut timer = PhaseTimer::new();
        let fl = fe.form_batch(false, &mut timer).expect("full batch");
        assert_eq!(fl.batch.rows(), 4);
        // the blocked enqueue now lands
        while fe.sh.q.lock().unwrap().reqs.is_empty() {
            std::thread::yield_now();
        }
        assert!(fe.handle().metrics().enqueue_blocked >= 1);
        // shut down: both callers get clean errors, nobody hangs
        h.close();
        for m in fl.members {
            let _ = m.tx.send(Err("test shutdown".into()));
        }
        fe.shutdown_pending();
        assert!(filler.join().unwrap().is_err());
        assert!(blocked.join().unwrap().is_err());
    }
}
