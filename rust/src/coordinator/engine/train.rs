//! The trainer: optimiser loop + stopping on top of the distributed
//! cycle. [`Engine`] launches one SPMD rank per worker, hands rank 0 the
//! optimiser (step 8 of the cycle) and parks the rest in
//! [`DistributedEvaluator::serve`].

use super::cycle::DistributedEvaluator;
use super::problem::{ParamLayout, Problem};
use crate::collectives::Cluster;
use crate::config::BackendKind;
use crate::coordinator::partition::Partition;
use crate::metrics::{Phase, PhaseTimer};
use crate::optim::{Adam, Lbfgs, OptResult, Optimizer, Scg, StopReason};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::time::Instant;

/// Optimiser selection.
#[derive(Clone, Debug)]
pub enum OptChoice {
    Lbfgs(Lbfgs),
    Scg(Scg),
    Adam(Adam),
}

impl OptChoice {
    fn as_optimizer(&self) -> Box<dyn Optimizer + '_> {
        match self {
            OptChoice::Lbfgs(o) => Box::new(o.clone()),
            OptChoice::Scg(o) => Box::new(o.clone()),
            OptChoice::Adam(o) => Box::new(o.clone()),
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    /// Fixed chunk size C (must equal the AOT config's C for Xla).
    pub chunk: usize,
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    pub opt: OptChoice,
    /// Per-view pipelined evaluation cycle (compute overlapping the
    /// collectives) vs the whole-cycle synchronous schedule. The two are
    /// bit-identical in outputs; `false` is the debugging escape hatch.
    pub pipeline: bool,
    pub verbose: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 1,
            chunk: 64,
            backend: BackendKind::RustCpu,
            artifacts_dir: PathBuf::from("artifacts"),
            opt: OptChoice::Lbfgs(Lbfgs { max_iters: 100, ..Default::default() }),
            pipeline: true,
            verbose: false,
        }
    }
}

/// Everything a training run reports.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Final (maximised) bound F.
    pub f: f64,
    /// Bound after each accepted optimiser iteration.
    pub trace: Vec<f64>,
    pub fitted: super::problem::Fitted,
    pub timing: PhaseTimer,
    pub iterations: usize,
    pub evaluations: usize,
    pub stop: StopReason,
    pub bytes_sent: u64,
    pub messages_sent: u64,
    /// Mean wall-clock per objective evaluation (the paper's
    /// "time per iteration"), seconds.
    pub sec_per_eval: f64,
    /// Per-rank total seconds spent in the distributable phases
    /// (stats_fwd + stats_vjp), indexed by rank.
    pub per_rank_compute: Vec<f64>,
}

impl TrainResult {
    /// Projected wall-clock per iteration on hardware with one core per
    /// rank: the critical path `max_r(distributable_r) + indistributable`.
    ///
    /// This testbed is single-core, so ranks time-share the core and raw
    /// wall-clock cannot exhibit the paper's worker scaling; the per-rank
    /// compute totals *do* divide with workers, and this projection is
    /// the faithful reconstruction of Fig 1a's y-axis (EXPERIMENTS.md
    /// reports both numbers).
    pub fn projected_sec_per_eval(&self) -> f64 {
        if self.evaluations == 0 {
            return 0.0;
        }
        let crit = self.per_rank_compute.iter().cloned().fold(0.0f64, f64::max);
        let leader_total = self.timing.total().as_secs_f64();
        let leader_dist = self.timing.get(Phase::StatsFwd).as_secs_f64()
            + self.timing.get(Phase::StatsVjp).as_secs_f64();
        let indist = (leader_total - leader_dist).max(0.0);
        (crit + indist) / self.evaluations as f64
    }
}

enum RunMode {
    /// Full optimisation.
    Optimize,
    /// Evaluate the objective k times at the initial point (benchmark
    /// mode — the paper's "average time per iteration").
    TimeOnly(usize),
}

/// Distributed trainer for sparse-GP models.
pub struct Engine {
    pub problem: Problem,
    pub cfg: EngineConfig,
}

impl Engine {
    pub fn new(problem: Problem, cfg: EngineConfig) -> Result<Engine> {
        problem.validate()?;
        if problem.views.iter().any(|v| v.z0.rows() != problem.views[0].z0.rows()) {
            return Err(anyhow!("all views must share M (per-view M is future work)"));
        }
        Ok(Engine { problem, cfg })
    }

    /// Train to convergence (or the iteration budget).
    pub fn train(&self) -> Result<TrainResult> {
        self.run(RunMode::Optimize)
    }

    /// Benchmark mode: time `evals` objective evaluations without
    /// optimising (Fig 1a/1b harness).
    pub fn time_iterations(&self, evals: usize) -> Result<TrainResult> {
        self.run(RunMode::TimeOnly(evals))
    }

    fn run(&self, mode: RunMode) -> Result<TrainResult> {
        let part = Partition::new(self.problem.n(), self.cfg.chunk, self.cfg.workers);

        let mut results = Cluster::run(self.cfg.workers, |comm| {
            let rank = comm.rank();
            match DistributedEvaluator::new(&self.problem, &self.cfg, &part, comm) {
                Err(e) => Err(anyhow!("rank {rank}: {e:#}")),
                Ok(mut ev) => {
                    if rank == 0 {
                        self.leader(ev, &mode).map(Some)
                    } else {
                        ev.serve().map(|_| None)
                    }
                }
            }
        });
        // propagate worker errors first, then take the leader's result
        for r in &results {
            if let Err(e) = r {
                return Err(anyhow!("{e:#}"));
            }
        }
        results
            .remove(0)
            .map(|o| o.expect("leader returns a result"))
    }

    /// Leader: drives the optimiser; each objective call runs the full
    /// distributed cycle through the evaluator.
    fn leader(&self, mut ev: DistributedEvaluator, mode: &RunMode) -> Result<TrainResult> {
        let layout = ParamLayout::new(&self.problem);
        let x0 = layout.initial_params(&self.problem);
        let n_params = ev.n_params();

        let mut eval_err: Option<anyhow::Error> = None;
        let mut eval_count = 0usize;
        let mut eval_seconds = 0.0f64;

        let opt_result: OptResult = {
            // The distributed objective (−F, −∇F for minimisation).
            let mut objective = |x: &[f64]| -> (f64, Vec<f64>) {
                let t0 = Instant::now();
                match ev.eval(x) {
                    Ok((f, mut grad)) => {
                        eval_count += 1;
                        eval_seconds += t0.elapsed().as_secs_f64();
                        for g in grad.iter_mut() {
                            *g = -*g;
                        }
                        (-f, grad)
                    }
                    Err(e) => {
                        // abort the optimiser with a large value; remember why
                        if eval_err.is_none() {
                            eval_err = Some(e);
                        }
                        (f64::INFINITY, vec![0.0; n_params])
                    }
                }
            };

            match mode {
                RunMode::Optimize => {
                    let opt = self.cfg.opt.as_optimizer();
                    opt.minimize(&mut objective, x0.clone())
                }
                RunMode::TimeOnly(k) => {
                    let mut f_last = 0.0;
                    for _ in 0..*k {
                        let (f, _) = objective(&x0);
                        f_last = f;
                    }
                    OptResult {
                        x: x0.clone(),
                        f: f_last,
                        iterations: *k,
                        evaluations: *k,
                        stop: StopReason::MaxIters,
                        trace: vec![f_last],
                    }
                }
            }
        };

        // 8. stop the workers and collect their compute-time totals
        let per_rank_compute = ev.finish();

        if let Some(e) = eval_err {
            return Err(e);
        }

        let fitted = layout.unpack_fitted(&self.problem, &opt_result.x);

        if self.cfg.verbose {
            eprintln!("[leader] {}", ev.timer().summary());
        }

        Ok(TrainResult {
            f: -opt_result.f,
            trace: opt_result.trace.iter().map(|v| -v).collect(),
            fitted,
            timing: ev.timer().clone(),
            iterations: opt_result.iterations,
            evaluations: opt_result.evaluations,
            stop: opt_result.stop,
            bytes_sent: ev.bytes_sent(),
            messages_sent: ev.messages_sent(),
            sec_per_eval: if eval_count > 0 { eval_seconds / eval_count as f64 } else { 0.0 },
            per_rank_compute,
        })
    }
}
