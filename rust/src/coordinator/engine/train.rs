//! The trainer: optimiser loop + stopping on top of the distributed
//! cycle. [`Engine`] launches one SPMD rank per worker, hands rank 0 the
//! optimiser (step 8 of the cycle) and parks the rest in
//! [`DistributedEvaluator::serve`].

use super::cycle::DistributedEvaluator;
use super::frontend::{FrontendConfig, FrontendHandle, ServingFrontend, ServingReport};
use super::problem::{Fitted, LatentSpec, ParamLayout, Problem};
use crate::collectives::Cluster;
use crate::config::BackendKind;
use crate::coordinator::partition::Partition;
use crate::linalg::simd::{self, SimdLevel};
use crate::linalg::Mat;
use crate::metrics::{Phase, PhaseTimer};
use crate::optim::{Adam, Lbfgs, OptResult, Optimizer, Scg, StopReason};
use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::time::Instant;

/// Optimiser selection.
#[derive(Clone, Debug)]
pub enum OptChoice {
    /// L-BFGS with strong-Wolfe line search (default).
    Lbfgs(Lbfgs),
    /// Scaled conjugate gradients.
    Scg(Scg),
    /// Adam (first-order baseline).
    Adam(Adam),
}

impl OptChoice {
    fn as_optimizer(&self) -> Box<dyn Optimizer + '_> {
        match self {
            OptChoice::Lbfgs(o) => Box::new(o.clone()),
            OptChoice::Scg(o) => Box::new(o.clone()),
            OptChoice::Adam(o) => Box::new(o.clone()),
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of SPMD ranks (rank 0 is the leader and also computes).
    pub workers: usize,
    /// Fixed chunk size C (must equal the AOT config's C for Xla).
    /// Store-backed problems override this with the store manifest's
    /// `chunk_rows` in [`Engine::new`] — the on-disk grid drives the
    /// partition and the streaming windows.
    pub chunk: usize,
    /// Which backend evaluates the per-chunk statistics.
    pub backend: BackendKind,
    /// AOT artifact directory (manifest + HLO text) for the Xla backend.
    pub artifacts_dir: PathBuf,
    /// Optimiser driving step 8 of the cycle.
    pub opt: OptChoice,
    /// Per-view pipelined evaluation cycle (compute overlapping the
    /// collectives) vs the whole-cycle synchronous schedule. The two are
    /// bit-identical in outputs; `false` is the debugging escape hatch.
    pub pipeline: bool,
    /// Print the leader's phase-timing summary after a run.
    pub verbose: bool,
    /// SIMD dispatch tier for the f64 microkernels. `None` defers to the
    /// `GPPAR_SIMD` environment variable, and failing that to
    /// auto-detection (AVX2+FMA when the CPU has it, the portable
    /// chunked-scalar tier otherwise). `Some(SimdLevel::Off)` is the
    /// escape hatch: bit-identical to the pre-SIMD scalar kernels.
    /// Applied process-wide by [`Engine::new`] before any rank spawns.
    pub simd: Option<SimdLevel>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 1,
            chunk: 64,
            backend: BackendKind::RustCpu,
            artifacts_dir: PathBuf::from("artifacts"),
            opt: OptChoice::Lbfgs(Lbfgs { max_iters: 100, ..Default::default() }),
            pipeline: true,
            verbose: false,
            simd: None,
        }
    }
}

/// Everything a training run reports.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Final (maximised) bound F.
    pub f: f64,
    /// Bound after each accepted optimiser iteration.
    pub trace: Vec<f64>,
    /// Fitted parameters (kernels, noise, inducing inputs, latents).
    pub fitted: Fitted,
    /// Leader-side per-phase wall-clock accounting.
    pub timing: PhaseTimer,
    /// Accepted optimiser iterations.
    pub iterations: usize,
    /// Objective evaluations (distributed cycles) driven.
    pub evaluations: usize,
    /// Why the optimiser stopped.
    pub stop: StopReason,
    /// Cluster-wide bytes shipped over the collectives.
    pub bytes_sent: u64,
    /// Cluster-wide message count over the collectives.
    pub messages_sent: u64,
    /// Mean wall-clock per objective evaluation (the paper's
    /// "time per iteration"), seconds.
    pub sec_per_eval: f64,
    /// Per-rank total seconds spent in the distributable phases
    /// (stats_fwd + stats_vjp), indexed by rank.
    pub per_rank_compute: Vec<f64>,
}

impl TrainResult {
    /// Projected wall-clock per iteration on hardware with one core per
    /// rank: the critical path `max_r(distributable_r) + indistributable`.
    ///
    /// This testbed is single-core, so ranks time-share the core and raw
    /// wall-clock cannot exhibit the paper's worker scaling; the per-rank
    /// compute totals *do* divide with workers, and this projection is
    /// the faithful reconstruction of Fig 1a's y-axis (EXPERIMENTS.md
    /// reports both numbers).
    pub fn projected_sec_per_eval(&self) -> f64 {
        if self.evaluations == 0 {
            return 0.0;
        }
        let crit = self.per_rank_compute.iter().cloned().fold(0.0f64, f64::max);
        let leader_total = self.timing.total().as_secs_f64();
        let leader_dist = self.timing.get(Phase::StatsFwd).as_secs_f64()
            + self.timing.get(Phase::StatsVjp).as_secs_f64();
        let indist = (leader_total - leader_dist).max(0.0);
        (crit + indist) / self.evaluations as f64
    }
}

enum RunMode {
    /// Full optimisation.
    Optimize,
    /// Evaluate the objective k times at the initial point (benchmark
    /// mode — the paper's "average time per iteration").
    TimeOnly(usize),
}

/// What the end-of-run serving session should do.
#[derive(Clone, Copy)]
struct ServePlan<'a> {
    /// Test inputs to serve (Nt × Q).
    xstar: &'a Mat,
    /// Serving partition granularity (rows per chunk of the batch split).
    rows_per_chunk: usize,
    /// After the first batch, hot-swap the posterior at the same fitted
    /// parameters (a full STATS round + swap broadcast) and serve the
    /// batch again — the protocol demo behind the CLI's `--refit-demo`.
    refit_demo: bool,
    /// `Some(rows)`: split `xstar` into runs of at most `rows` rows and
    /// serve them as a **batch stream** (batch k+1 issued before batch
    /// k's gather); `None`: one sequential batch. Bit-identical outputs
    /// either way — streaming is a protocol reordering.
    stream_rows: Option<usize>,
}

/// What a serving session produced: the batch output, plus the
/// post-hot-swap output when the plan asked for the refit demo.
type Served = ((Mat, Vec<f64>), Option<(Mat, Vec<f64>)>);

/// Distributed trainer for sparse-GP models.
pub struct Engine {
    /// The inference problem being fit.
    pub problem: Problem,
    /// Cluster + optimiser configuration.
    pub cfg: EngineConfig,
}

impl Engine {
    /// Validate the problem and bind it to a configuration.
    ///
    /// An explicit [`EngineConfig::simd`] tier is applied process-wide
    /// here, before any compute rank spawns, so every rank and backend
    /// runs the same dispatch tier (the serial-vs-distributed
    /// bit-identity guarantees depend on that).
    pub fn new(problem: Problem, mut cfg: EngineConfig) -> Result<Engine> {
        if let Some(level) = cfg.simd {
            simd::set_active(level);
        }
        problem.validate()?;
        if problem.views.iter().any(|v| v.z0.rows() != problem.views[0].z0.rows()) {
            return Err(anyhow!("all views must share M (per-view M is future work)"));
        }
        if let Some(src) = problem.views[0].y.store() {
            // the store's chunk grid is the partition grid: adopt its
            // chunk size so every layer (partition, STATS slot mapping,
            // streaming windows) agrees with the manifest
            cfg.chunk = src.manifest().chunk_rows;
        }
        Ok(Engine { problem, cfg })
    }

    /// The cluster's data partition: store-backed problems are assigned
    /// **by manifest chunk id** ([`Partition::from_manifest`], which also
    /// re-validates the manifest); resident problems by the arithmetic
    /// grid.
    fn partition(&self) -> Result<Partition> {
        match self.problem.views[0].y.store() {
            Some(src) => Partition::from_manifest(src.manifest(), self.cfg.workers),
            None => Ok(Partition::new(self.problem.n(), self.cfg.chunk,
                                      self.cfg.workers)),
        }
    }

    /// Train to convergence (or the iteration budget).
    pub fn train(&self) -> Result<TrainResult> {
        Ok(self.run(RunMode::Optimize, None)?.0)
    }

    /// Benchmark mode: time `evals` objective evaluations without
    /// optimising (Fig 1a/1b harness).
    pub fn time_iterations(&self, evals: usize) -> Result<TrainResult> {
        Ok(self.run(RunMode::TimeOnly(evals), None)?.0)
    }

    /// Train, then serve `xstar` through the sharded posterior on the
    /// *same* cluster before it shuts down — the fitted model's
    /// predictions never leave the SPMD world. Returns the training
    /// result plus the predictive mean (Nt × D) and variance (Nt).
    ///
    /// Supervised (observed-X) problems only. The posterior is built by
    /// the cluster itself: a distributed stats-only pass (the STATS
    /// verb) reduces view 0's statistics at the fitted parameters, so
    /// the leader does **no full-data work** — its own contribution is
    /// its resident chunks, like any other rank. `rows_per_chunk` is the
    /// serving partition granularity (rows per chunk of the batch
    /// split, the serving analog of [`EngineConfig::chunk`]).
    pub fn train_then_predict(&self, xstar: &Mat, rows_per_chunk: usize)
                              -> Result<(TrainResult, Mat, Vec<f64>)> {
        let plan = self.serve_plan(xstar, rows_per_chunk, false, None)?;
        let (result, served) = self.run(RunMode::Optimize, Some(plan))?;
        let ((mean, var), _) = served
            .ok_or_else(|| anyhow!("run returned no serving output"))?;
        Ok((result, mean, var))
    }

    /// [`train_then_predict`](Engine::train_then_predict), but the test
    /// batch is split into runs of at most `stream_rows` rows and served
    /// as a **batch stream**: batch k+1's shard sends overlap batch k's
    /// gather, so the serving ranks never idle for the leader's
    /// round-trip between batches. The assembled output is bit-identical
    /// to the sequential path (streaming is a protocol reordering, not a
    /// different computation).
    pub fn train_then_predict_stream(&self, xstar: &Mat, rows_per_chunk: usize,
                                     stream_rows: usize)
                                     -> Result<(TrainResult, Mat, Vec<f64>)> {
        let plan = self.serve_plan(xstar, rows_per_chunk, false, Some(stream_rows))?;
        let (result, served) = self.run(RunMode::Optimize, Some(plan))?;
        let ((mean, var), _) = served
            .ok_or_else(|| anyhow!("run returned no serving output"))?;
        Ok((result, mean, var))
    }

    /// [`train_then_predict`](Engine::train_then_predict), plus a
    /// **posterior hot-swap exercise**: after the first batch the leader
    /// refits the posterior at the same fitted parameters through
    /// `DistributedEvaluator::refit_and_swap` (STATS round + swap
    /// broadcast, session kept open) and serves the batch again.
    /// Returns the training result and the (before, after) predictions —
    /// identical by construction, which is exactly what the CLI's
    /// `predict --refit-demo` asserts.
    pub fn train_predict_refit(&self, xstar: &Mat, rows_per_chunk: usize)
                               -> Result<(TrainResult, (Mat, Vec<f64>), (Mat, Vec<f64>))> {
        let plan = self.serve_plan(xstar, rows_per_chunk, true, None)?;
        let (result, served) = self.run(RunMode::Optimize, Some(plan))?;
        let (before, after) = served
            .ok_or_else(|| anyhow!("run returned no serving output"))?;
        let after = after
            .ok_or_else(|| anyhow!("run returned no refit-demo output"))?;
        Ok((result, before, after))
    }

    /// Train, then stand up the **concurrent-client serving front-end**
    /// on the same cluster: a micro-batching scheduler
    /// ([`ServingFrontend`]) coalesces rows enqueued by any number of
    /// client threads into size-or-deadline-triggered batches and feeds
    /// them through the streamed sharded-predict pipeline. Per-request
    /// results are bit-identical to serving each request alone.
    ///
    /// `drive` receives a cloneable [`FrontendHandle`] and runs on its
    /// own thread while the leader thread pumps the scheduler; hand
    /// clones to as many client threads as the load calls for. The
    /// session ends when `drive` returns (the queue is closed for it,
    /// even on panic) or when it calls [`FrontendHandle::close`] itself.
    /// Returns the training result, `drive`'s output, and the serving
    /// report (latency/throughput snapshot + serve-phase timings).
    ///
    /// Supervised (observed-X) problems only; `rows_per_chunk` is the
    /// serving partition granularity, as in
    /// [`train_then_predict`](Engine::train_then_predict).
    pub fn train_then_serve<T: Send>(&self, rows_per_chunk: usize, fcfg: FrontendConfig,
                                     drive: impl FnOnce(FrontendHandle) -> T + Send)
                                     -> Result<(TrainResult, T, ServingReport)> {
        match self.problem.latent {
            LatentSpec::Observed(_) => {}
            LatentSpec::ObservedStore => bail!(
                "serving store-backed problems is not yet supported \
                 (train from the store, then build a resident problem to serve)"),
            LatentSpec::Variational { .. } => bail!(
                "train_then_serve needs a supervised problem (observed X)"),
        }
        if rows_per_chunk == 0 {
            bail!("rows_per_chunk must be positive");
        }
        let part = self.partition()?;

        // `Cluster::run` wants `Fn`, but `drive` is `FnOnce`; only
        // rank 0 takes it out of the slot, exactly once.
        let drive_slot = std::sync::Mutex::new(Some(drive));
        let mut results = Cluster::run(self.cfg.workers, |comm| {
            let rank = comm.rank();
            match DistributedEvaluator::new(&self.problem, &self.cfg, &part, comm) {
                Err(e) => Err(anyhow!("rank {rank}: {e:#}")),
                Ok(mut ev) => {
                    if rank == 0 {
                        // a poisoned slot still holds the closure: the
                        // take below is the only critical section
                        let drive = drive_slot
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .take()
                            .ok_or_else(|| anyhow!("leader drive closure already taken"))?;
                        self.leader_frontend(&mut ev, rows_per_chunk, &fcfg, drive).map(Some)
                    } else {
                        ev.serve().map(|_| None)
                    }
                }
            }
        });
        // propagate worker errors first, then take the leader's result
        for r in &results {
            if let Err(e) = r {
                return Err(anyhow!("{e:#}"));
            }
        }
        results
            .remove(0)
            .and_then(|o| o.ok_or_else(|| anyhow!("leader produced no result")))
    }

    /// Validate a serving request against the problem.
    fn serve_plan<'a>(&self, xstar: &'a Mat, rows_per_chunk: usize, refit_demo: bool,
                      stream_rows: Option<usize>) -> Result<ServePlan<'a>> {
        match self.problem.latent {
            LatentSpec::Observed(_) => {}
            LatentSpec::ObservedStore => bail!(
                "serving store-backed problems is not yet supported \
                 (train from the store, then build a resident problem to serve)"),
            LatentSpec::Variational { .. } => bail!(
                "train_then_predict needs a supervised problem (observed X)"),
        }
        if xstar.cols() != self.problem.q {
            bail!("xstar has Q={}, problem has Q={}", xstar.cols(), self.problem.q);
        }
        if rows_per_chunk == 0 {
            bail!("rows_per_chunk must be positive");
        }
        if stream_rows == Some(0) {
            bail!("stream batch rows must be positive");
        }
        Ok(ServePlan { xstar, rows_per_chunk, refit_demo, stream_rows })
    }

    fn run(&self, mode: RunMode, predict: Option<ServePlan>)
           -> Result<(TrainResult, Option<Served>)> {
        let part = self.partition()?;

        let mut results = Cluster::run(self.cfg.workers, |comm| {
            let rank = comm.rank();
            match DistributedEvaluator::new(&self.problem, &self.cfg, &part, comm) {
                Err(e) => Err(anyhow!("rank {rank}: {e:#}")),
                Ok(mut ev) => {
                    if rank == 0 {
                        self.leader(&mut ev, &mode, predict).map(Some)
                    } else {
                        ev.serve().map(|_| None)
                    }
                }
            }
        });
        // propagate worker errors first, then take the leader's result
        for r in &results {
            if let Err(e) = r {
                return Err(anyhow!("{e:#}"));
            }
        }
        results
            .remove(0)
            .and_then(|o| o.ok_or_else(|| anyhow!("leader produced no result")))
    }

    /// Leader: drives the optimiser; each objective call runs the full
    /// distributed cycle through the evaluator. When `predict` is set,
    /// a serving session runs between the last optimiser step and the
    /// shutdown broadcast.
    fn leader(&self, ev: &mut DistributedEvaluator, mode: &RunMode,
              predict: Option<ServePlan>)
              -> Result<(TrainResult, Option<Served>)> {
        let layout = ParamLayout::new(&self.problem);
        let x0 = layout.initial_params(&self.problem);
        let (opt_result, eval_err, eval_count, eval_seconds) = self.optimise(ev, mode, &x0);
        let fitted = layout.unpack_fitted(&self.problem, &opt_result.x);

        // serve the fitted posterior on the same cluster before shutdown
        let mut served = None;
        let mut serve_err: Option<anyhow::Error> = None;
        if let Some(plan) = predict {
            if eval_err.is_none() {
                match self.serve_fitted(ev, &opt_result.x, plan) {
                    Ok(out) => served = Some(out),
                    Err(e) => serve_err = Some(e),
                }
            }
        }

        // 8. stop the workers and collect their compute-time totals
        let per_rank_compute = ev.finish();

        if let Some(e) = eval_err {
            return Err(e);
        }
        if let Some(e) = serve_err {
            return Err(e);
        }

        if self.cfg.verbose {
            eprintln!("[leader] {}", ev.timer().summary());
        }

        Ok((self.assemble(ev, opt_result, fitted, eval_count, eval_seconds,
                          per_rank_compute),
            served))
    }

    /// Drive one optimiser run over the distributed objective. Returns
    /// the optimiser's raw (minimisation-sign) result plus the
    /// evaluation accounting: the first hard cluster error, the number
    /// of successful evaluations, and the wall-clock they took.
    fn optimise(&self, ev: &mut DistributedEvaluator, mode: &RunMode, x0: &[f64])
                -> (OptResult, Option<anyhow::Error>, usize, f64) {
        let n_params = ev.n_params();

        let mut eval_err: Option<anyhow::Error> = None;
        let mut eval_count = 0usize;
        let mut eval_seconds = 0.0f64;

        let opt_result: OptResult = {
            // The distributed objective (−F, −∇F for minimisation).
            let mut objective = |x: &[f64]| -> (f64, Vec<f64>) {
                if eval_err.is_some() {
                    // The first hard error is sticky: stop driving the
                    // (possibly poisoned) evaluator and hand the
                    // optimiser the NaN abort sentinel — it stops with
                    // `StopReason::Aborted` instead of burning further
                    // doomed cluster rounds.
                    return (f64::NAN, vec![0.0; n_params]);
                }
                let t0 = Instant::now();
                match ev.eval(x) {
                    Ok((f, mut grad)) => {
                        eval_count += 1;
                        eval_seconds += t0.elapsed().as_secs_f64();
                        for g in grad.iter_mut() {
                            *g = -*g;
                        }
                        (-f, grad)
                    }
                    Err(e) => {
                        eval_err = Some(e);
                        (f64::NAN, vec![0.0; n_params])
                    }
                }
            };

            match mode {
                RunMode::Optimize => {
                    let opt = self.cfg.opt.as_optimizer();
                    opt.minimize(&mut objective, x0.to_vec())
                }
                RunMode::TimeOnly(k) => {
                    let mut f_last = 0.0;
                    for _ in 0..*k {
                        let (f, _) = objective(x0);
                        f_last = f;
                    }
                    OptResult {
                        x: x0.to_vec(),
                        f: f_last,
                        iterations: *k,
                        evaluations: *k,
                        stop: StopReason::MaxIters,
                        trace: vec![f_last],
                    }
                }
            }
        };

        (opt_result, eval_err, eval_count, eval_seconds)
    }

    /// Assemble the public [`TrainResult`] from a finished run (the sign
    /// flips undo the minimisation convention handed to the optimiser).
    fn assemble(&self, ev: &DistributedEvaluator, opt_result: OptResult, fitted: Fitted,
                eval_count: usize, eval_seconds: f64, per_rank_compute: Vec<f64>)
                -> TrainResult {
        TrainResult {
            f: -opt_result.f,
            trace: opt_result.trace.iter().map(|v| -v).collect(),
            fitted,
            timing: ev.timer().clone(),
            iterations: opt_result.iterations,
            evaluations: opt_result.evaluations,
            stop: opt_result.stop,
            bytes_sent: ev.bytes_sent(),
            messages_sent: ev.messages_sent(),
            sec_per_eval: if eval_count > 0 { eval_seconds / eval_count as f64 } else { 0.0 },
            per_rank_compute,
        }
    }

    /// Leader: one complete serving session over the training cluster —
    /// the posterior is rebuilt at the fitted parameter vector `x`
    /// (usually **free**: the final accepted evaluation's captured
    /// statistics are reused when `x` matches, and only otherwise does a
    /// distributed stats-only pass run — either way, no leader-side
    /// full-data recompute), broadcast, the batch served (sequentially
    /// or as a batch stream, per the plan), and — for the refit demo —
    /// hot-swapped via a STATS round and served again. The session is
    /// always closed, even when a step fails, so the workers are back at
    /// the command broadcast before `finish` stops them.
    fn serve_fitted(&self, ev: &mut DistributedEvaluator, x: &[f64], plan: ServePlan)
                    -> Result<Served> {
        // The refit demo asserts a hot-swap at the same parameters
        // changes *nothing*, and the swapped-in core always comes from
        // the slot-wire STATS round — so its pre-swap core must too (the
        // captured final-eval statistics agree only up to float
        // summation order).
        let core = if plan.refit_demo {
            ev.posterior_core_fresh(x)?
        } else {
            ev.posterior_core_at(x)?
        };
        ev.begin_serving(core, plan.rows_per_chunk)?;
        let first = self.serve_batches(ev, &plan);
        let second = if plan.refit_demo && first.is_ok() {
            Some(ev.refit_and_swap(x)
                 .and_then(|()| self.serve_batches(ev, &plan)))
        } else {
            None
        };
        let end = ev.end_serving();
        let first = first?;
        let second = second.transpose()?;
        end?;
        Ok((first, second))
    }

    /// Leader: serve the plan's test inputs through the open session —
    /// one sequential batch, or a stream of `stream_rows`-row batches
    /// whose per-row results are reassembled into the same (Nt × D, Nt)
    /// shape (row order is preserved, so the two modes are
    /// bit-identical).
    fn serve_batches(&self, ev: &mut DistributedEvaluator, plan: &ServePlan)
                     -> Result<(Mat, Vec<f64>)> {
        let Some(rows) = plan.stream_rows else {
            return ev.predict_sharded(plan.xstar);
        };
        let nt = plan.xstar.rows();
        let q = plan.xstar.cols();
        let d = self.problem.views[0].y.cols();
        let mut batches = Vec::with_capacity((nt + rows - 1) / rows);
        let mut start = 0;
        while start < nt {
            let end = (start + rows).min(nt);
            let slice = plan.xstar.as_slice()[start * q..end * q].to_vec();
            batches.push(Mat::from_vec(end - start, q, slice));
            start = end;
        }
        let outs = ev.predict_stream_sharded(&batches)?;
        let mut mean = Mat::zeros(nt, d);
        let mut var = Vec::with_capacity(nt);
        let mut row = 0;
        for (bm, bv) in &outs {
            mean.as_mut_slice()[row * d..(row + bm.rows()) * d]
                .copy_from_slice(bm.as_slice());
            var.extend_from_slice(bv);
            row += bm.rows();
        }
        Ok((mean, var))
    }

    /// Leader for [`train_then_serve`](Engine::train_then_serve):
    /// optimise, run one front-end serving session at the fitted
    /// parameters, and shut the workers down — mirroring
    /// [`leader`](Engine::leader)'s error ordering (evaluation errors
    /// beat serving errors, and `finish` always runs).
    fn leader_frontend<T: Send>(&self, ev: &mut DistributedEvaluator, rows_per_chunk: usize,
                                fcfg: &FrontendConfig,
                                drive: impl FnOnce(FrontendHandle) -> T + Send)
                                -> Result<(TrainResult, T, ServingReport)> {
        let layout = ParamLayout::new(&self.problem);
        let x0 = layout.initial_params(&self.problem);
        let (opt_result, eval_err, eval_count, eval_seconds) =
            self.optimise(ev, &RunMode::Optimize, &x0);
        let fitted = layout.unpack_fitted(&self.problem, &opt_result.x);

        let mut served: Option<(T, ServingReport)> = None;
        let mut serve_err: Option<anyhow::Error> = None;
        if eval_err.is_none() {
            match self.serve_frontend_session(ev, &opt_result.x, rows_per_chunk, fcfg, drive) {
                Ok(out) => served = Some(out),
                Err(e) => serve_err = Some(e),
            }
        }

        let per_rank_compute = ev.finish();

        if let Some(e) = eval_err {
            return Err(e);
        }
        if let Some(e) = serve_err {
            return Err(e);
        }
        let (out, report) = served
            .ok_or_else(|| anyhow!("serving session produced no output"))?;

        if self.cfg.verbose {
            eprintln!("[leader] {}", ev.timer().summary());
        }

        let result = self.assemble(ev, opt_result, fitted, eval_count, eval_seconds,
                                   per_rank_compute);
        Ok((result, out, report))
    }

    /// Leader: one complete front-end serving session — build and
    /// broadcast the posterior at `x`, pump the micro-batch scheduler on
    /// this thread while `drive` generates load from its own, and close
    /// the session. The client queue is closed when `drive` returns
    /// **even if it panics**, so the scheduler always drains and this
    /// function cannot hang the cluster.
    fn serve_frontend_session<T: Send>(&self, ev: &mut DistributedEvaluator, x: &[f64],
                                       rows_per_chunk: usize, fcfg: &FrontendConfig,
                                       drive: impl FnOnce(FrontendHandle) -> T + Send)
                                       -> Result<(T, ServingReport)> {
        let core = ev.posterior_core_at(x)?;
        ev.begin_serving(core, rows_per_chunk)?;
        let d = self.problem.views[0].y.cols();
        let fe = ServingFrontend::new(fcfg.clone(), self.problem.q, d);
        let (report, out) = std::thread::scope(|s| {
            let handle = fe.handle();
            let jh = s.spawn(move || {
                // Close the queue even when `drive` panics, so the
                // scheduler below always sees end-of-input.
                struct CloseOnDrop(FrontendHandle);
                impl Drop for CloseOnDrop {
                    fn drop(&mut self) {
                        self.0.close();
                    }
                }
                let guard = CloseOnDrop(handle);
                drive(guard.0.clone())
            });
            let report = ev.serve_frontend(&fe);
            (report, jh.join())
        });
        let end = ev.end_serving();
        let report = report?;
        let out = out.map_err(|_| anyhow!("serve drive thread panicked"))?;
        end?;
        Ok((out, report))
    }
}
