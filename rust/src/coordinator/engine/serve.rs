//! Sharded serving: distribute the posterior prediction path across
//! ranks.
//!
//! Training parallelises the *fit*; this module parallelises the
//! *serve*. The precomputed posterior state
//! ([`PosteriorCore`]: `A⁻¹P`, the Woodbury matrix, kernel, Z) is
//! broadcast through `Comm::bcast` **once per session**, then each
//! prediction batch is partitioned over ranks with the same
//! [`Partition`] machinery training uses for datapoints:
//!
//! ```text
//!   L:  bcast [PREDICT, Nt] ── send shard rows ──▸ compute own shard ── gather
//!   W:  bcast ───────────────▸ recv shard ───────▸ predict_batch ────── gather
//! ```
//!
//! Per-shard evaluation goes through [`Backend::predict_batch`] (serial
//! scalar rows on `rust-cpu`, intra-rank row-block fan-out on
//! `parallel-cpu`, host fallback on `xla`), and the per-rank results are
//! gathered back to the leader in rank order. Prediction rows are
//! independent — there is **no cross-row reduction** — so the assembled
//! output is bit-identical to the single-node
//! [`Posterior`](crate::models::Posterior) built from the same core, at
//! every cluster size (asserted for ranks 1–9 in
//! `rust/tests/serve_test.rs`).
//!
//! Failure protocol: a rank whose shard computation errors ships a
//! one-element `[1.0]` failure payload instead of its results, so the
//! gather stays in lockstep and the leader surfaces the failure as an
//! `Err` without desyncing the session.
//!
//! Steady-state allocation: the leader caches the row partition per
//! batch size and reuses wire/output scratch buffers
//! (`CycleScratch`-style), so serving a stream of same-sized batches
//! does not allocate beyond the transport's own message copies.
//!
//! Two ways in:
//! - standalone, over a raw [`Comm`] (see `examples/scaling_demo.rs`):
//!   [`DistributedPosterior::leader`] / [`worker_serve`];
//! - from a training cluster, via
//!   [`DistributedEvaluator::begin_serving`](super::cycle::DistributedEvaluator::begin_serving) —
//!   a fitted model is served by the same ranks without leaving the
//!   SPMD world.

use crate::collectives::Comm;
use crate::coordinator::backend::Backend;
use crate::coordinator::partition::Partition;
use crate::linalg::Mat;
use crate::math::predict::PosteriorCore;
use anyhow::{anyhow, Result};

/// Tag for the leader → worker prediction-shard sends (disjoint from the
/// training cycle's `TAG_LOCALS` and the collective tags).
const TAG_XSTAR: u64 = 300;

/// Serve-session sub-commands (broadcast at each batch).
const SRV_PREDICT: f64 = 1.0;
const SRV_DONE: f64 = 0.0;

/// Reusable per-session buffers so the steady-state serve loop stops
/// allocating: command/shard wires, the worker's shard matrix, per-rank
/// mean/variance staging and the gather payload.
#[derive(Default)]
struct ServeScratch {
    /// Sub-command broadcast buffer (round-trips through `bcast`).
    cmd: Vec<f64>,
    /// Leader-side per-rank shard wire (packed X* rows).
    xwire: Vec<f64>,
    /// Worker-side received shard (rows × Q).
    xshard: Mat,
    /// This rank's shard mean (rows × D, row-major).
    mean: Vec<f64>,
    /// This rank's shard variance (rows).
    var: Vec<f64>,
    /// Gather payload: `mean ++ var ++ [fail flag]`.
    payload: Vec<f64>,
}

/// One rank's half of a sharded serving session. Build with
/// [`DistributedPosterior::leader`] on rank 0 and
/// [`DistributedPosterior::worker`] elsewhere (or let
/// [`worker_serve`] do both worker steps); the construction pair
/// performs the one-time posterior broadcast.
pub struct DistributedPosterior {
    core: PosteriorCore,
    /// Rows per partition chunk (the serving analog of the training
    /// chunk size; granularity of the per-rank row split).
    rows_per_chunk: usize,
    /// Cached row partition, keyed by the batch size it was built for.
    part: Option<Partition>,
    scratch: ServeScratch,
}

impl DistributedPosterior {
    /// Leader (rank 0): broadcast `core` (and the partition granularity)
    /// to every rank, opening the serving session.
    pub fn leader(core: PosteriorCore, rows_per_chunk: usize, comm: &mut Comm)
                  -> DistributedPosterior {
        assert!(rows_per_chunk > 0, "rows_per_chunk must be positive");
        let mut wire = Vec::with_capacity(
            1 + PosteriorCore::wire_len(core.q(), core.m(), core.d()));
        wire.push(rows_per_chunk as f64);
        core.pack_into(&mut wire);
        comm.bcast(0, wire);
        DistributedPosterior { core, rows_per_chunk, part: None,
                               scratch: ServeScratch::default() }
    }

    /// Worker: receive the posterior broadcast that opens the session.
    pub fn worker(comm: &mut Comm) -> Result<DistributedPosterior> {
        let wire = comm.bcast(0, Vec::new());
        if wire.is_empty() {
            return Err(anyhow!("empty posterior broadcast"));
        }
        let rows_per_chunk = wire[0] as usize;
        if rows_per_chunk == 0 {
            return Err(anyhow!("rows_per_chunk must be positive"));
        }
        let core = PosteriorCore::unpack(&wire[1..])?;
        Ok(DistributedPosterior { core, rows_per_chunk, part: None,
                                  scratch: ServeScratch::default() })
    }

    /// The broadcast posterior state.
    pub fn core(&self) -> &PosteriorCore {
        &self.core
    }

    /// Refresh the cached row partition for a batch of `nt` rows
    /// (recomputed only when the batch size changes).
    fn partition_for(&mut self, nt: usize, ranks: usize) -> &Partition {
        let stale = self.part.as_ref().map(|p| p.n != nt).unwrap_or(true);
        if stale {
            self.part = Some(Partition::new(nt, self.rows_per_chunk, ranks));
        }
        self.part.as_ref().expect("partition just ensured")
    }

    /// Leader: predict one batch, sharded across ranks (allocating
    /// convenience wrapper around
    /// [`predict_into`](DistributedPosterior::predict_into)).
    pub fn predict(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                   xstar: &Mat) -> Result<(Mat, Vec<f64>)> {
        let mut mean = Mat::zeros(0, 0);
        let mut var = Vec::new();
        self.predict_into(comm, backend, xstar, &mut mean, &mut var)?;
        Ok((mean, var))
    }

    /// Leader: predict one batch, sharded across ranks, into reusable
    /// output buffers (resized only when the batch shape changes — the
    /// zero-allocation steady-state entry point).
    ///
    /// Row `i` of `xstar` produces row `i` of `mean_out` and
    /// `var_out[i]`; results are assembled in rank order, which is row
    /// order, so the output is bit-identical to the single-node
    /// posterior.
    pub fn predict_into(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                        xstar: &Mat, mean_out: &mut Mat, var_out: &mut Vec<f64>)
                        -> Result<()> {
        let nt = xstar.rows();
        let d = self.core.d();
        if xstar.cols() != self.core.q() {
            return Err(anyhow!("xstar has Q={}, posterior expects Q={}",
                               xstar.cols(), self.core.q()));
        }
        if mean_out.rows() != nt || mean_out.cols() != d {
            *mean_out = Mat::zeros(nt, d);
        }
        var_out.resize(nt, 0.0);
        if nt == 0 {
            return Ok(()); // nothing to shard; no collective round needed
        }

        let ranks = comm.size();
        self.partition_for(nt, ranks);
        let scratch = &mut self.scratch;

        // announce the batch
        scratch.cmd.clear();
        scratch.cmd.extend_from_slice(&[SRV_PREDICT, nt as f64]);
        scratch.cmd = comm.bcast(0, std::mem::take(&mut scratch.cmd));

        // ship each worker its contiguous run of rows
        let part = self.part.as_ref().expect("partition cached above");
        for r in 1..ranks {
            if let Some(sp) = part.worker_span(r) {
                scratch.xwire.clear();
                scratch.xwire.extend_from_slice(
                    &xstar.as_slice()[sp.start * xstar.cols()..sp.end * xstar.cols()]);
                comm.send(r, TAG_XSTAR, &scratch.xwire);
            }
        }

        // leader's own shard (rank 0 always owns the first run of rows),
        // computed straight into the output buffers — no staging copies
        let sp0 = part.worker_span(0).expect("rank 0 owns chunks when nt > 0");
        let rows0 = sp0.len();
        let own = backend.predict_batch(&self.core, xstar, sp0.start, rows0,
                                        &mut mean_out.as_mut_slice()
                                            [sp0.start * d..sp0.end * d],
                                        &mut var_out[sp0.start..sp0.end]);

        // gather (fail-flagged payloads keep the collective in lockstep
        // even when a rank's compute errored; the leader's own results
        // are already in place, so its payload is the flag alone)
        scratch.payload.clear();
        scratch.payload.push(if own.is_ok() { 0.0 } else { 1.0 });
        let gathered = comm.gather(0, &scratch.payload).expect("root");
        own.map_err(|e| anyhow!("rank 0 prediction failed: {e:#}"))?;

        // assemble worker shards into the output rows
        for (r, piece) in gathered.iter().enumerate().skip(1) {
            let Some(sp) = part.worker_span(r) else {
                continue; // chunkless rank contributed nothing
            };
            let rows = sp.len();
            let want = rows * (d + 1) + 1;
            if piece.len() != want || *piece.last().expect("non-empty payload") != 0.0 {
                return Err(anyhow!("prediction failed on rank {r}"));
            }
            mean_out.as_mut_slice()[sp.start * d..sp.end * d]
                .copy_from_slice(&piece[..rows * d]);
            var_out[sp.start..sp.end].copy_from_slice(&piece[rows * d..rows * (d + 1)]);
        }
        Ok(())
    }

    /// Worker: serve prediction batches until the leader ends the
    /// session. A failing shard computation is reported through the
    /// fail-flagged gather payload (the session keeps running); the
    /// first such error is returned once the leader closes the session.
    pub fn serve(&mut self, comm: &mut Comm, backend: &mut dyn Backend) -> Result<()> {
        let rank = comm.rank();
        let ranks = comm.size();
        let d = self.core.d();
        let q = self.core.q();
        let mut sticky_err: Option<anyhow::Error> = None;

        loop {
            let cmd = comm.bcast(0, Vec::new());
            if cmd.is_empty() || cmd[0] == SRV_DONE {
                return match sticky_err {
                    Some(e) => Err(anyhow!("rank {rank}: {e:#}")),
                    None => Ok(()),
                };
            }
            let nt = cmd[1] as usize;
            self.partition_for(nt, ranks);
            let span = self.part.as_ref().expect("partition cached").worker_span(rank);
            let scratch = &mut self.scratch;
            scratch.payload.clear();

            match span {
                None => scratch.payload.push(0.0), // no rows, success by definition
                Some(sp) => {
                    let rows = sp.len();
                    let msg = comm.recv(0, TAG_XSTAR);
                    debug_assert_eq!(msg.len(), rows * q, "shard wire length");
                    if scratch.xshard.rows() == rows && scratch.xshard.cols() == q {
                        scratch.xshard.set_from(&msg);
                    } else {
                        scratch.xshard = Mat::from_vec(rows, q, msg);
                    }
                    scratch.mean.clear();
                    scratch.mean.resize(rows * d, 0.0);
                    scratch.var.clear();
                    scratch.var.resize(rows, 0.0);
                    match backend.predict_batch(&self.core, &scratch.xshard, 0, rows,
                                                &mut scratch.mean, &mut scratch.var) {
                        Ok(()) => {
                            scratch.payload.extend_from_slice(&scratch.mean);
                            scratch.payload.extend_from_slice(&scratch.var);
                            scratch.payload.push(0.0);
                        }
                        Err(e) => {
                            scratch.payload.push(1.0);
                            if sticky_err.is_none() {
                                sticky_err = Some(e);
                            }
                        }
                    }
                }
            }
            let _ = comm.gather(0, &scratch.payload);
        }
    }

    /// Leader: close the session — workers return from
    /// [`serve`](DistributedPosterior::serve).
    pub fn finish(&mut self, comm: &mut Comm) {
        comm.bcast(0, vec![SRV_DONE]);
    }
}

/// Worker half of a whole serving session in one call: receive the
/// posterior broadcast, then serve batches until the leader closes the
/// session. This is what the training cycle's worker loop calls when the
/// leader switches the cluster into serving mode.
pub fn worker_serve(comm: &mut Comm, backend: &mut dyn Backend) -> Result<()> {
    let mut dp = DistributedPosterior::worker(comm)?;
    dp.serve(comm, backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Cluster;
    use crate::coordinator::backend::RustCpuBackend;
    use crate::kern::RbfArd;
    use crate::math::stats::sgpr_stats_fwd;
    use crate::models::Posterior;
    use crate::testutil::prop::Rng64;

    fn toy_core(seed: u64) -> PosteriorCore {
        let (n, m, q, d) = (50usize, 8usize, 2usize, 3usize);
        let mut rng = Rng64::new(seed);
        let x = Mat::from_fn(n, q, |_, _| rng.normal());
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        let kern = RbfArd::iso(1.2, 1.1, q);
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
        PosteriorCore::new(kern, z, 20.0, &st).unwrap()
    }

    /// Several batches (including a resize and an empty batch) through
    /// one session must each match the single-node posterior exactly.
    #[test]
    fn session_serves_multiple_batch_sizes() {
        let core = toy_core(42);
        let single = Posterior::from_core(core.clone());
        let mut rng = Rng64::new(43);
        let batches: Vec<Mat> = [17usize, 17, 0, 5]
            .iter()
            .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
            .collect();
        let expect: Vec<(Mat, Vec<f64>)> =
            batches.iter().map(|b| single.predict(b)).collect();

        for size in [1usize, 3, 4] {
            let core_ref = &core;
            let batches_ref = &batches;
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                             &mut comm);
                    let mut out = Vec::new();
                    let mut mean = Mat::zeros(0, 0);
                    let mut var = Vec::new();
                    for b in batches_ref {
                        dp.predict_into(&mut comm, &mut backend, b, &mut mean,
                                        &mut var).unwrap();
                        out.push((mean.clone(), var.clone()));
                    }
                    dp.finish(&mut comm);
                    Some(out)
                } else {
                    worker_serve(&mut comm, &mut backend).unwrap();
                    None
                }
            });
            let got = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in got.iter().zip(&expect).enumerate() {
                assert_eq!(gm.rows(), em.rows(), "size {size} batch {i}");
                if em.rows() > 0 {
                    assert!(gm.max_abs_diff(em) == 0.0, "size {size} batch {i}: mean");
                }
                assert_eq!(gv, ev, "size {size} batch {i}: var");
            }
        }
    }

    /// A batch smaller than the rank count leaves trailing ranks without
    /// rows; they must still stay in lockstep.
    #[test]
    fn tiny_batches_leave_ranks_idle_but_synchronised() {
        let core = toy_core(44);
        let single = Posterior::from_core(core.clone());
        let mut rng = Rng64::new(45);
        let xstar = Mat::from_fn(2, 2, |_, _| rng.normal());
        let (em, ev) = single.predict(&xstar);

        let core_ref = &core;
        let xs = &xstar;
        let results = Cluster::run(5, move |mut comm| {
            let mut backend = RustCpuBackend;
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), 1, &mut comm);
                let out = dp.predict(&mut comm, &mut backend, xs).unwrap();
                dp.finish(&mut comm);
                Some(out)
            } else {
                worker_serve(&mut comm, &mut backend).unwrap();
                None
            }
        });
        let (gm, gv) = results[0].as_ref().expect("leader output");
        assert!(gm.max_abs_diff(&em) == 0.0);
        assert_eq!(gv, &ev);
    }
}
